"""Federated / cross-pod update compression (the paper's §VI scenario).

    PYTHONPATH=src python examples/federated_updates.py

Simulates N workers computing local gradients; each worker RD-quantizes its
update on the DeepCABAC grid with error feedback, and the server aggregates
dequantized updates.  Reports the wire rate the CABAC coder achieves on the
quantized update stream vs raw fp32, and shows training still converges.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.compression.q8 import q8_encode
from repro.core import binarization as B
from repro.core.cabac import RangeEncoder
from repro.distributed.compress import (CompressionConfig,
                                        ef_compress_update,
                                        init_error_feedback)


def main():
    rng = np.random.default_rng(0)
    n_workers, dim = 4, (64, 512)
    target = jnp.asarray(rng.standard_normal(dim), jnp.float32)
    params = {"w": jnp.zeros(dim, jnp.float32)}
    efs = [init_error_feedback(params) for _ in range(n_workers)]
    cfg = CompressionConfig(enabled=True)
    lr = 0.1
    wire_bits, raw_bits = 0.0, 0.0

    for step in range(150):
        agg = jnp.zeros(dim, jnp.float32)
        for wkr in range(n_workers):
            noise = 0.05 * jnp.asarray(
                rng.standard_normal(dim), jnp.float32)
            g = {"w": 2 * (params["w"] - target) + noise}
            gq, efs[wkr] = ef_compress_update(g, efs[wkr], cfg)
            agg = agg + gq["w"]
            if step % 25 == 0 and wkr == 0:
                codes, _ = q8_encode(g["w"])
                enc = RangeEncoder(B.make_contexts())
                B.encode_levels(enc, np.asarray(codes,
                                                np.int64).ravel()[:65536])
                bits = 8 * len(enc.finish()) / 65536
                wire_bits += bits
                raw_bits += 32
        params = {"w": params["w"] - lr * agg / n_workers}
        if step % 25 == 0:
            err = float(jnp.mean(jnp.square(params["w"] - target)))
            print(f"step {step:3d}: mse={err:.2e}")

    err = float(jnp.mean(jnp.square(params["w"] - target)))
    n = wire_bits and raw_bits
    print(f"final mse {err:.2e}; CABAC'd update stream: "
          f"{wire_bits/(raw_bits/32):.2f} bits/param vs 32 fp32 "
          f"(x{raw_bits/wire_bits:.1f} less inter-pod traffic)")
    assert err < 1e-3


if __name__ == "__main__":
    main()
