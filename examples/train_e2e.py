"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps with fault-tolerant compressed checkpointing.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--tiny]

``--tiny`` shrinks to the smoke config for quick CI runs; the default is a
≈80M-parameter model (CPU-feasible in ~20-40 min; the same driver scales
to the full assigned configs on a TPU mesh via launch/train.py).
"""

import argparse
import tempfile

from repro.checkpoint.manager import CheckpointConfig
from repro.configs import get_smoke_config
from repro.distributed.compress import CompressionConfig
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3-8b")
    if not args.tiny:
        # ~80M params: a real (if small) language model
        cfg = cfg.replace(num_layers=8, d_model=512, num_heads=8,
                          num_kv_heads=4, head_dim=64, d_ff=1536,
                          vocab_size=32768)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    mesh = make_local_mesh(1, 1)
    print(f"training {cfg.name} variant: L={cfg.num_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab_size}; ckpt -> {ckpt_dir}")
    res = train_loop(
        cfg, mesh,
        LoopConfig(total_steps=args.steps, batch=8,
                   seq=256 if not args.tiny else 64,
                   ckpt_every=100, log_every=20),
        opt_cfg=AdamWConfig(lr=1e-3),
        comp_cfg=CompressionConfig(enabled=True),
        ckpt_cfg=CheckpointConfig(ckpt_dir, params_mode="cabac",
                                  delta_rel=1e-3, async_save=True))
    n = max(len(res.losses) // 10, 1)
    for i in range(0, len(res.losses), n):
        print(f"  step {i:4d}: loss {res.losses[i]:.4f}")
    print(f"final loss {res.losses[-1]:.4f} (from {res.losses[0]:.4f}); "
          f"checkpoints at {ckpt_dir}")
    assert res.losses[-1] < res.losses[0]


if __name__ == "__main__":
    main()
