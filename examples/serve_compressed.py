"""Serve a model from a DeepCABAC container with request-level batching.

    PYTHONPATH=src python examples/serve_compressed.py

Trains briefly, writes the weights as a DeepCABAC container (the paper's
deployment artifact), then serves through `ServeSession` with three weight
backends — `bf16` (raw weights), `container` (stream-decoded blob), and
`q8` (in-memory int8 fixed-point) — submitting mixed-length requests and
verifying the container session emits exactly the raw session's tokens.
"""

import numpy as np
import jax

from repro.compression import flatten_tree, get
from repro.configs import get_smoke_config
from repro.data.pipeline import make_batch
from repro.models.transformer import init_params, train_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.serve.session import ServeConfig, ServeSession


def run_session(cfg, weights, backend, prompts, steps):
    session = ServeSession(cfg, weights, backend=backend,
                           serve_cfg=ServeConfig(slots=4, max_len=96))
    handles = [session.submit(p, max_new_tokens=steps) for p in prompts]
    session.run()
    return [h.result() for h in handles]


def main():
    cfg = get_smoke_config("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=2e-3)
    state = adamw_init(params, ocfg)
    step = jax.jit(lambda p, s, b: adamw_update(
        jax.grad(train_loss)(p, b, cfg), s, p, ocfg))
    print("training briefly ...")
    for i in range(80):
        params, state = step(params, state,
                             make_batch(cfg, i, batch=16, seq=64))

    flat = flatten_tree(params)
    res = get("deepcabac-v2", delta=1e-4, lam=0.0).compress(flat)
    print(f"container: {len(res.blob)/1024:.1f} KiB "
          f"({res.report['bits_per_param']:.2f} bits/param, "
          f"x{100/res.report['ratio_pct']:.1f} vs fp32)")

    # mixed-length request stream — more requests than KV slots, so the
    # scheduler exercises admission + eviction
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (16, 9, 24, 12, 16, 7)]
    out_raw = run_session(cfg, params, "bf16", prompts, steps=24)
    out_c = run_session(cfg, res.blob, "container", prompts, steps=24)
    match = np.mean([np.mean(a == b) for a, b in zip(out_raw, out_c)])
    print(f"{len(prompts)} requests x 24 tokens; "
          f"token agreement raw-vs-compressed = {match:.3f}")
    assert match == 1.0, "near-lossless container must match greedy decode"

    # the int8 fixed-point path trades exactness for bandwidth
    out_q8 = run_session(cfg, params, "q8", prompts, steps=24)
    agree = np.mean([np.mean(a == b) for a, b in zip(out_raw, out_q8)])
    print(f"q8 fixed-point backend token agreement vs bf16 = {agree:.3f}")
    print("OK")


if __name__ == "__main__":
    main()
