"""Serve a model from a DeepCABAC container with batched requests.

    PYTHONPATH=src python examples/serve_compressed.py

Trains briefly, writes the weights as a DeepCABAC container (the paper's
deployment artifact), loads a ServeEngine from the container, and runs
batched greedy generation — verifying the compressed engine emits the same
tokens as the raw-weight engine.
"""

import numpy as np
import jax

from repro.compression import flatten_tree, get
from repro.configs import get_smoke_config
from repro.data.pipeline import make_batch
from repro.models.transformer import init_params, train_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.serve.engine import ServeEngine


def main():
    cfg = get_smoke_config("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=2e-3)
    state = adamw_init(params, ocfg)
    step = jax.jit(lambda p, s, b: adamw_update(
        jax.grad(train_loss)(p, b, cfg), s, p, ocfg))
    print("training briefly ...")
    for i in range(80):
        params, state = step(params, state,
                             make_batch(cfg, i, batch=16, seq=64))

    flat = flatten_tree(params)
    res = get("deepcabac-v2", delta=1e-4, lam=0.0).compress(flat)
    print(f"container: {len(res.blob)/1024:.1f} KiB "
          f"({res.report['bits_per_param']:.2f} bits/param, "
          f"x{100/res.report['ratio_pct']:.1f} vs fp32)")

    raw = ServeEngine(cfg, params, max_len=96)
    compressed = ServeEngine.from_compressed(cfg, res.blob, max_len=96)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 16)).astype(np.int32)
    out_raw = raw.generate(prompts, steps=24)
    out_c = compressed.generate(prompts, steps=24)
    match = np.mean(out_raw == out_c)
    print(f"batched generation: {out_c.shape}; "
          f"token agreement raw-vs-compressed = {match:.3f}")
    assert match == 1.0, "near-lossless container must match greedy decode"
    print("OK")


if __name__ == "__main__":
    main()
