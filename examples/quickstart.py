"""Quickstart: compress a model with DeepCABAC and decode it back.

    PYTHONPATH=src python examples/quickstart.py

Trains a small MLP on a synthetic task, compresses it with DC-v2 (the
grid-search quantizer + CABAC), compares against uniform quantization +
Huffman, and verifies accuracy survives.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

from benchmarks.tasks import flat_weights, train_mlp  # noqa: E402
from repro.core.deepcabac import compress_dc_v2  # noqa: E402
from repro.core.codec import decode_state_dict  # noqa: E402
from repro.core.huffman import scalar_huffman_size_bits  # noqa: E402
from repro.core.quant import uniform_quantize  # noqa: E402


def main():
    print("training a small classifier on a synthetic task ...")
    fx = train_mlp(steps=300)
    flat = flat_weights(fx.params)
    orig_acc = fx.accuracy(fx.params)
    orig_bits = 32 * sum(w.size for w in flat.values())
    print(f"original: acc={orig_acc:.4f}, size={orig_bits/8/1024:.1f} KiB")

    print("\nDeepCABAC (DC-v2), a few (Delta, lambda) points:")
    wmax = max(float(np.abs(w).max()) for w in flat.values() if w.ndim >= 2)
    for frac, lam in [(0.05, 0.0), (0.1, 1e-4), (0.25, 1e-3)]:
        res = compress_dc_v2(flat, delta=frac * wmax, lam=lam)
        rec = res.reconstructed()
        acc = fx.accuracy({k: np.asarray(v) for k, v in rec.items()})
        ratio = orig_bits / (8 * len(res.blob))
        print(f"  delta={frac:0.2f}*wmax lam={lam:7.0e}: "
              f"x{ratio:5.1f} smaller, acc={acc:.4f}, "
              f"{res.report['bits_per_param']:.2f} bits/param")

    # decode round-trip through the container
    blob = compress_dc_v2(flat, delta=0.05 * wmax, lam=1e-4).blob
    restored = decode_state_dict(blob)
    assert set(restored) == set(flat)
    print(f"\ncontainer decode OK ({len(blob)} bytes)")

    # baseline: uniform quantization + scalar Huffman
    bits = 0
    for w in flat.values():
        if w.ndim >= 2:
            a, centers = uniform_quantize(w.ravel(), 64)
            bits += scalar_huffman_size_bits(a) + 32 * 64
        else:
            bits += 32 * w.size
    print(f"uniform(64) + Huffman baseline: x{orig_bits/bits:.1f} smaller")


if __name__ == "__main__":
    main()
