"""Quickstart: compress a model with DeepCABAC and decode it back.

    PYTHONPATH=src python examples/quickstart.py

Trains a small MLP on a synthetic task, compresses it through the
``repro.compression`` codec registry with DC-v2 (the grid quantizer +
CABAC), compares against the scalar-Huffman baseline codec, and verifies
accuracy survives.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

from benchmarks.tasks import flat_weights, train_mlp  # noqa: E402
from repro import compression  # noqa: E402


def main():
    print("training a small classifier on a synthetic task ...")
    fx = train_mlp(steps=300)
    flat = flat_weights(fx.params)
    orig_acc = fx.accuracy(fx.params)
    orig_bits = 32 * sum(w.size for w in flat.values())
    print(f"original: acc={orig_acc:.4f}, size={orig_bits/8/1024:.1f} KiB")
    print(f"registered codecs: {', '.join(compression.available())}")

    print("\nDeepCABAC (DC-v2), a few (Delta, lambda) points:")
    wmax = max(float(np.abs(w).max()) for w in flat.values() if w.ndim >= 2)
    for frac, lam in [(0.05, 0.0), (0.1, 1e-4), (0.25, 1e-3)]:
        codec = compression.get("deepcabac-v2", delta=frac * wmax, lam=lam)
        res = codec.compress(flat)
        rec = res.reconstructed()
        acc = fx.accuracy({k: np.asarray(v) for k, v in rec.items()})
        ratio = orig_bits / (8 * len(res.blob))
        print(f"  delta={frac:0.2f}*wmax lam={lam:7.0e}: "
              f"x{ratio:5.1f} smaller, acc={acc:.4f}, "
              f"{res.report['bits_per_param']:.2f} bits/param")

    # decode round-trip through the container
    blob = compression.get("deepcabac-v2",
                           delta=0.05 * wmax, lam=1e-4).compress(flat).blob
    restored = compression.decompress(blob)
    assert set(restored) == set(flat)
    print(f"\ncontainer decode OK ({len(blob)} bytes)")

    # baseline: same nearest-level grid, scalar Huffman with explicit table
    huff = compression.get("huffman", delta_rel=0.25).compress(flat)
    acc = fx.accuracy({k: np.asarray(v)
                       for k, v in huff.reconstructed().items()})
    print(f"huffman baseline: x{orig_bits/(8*len(huff.blob)):.1f} smaller, "
          f"acc={acc:.4f} "
          f"({huff.report['bits_per_param']:.2f} bits/param incl. tables)")


if __name__ == "__main__":
    main()
