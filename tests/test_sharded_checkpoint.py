"""Sharded checkpoints: shard-grid math, manifest integrity, byte-range
record reads, elastic N->M restore (bit-identical to the monolithic
path), sub-mesh decode accounting, and backend cold-start from a
manifest."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint import sharded
from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.checkpoint.sharded import MeshSpec
from repro.compression.tree import flatten_tree
from repro.configs import get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _state(seed=0):
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_train_state(cfg, AdamWConfig(), seed=seed)


def _save_both(tmp_path, state, codec="deepcabac-v3", save_shards=4):
    mono = CheckpointManager(CheckpointConfig(
        os.path.join(str(tmp_path), "mono"), codec=codec, delta_rel=1e-3))
    mono.save(state, 1)
    shard = CheckpointManager(CheckpointConfig(
        os.path.join(str(tmp_path), "shard"), codec=codec, delta_rel=1e-3,
        sharded=True, shard_workers=2))
    shard.save(state, 1, mesh=MeshSpec(("data", "model"), (save_shards, 1)))
    return mono, shard


def _step_dir(mgr, step=1):
    return os.path.join(mgr.cfg.directory, f"step_{step:08d}")


# -- shard-grid math ---------------------------------------------------------

def test_mesh_spec_from_any():
    ms = MeshSpec.from_any({"data": 4, "model": 2})
    assert ms.axis_names == ("data", "model")
    assert ms.size == 8
    assert MeshSpec.from_any(ms) is ms
    assert MeshSpec.from_any(None).size == 1


def test_shard_grid_and_boxes():
    mesh = MeshSpec(("data", "model"), (4, 2))
    axes = [("data",), ()]
    assert sharded.shard_grid(axes, mesh) == (4, 1)
    starts, stops = sharded.shard_box((8, 6), (4, 1), (2, 0))
    assert starts == (4, 0) and stops == (6, 6)
    # tuple-axis dim: 8-way shard over (data, model), data major
    axes = [("data", "model"), ()]
    assert sharded.shard_grid(axes, mesh) == (8, 1)
    starts, stops = sharded.shard_box((16, 4), (8, 1), (5, 0))
    assert starts == (10, 0) and stops == (12, 4)


def test_owner_device_dedupes_replicas():
    mesh = MeshSpec(("data", "model"), (2, 2))
    axes = [("data",), ()]          # replicated over model
    owners = {sharded._owner_device(axes, mesh, (i, 0)) for i in range(2)}
    # owners are the model=0 replicas: flat ids 0 and 2
    assert owners == {0, 2}


def test_device_box_covers_mesh():
    mesh = MeshSpec(("data", "model"), (2, 2))
    axes = [("data",), ("model",)]
    seen = set()
    for dev in range(mesh.size):
        starts, stops = sharded.device_box((8, 8), axes, mesh, dev)
        seen.add((starts, stops))
    assert len(seen) == 4           # 2x2 distinct boxes
    assert sum((b[0] - a[0]) * (b[1] - a[1])
               for (a, b) in seen) == 64


# -- save/restore round trips ------------------------------------------------

def test_sharded_restore_bit_identical_to_monolithic(tmp_path):
    cfg, state = _state()
    mono, shard = _save_both(tmp_path, state)
    r_mono, _ = mono.restore(state)
    r_shard, meta = shard.restore(state)
    assert meta["sharded"] is True
    assert meta["shard_files"] >= 2
    for a, b in zip(jax.tree.leaves(r_mono["params"]),
                    jax.tree.leaves(r_shard["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-param state is exact
    np.testing.assert_array_equal(np.asarray(state["step"]),
                                  np.asarray(r_shard["step"]))


def test_restore_on_mesh_in_process(tmp_path):
    """mesh= restore returns mesh-sharded jax Arrays, bit-identical."""
    cfg, state = _state()
    mono, shard = _save_both(tmp_path, state, save_shards=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r_mesh, _ = shard.restore(state, mesh=mesh)
    r_mono, _ = mono.restore(state)
    leaves = jax.tree.leaves(r_mesh["params"])
    assert all(isinstance(x, jax.Array) for x in leaves)
    assert leaves[0].sharding.mesh.shape == {"data": 1, "model": 1}
    for a, b in zip(jax.tree.leaves(r_mono["params"]), leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_schema_and_byte_ranges(tmp_path):
    from repro.core.container import read_record_at
    cfg, state = _state()
    _, shard = _save_both(tmp_path, state)
    d = _step_dir(shard)
    manifest = sharded.load_manifest(d)
    assert manifest["format"] == "dcbc-manifest"
    assert manifest["mesh"] == {"axes": ["data", "model"], "shape": [4, 1]}
    sharded.verify_files(d, manifest)      # content hashes hold
    n_cabac = 0
    for name, tinfo in manifest["tensors"].items():
        covered = 0
        for sh in tinfo["shards"]:
            # every manifest byte-range must parse standalone
            with open(os.path.join(d, sh["file"]), "rb") as f:
                f.seek(sh["offset"])
                buf = f.read(sh["length"])
            hdr, payload = read_record_at(buf)
            assert hdr.name == sh["record"]
            assert tuple(hdr.shape) == tuple(
                b - a for a, b in zip(sh["start"], sh["stop"]))
            covered += int(np.prod(hdr.shape)) if hdr.shape else 1
            if tinfo["encoding"] == "cabac_v3":
                assert sh["chunk_counts"] == list(hdr.chunk_counts)
                n_cabac += 1
        assert covered == int(np.prod(tinfo["shape"]))
    assert n_cabac > 4                      # tensors actually sharded


def test_submesh_restore_decodes_strictly_fewer_values(tmp_path):
    cfg, state = _state()
    _, shard = _save_both(tmp_path, state)
    d = _step_dir(shard)
    manifest = sharded.load_manifest(d)
    total = sharded.manifest_total_values(manifest)
    stats = sharded.RestoreStats()
    out = sharded.restore_local_slices(
        d, MeshSpec(("data", "model"), (2, 1)), [0], stats=stats)
    assert stats.decoded_values < total
    # ... and the decoded slices are the right slices
    flat = flatten_tree(jax.device_get(state["params"]))
    full = sharded.restore_flat(d)
    for name, by_dev in out.items():
        (arr,) = by_dev.values()
        ref = full[name]
        box = tuple(slice(0, s) for s in arr.shape)
        np.testing.assert_array_equal(arr, ref[box])
        assert name in flat


def test_truncated_shard_file_errors(tmp_path):
    cfg, state = _state()
    _, shard = _save_both(tmp_path, state)
    d = _step_dir(shard)
    fname = sorted(f for f in os.listdir(d) if f.endswith(".dcbc"))[0]
    path = os.path.join(d, fname)
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(ValueError, match="truncated"):
        sharded.restore_flat(d)
    # hash verification also catches it
    with pytest.raises(ValueError, match="hash mismatch"):
        sharded.verify_files(d, sharded.load_manifest(d))


def test_restore_mesh_on_monolithic_checkpoint_errors(tmp_path):
    """mesh= must not be a silent no-op against a monolithic save."""
    cfg, state = _state()
    mono = CheckpointManager(CheckpointConfig(
        str(tmp_path), codec="deepcabac-v3", delta_rel=1e-3))
    mono.save(state, 1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="sharded checkpoint"):
        mono.restore(state, mesh=mesh)


def test_manifest_version_gate(tmp_path):
    cfg, state = _state()
    _, shard = _save_both(tmp_path, state)
    d = _step_dir(shard)
    mpath = os.path.join(d, sharded.MANIFEST_NAME)
    manifest = json.load(open(mpath))
    manifest["manifest_version"] = sharded.MANIFEST_MAX_VERSION + 1
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ValueError, match="manifest version"):
        sharded.load_manifest(d)


# -- serve backend cold start from a manifest --------------------------------

@pytest.mark.parametrize("backend", ["bf16", "container", "q8"])
def test_backend_cold_start_from_manifest(tmp_path, backend):
    from repro import compression
    from repro.serve.backends import get_backend

    cfg = get_smoke_config("llama3-8b")
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    codec = compression.get("deepcabac-v3", delta_rel=1e-3)
    blob = codec.compress(params).blob
    payloads, manifest = sharded.write_sharded(
        codec.quantize_entries(flatten_tree(params)),
        MeshSpec(("data", "model"), (2, 1)), codec_name=codec.name)
    d = str(tmp_path)
    for fname, data in payloads.items():
        with open(os.path.join(d, fname), "wb") as f:
            f.write(data)
    with open(os.path.join(d, sharded.MANIFEST_NAME), "w") as f:
        json.dump(manifest, f)

    from_blob = get_backend(backend).load(cfg, blob)
    from_manifest = get_backend(backend).load(cfg, d)
    la, lb = jax.tree.leaves(from_blob), jax.tree.leaves(from_manifest)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_backend_manifest_on_mesh(tmp_path):
    from repro import compression
    from repro.serve.backends import Bf16Backend

    cfg = get_smoke_config("llama3-8b")
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    codec = compression.get("deepcabac-v3", delta_rel=1e-3)
    payloads, manifest = sharded.write_sharded(
        codec.quantize_entries(flatten_tree(params)),
        MeshSpec(("data", "model"), (2, 1)), codec_name=codec.name)
    d = str(tmp_path)
    for fname, data in payloads.items():
        with open(os.path.join(d, fname), "wb") as f:
            f.write(data)
    with open(os.path.join(d, sharded.MANIFEST_NAME), "w") as f:
        json.dump(manifest, f)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = Bf16Backend(mesh=mesh).load(cfg, d)
    leaves = jax.tree.leaves(tree)
    assert all(isinstance(x, jax.Array) for x in leaves)
    assert leaves[0].sharding.mesh.shape == {"data": 1, "model": 1}
    ref = Bf16Backend().load(cfg, codec.compress(params).blob)
    for a, b in zip(jax.tree.leaves(ref), leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- N -> M elastic resharding (real multi-device meshes, subprocess) --------

ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax
import numpy as np

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.checkpoint.sharded import MeshSpec
from repro.configs import get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state

cfg = get_smoke_config("llama3-8b")
state = init_train_state(cfg, AdamWConfig(), seed=0)
with tempfile.TemporaryDirectory() as td:
    mono = CheckpointManager(CheckpointConfig(td + "/mono",
                                              codec="deepcabac-v3"))
    mono.save(state, 1)
    ref, _ = mono.restore(state)
    mgr = CheckpointManager(CheckpointConfig(td + "/shard",
                                             codec="deepcabac-v3",
                                             sharded=True, shard_workers=2))
    # save on a simulated 4-device mesh ...
    mgr.save(state, 1, mesh=MeshSpec(("data", "model"), (4, 1)))
    # ... restore on 1-, 2- and 8-device meshes
    for shape in [(1, 1), (2, 1), (4, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        restored, _ = mgr.restore(state, mesh=mesh)
        leaves = jax.tree.leaves(restored["params"])
        assert all(isinstance(x, jax.Array) for x in leaves)
        assert leaves[0].sharding.mesh.shape == dict(
            zip(("data", "model"), shape)), leaves[0].sharding
        for a, b in zip(jax.tree.leaves(ref["params"]), leaves):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print(json.dumps({"ok": True}))
"""


def test_elastic_nm_resharding_roundtrip():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"] is True
