"""Flash-attention Pallas kernel: shape/dtype sweep vs the jnp oracle, and
equivalence with the model's scan-flash path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.attention import attend


@pytest.mark.parametrize("b,sq,skv,h,g,d", [
    (2, 256, 256, 4, 2, 64),
    (1, 512, 512, 2, 2, 128),
    (2, 128, 384, 4, 4, 64),     # q shorter than kv (causal offset)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(b, sq, skv, h, g, d, dtype):
    rng = np.random.default_rng(b * 100 + sq)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, g, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, g, d)), dtype)
    ref = np.asarray(flash_attention(q, k, v, use_ref=True),
                     dtype=np.float32)
    out = np.asarray(flash_attention(q, k, v, interpret=True,
                                     bq=128, bk=128), dtype=np.float32)
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out, ref, atol=tol, rtol=tol)


def test_flash_matches_scan_attend():
    """The kernel and the model's scan-flash path agree (same math)."""
    rng = np.random.default_rng(7)
    b, s, h, g, d = 2, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(s), (b, s))
    scan = np.asarray(attend(q, k, v, qpos, impl="scan", kv_block=128))
    kern = np.asarray(flash_attention(q, k, v, interpret=True,
                                      bq=128, bk=128))
    np.testing.assert_allclose(kern, scan, atol=2e-5, rtol=2e-5)


def test_flash_ref_is_causal():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    out1 = flash_attention_ref(q, k, v, causal=True)
    # future keys must not influence earlier outputs
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(99.0)
    out2 = flash_attention_ref(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Regression: the old `attend` silently dropped to naive when pallas_flash
# was requested with ragged kv_len or d != dv.  Now the downgrade is
# recorded in kernels.dispatch_report() and raises under strict policies.
# ---------------------------------------------------------------------------

def _ragged_inputs():
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.standard_normal((2, 16, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 2, 32)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    kv_len = jnp.asarray([9, 16], jnp.int32)
    return q, k, v, qpos, kv_len


def test_pallas_flash_kv_len_fallback_is_recorded():
    from repro import kernels
    kernels.clear_dispatch_report()
    q, k, v, qpos, kv_len = _ragged_inputs()
    pol = kernels.KernelPolicy(platform="tpu").override(
        "flash_attention", "pallas")
    out = attend(q, k, v, qpos, policy=pol, kv_len=kv_len)
    # fell back to a kv_len-aware path, and said so
    want = attend(q, k, v, qpos, impl="naive", kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    recs = [r for r in kernels.dispatch_report()
            if r["op"] == "flash_attention" and r["requested"] == "pallas"]
    assert recs and "kv_len" in recs[0]["reason"]
    kernels.clear_dispatch_report()


def test_pallas_flash_kv_len_strict_raises():
    from repro import kernels
    q, k, v, qpos, kv_len = _ragged_inputs()
    pol = kernels.KernelPolicy(platform="tpu", strict=True).override(
        "flash_attention", "pallas")
    with pytest.raises(kernels.KernelDispatchError, match="kv_len"):
        attend(q, k, v, qpos, policy=pol, kv_len=kv_len)
    # d != dv mismatch raises too
    v8 = v[..., :8]
    with pytest.raises(kernels.KernelDispatchError, match="d != dv"):
        attend(q, k, v8, qpos, policy=pol)
    # but a satisfiable strict request runs
    out = attend(q, k, v, qpos, policy=pol.override(
        "flash_attention", "interpret"))
    assert out.shape == q.shape
