"""Mini dry-run in a subprocess: 8 fake host devices, reduced configs,
(2,2,2) pod mesh — exercises the real lower_cell/analyze path including the
cross-pod axis and the compressed cross-pod collective."""

import json
import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.dryrun import analyze, collective_bytes
from repro.distributed.compress import cross_pod_psum_compressed
from repro.distributed.sharding import DEFAULT_RULES
from repro.optim.adamw import AdamWConfig
from repro.distributed.compress import CompressionConfig
from repro.train.steps import (batch_specs, init_train_state,
                               make_train_step, state_specs)
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_smoke_config("llama3-8b").replace(
    num_heads=4, num_kv_heads=2, d_model=128, d_ff=256)
ocfg, ccfg = AdamWConfig(), CompressionConfig(enabled=True)
state_shape = jax.eval_shape(lambda: init_train_state(cfg, ocfg, ccfg))
step_fn, _ = make_train_step(cfg, mesh, ocfg, ccfg)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
st_specs = state_specs(state_shape, mesh, DEFAULT_RULES)
b_specs = batch_specs(batch, mesh, DEFAULT_RULES)
sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
lowered = jax.jit(step_fn, in_shardings=(sh(st_specs), sh(b_specs)),
                  donate_argnums=(0,)).lower(state_shape, batch)
compiled = lowered.compile()
res = analyze(lowered, compiled, 8)
assert res["flops_per_device"] > 0

# compressed cross-pod collective: numerical check on real devices
x = jnp.stack([jnp.full((4, 128), float(i + 1)) for i in range(2)])
x = jax.device_put(x, NamedSharding(mesh, P("pod")))
out = cross_pod_psum_compressed(x, mesh)
np.testing.assert_allclose(np.asarray(out)[0], 3.0, rtol=1e-2)
np.testing.assert_allclose(np.asarray(out)[1], 3.0, rtol=1e-2)
print(json.dumps({"ok": True,
                  "coll": res["collectives"]["total_per_device_bytes"]}))
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    assert payload["ok"]
    assert payload["coll"] > 0, "train step must contain collectives"
