"""FIM estimation (variational + empirical), VD pruning rule, and the
lossless baseline coders (Huffman round-trip, CSR, bzip2, entropy)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import bzip2_size_bits, csr_huffman_size_bits, csr_streams
from repro.core.fim import (empirical_fisher_diag, variational_fim,
                            vd_sparsify)
from repro.core.huffman import (build_huffman, epmd_entropy_bits,
                                huffman_decode, huffman_encode,
                                huffman_payload_bits)


def _toy_problem():
    """Least squares where only the first feature matters."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 8)).astype(np.float32)
    w_true = np.zeros(8, np.float32)
    w_true[0] = 2.0
    y = x @ w_true
    params = {"w": jnp.asarray(w_true + 0.01 * rng.standard_normal(8),
                               jnp.float32)}

    def loss(p, batch):
        xb, yb = batch
        return jnp.mean(jnp.square(xb @ p["w"] - yb))

    batches = [(jnp.asarray(x[i::4]), jnp.asarray(y[i::4]))
               for i in range(4)]
    return params, loss, batches


def test_empirical_fisher_identifies_important_weight():
    params, loss, batches = _toy_problem()
    # perturb so gradients are informative
    params = {"w": params["w"] + 0.1}
    fim = empirical_fisher_diag(loss, params, batches)
    f = np.asarray(fim["w"])
    assert f[0] > 0 and np.all(np.isfinite(f))


def test_variational_fim_sigma_reflects_curvature():
    """Paper appendix B: sigma_i^2 ~ beta / H_i — high-curvature directions
    get small posterior std (F_i = 1/sigma_i^2 large)."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((512, 4)).astype(np.float32)
    x[:, 0] *= 10.0                  # 100x curvature on feature 0
    w_true = np.array([1.0, 1.0, 1.0, 0.0], np.float32)
    y = x @ w_true
    params = {"w": jnp.asarray(w_true + 0.01 * rng.standard_normal(4),
                               jnp.float32)}

    def loss(p, batch):
        xb, yb = batch
        return jnp.mean(jnp.square(xb @ p["w"] - yb))

    batches = [(jnp.asarray(x[i::4]), jnp.asarray(y[i::4]))
               for i in range(4)]
    res = variational_fim(loss, params, batches, steps=500, beta=1e-3,
                          lr=5e-3, seed=0)
    sigma = np.asarray(res.sigma["w"])
    assert sigma[0] < sigma[1] and sigma[0] < sigma[2], sigma
    # the pruning rule keeps the useful weights, drops the dead one
    pruned = np.asarray(vd_sparsify(res)["w"])
    assert pruned[0] != 0.0 and pruned[1] != 0.0


# -- lossless baselines ---------------------------------------------------------

def test_huffman_roundtrip_and_optimality():
    rng = np.random.default_rng(1)
    vals = (rng.standard_t(2, 5000) * 3).astype(np.int64)
    code = build_huffman(vals)
    enc = huffman_encode(vals, code)
    out = huffman_decode(enc, vals.size, code)
    assert np.array_equal(out, vals)
    h = epmd_entropy_bits(vals)
    payload = huffman_payload_bits(vals, code)
    assert h <= payload <= h + vals.size   # within 1 bit/symbol of entropy


def test_csr_streams_reconstructible():
    m = np.zeros((8, 64), dtype=np.int64)
    m[2, 5], m[2, 60], m[7, 0] = 3, -2, 9
    deltas, values, nrows = csr_streams(m)
    assert nrows == 8
    # padding symbols have value 0; real values survive
    assert set(values.tolist()) >= {3, -2, 9}


def test_csr_huffman_beats_dense_for_sparse():
    rng = np.random.default_rng(2)
    m = (rng.random((64, 512)) < 0.02).astype(np.int64) * \
        rng.integers(1, 15, (64, 512))
    sparse_bits = csr_huffman_size_bits(m)
    dense_bits = 8 * m.size            # int8 dense
    assert sparse_bits < dense_bits


def test_bzip2_size_positive():
    rng = np.random.default_rng(3)
    lv = (rng.standard_normal(10000) * 2).astype(np.int64)
    assert bzip2_size_bits(lv) > 0
