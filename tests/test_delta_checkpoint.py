"""Temporal delta ("P-frame") checkpoints: keyframe cadence, chain
restore bit-identity vs a direct step-locked encode (both CABAC
engines), elastic mesh restore of a chained step, chain-aware
retention / orphan protection, descriptive chain errors, and the live
weight swap into a running ServeSession."""

import json
import os
import shutil

import jax
import numpy as np
import pytest

from repro import compression
from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              DeltaBaseMissingError, delta)
from repro.checkpoint.delta import DeltaChainError
from repro.checkpoint import sharded
from repro.checkpoint.sharded import MeshSpec
from repro.configs import get_smoke_config
from repro.core.cabac_vec import resolve_backend
from repro.core.codec import DecodeOptions, QuantizedTensor
from repro.models.transformer import init_params
from repro.serve.backends import get_backend
from repro.serve.session import ServeConfig, ServeSession

# both entropy-coding engines must produce/consume identical chains;
# the C lanes kernel is optional per-platform
BACKENDS = ["numpy"] + (["c"] if resolve_backend("auto") == "c" else [])

# The smoke-model integration tests below decode full model containers;
# on the numpy lane engine that is ~100x slower than the C kernel and
# adds nothing (engine-level delta coverage is the backend-parametrized
# tests above, which force the numpy engine explicitly on small tensors).
skip_on_forced_numpy = pytest.mark.skipif(
    os.environ.get("REPRO_CABAC_BACKEND") == "numpy",
    reason="smoke-model decode is impractical on the forced numpy lane "
           "engine; delta coding on the numpy engine is covered by the "
           "backend-parametrized tests")


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer/kernel": rng.standard_normal((32, 16)).astype(np.float32),
            "layer/bias": rng.standard_normal(16).astype(np.float32)}


def _drift(flat, seed):
    """Multiplicative drift — the residual model one optimizer step away
    from the base produces (small relative change, zeros stay zero)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in flat.items():
        v = np.asarray(v)
        if v.dtype.kind == "f":
            out[k] = (v * (1 + 1e-4 * rng.standard_normal(v.shape))
                      ).astype(v.dtype)
        else:
            out[k] = v
    return out


def _mgr(tmp_path, name="ckpt", **kw):
    kw.setdefault("codec", "deepcabac-delta")
    return CheckpointManager(CheckpointConfig(
        os.path.join(str(tmp_path), name), **kw))


def _meta(mgr, step):
    with open(os.path.join(mgr.cfg.directory, f"step_{step:08d}",
                           "meta.json")) as f:
        return json.load(f)


def _save_drifting(mgr, steps, seed=0):
    flat = _tree(seed)
    for step in steps:
        mgr.save({"params": dict(flat), "opt": {"count": np.int32(step)}},
                 step)
        flat = _drift(flat, seed + step)
    return flat


# -- keyframe cadence --------------------------------------------------------

def test_keyframe_cadence_and_meta(tmp_path):
    mgr = _mgr(tmp_path, keep=10, delta_every=3)
    _save_drifting(mgr, range(1, 7))
    kinds = [_meta(mgr, s)["kind"] for s in range(1, 7)]
    depths = [_meta(mgr, s)["chain_depth"] for s in range(1, 7)]
    assert kinds == ["keyframe", "delta", "delta",
                     "keyframe", "delta", "delta"]
    assert depths == [0, 1, 2, 0, 1, 2]
    assert [_meta(mgr, s).get("base_step") for s in (2, 3, 5)] == [1, 2, 4]
    # P-frames of a drifting model must be much smaller than I-frames
    kf = _meta(mgr, 1)["params_compressed_bytes"]
    for s in (2, 3, 5, 6):
        assert _meta(mgr, s)["params_compressed_bytes"] < 0.5 * kf


def test_delta_every_zero_keeps_every_save_a_keyframe(tmp_path):
    mgr = _mgr(tmp_path, keep=4, delta_every=0)
    _save_drifting(mgr, (1, 2))
    for s in (1, 2):
        assert delta.base_step_of(
            os.path.join(mgr.cfg.directory, f"step_{s:08d}")) is None


# -- chain restore bit-identity ----------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_chain_restore_bit_identical_to_direct_encode(tmp_path, backend):
    """base + k chained P-frames == one direct step-locked encode of the
    last frame, in integer level space (zero drift across the chain)."""
    mgr = _mgr(tmp_path, keep=10, delta_every=4)
    _save_drifting(mgr, range(1, 4))

    codec = mgr._codec()
    frames = [_tree(0)]
    for step in (1, 2):
        frames.append(_drift(frames[-1], step))
    direct = codec.quantize_entries(frames[0])
    for f in frames[1:]:
        direct = codec.quantize_like(f, direct)

    got = delta.restore_levels(mgr.cfg.directory, 3,
                               opts=DecodeOptions(backend=backend))
    assert sorted(got) == sorted(direct)
    for k in direct:
        a, b = got[k], direct[k]
        if isinstance(b, QuantizedTensor):
            assert isinstance(a, QuantizedTensor), k
            assert a.step == b.step, k
            assert np.array_equal(a.levels, b.levels), k
        else:
            assert np.array_equal(a, np.asarray(b)), k


@pytest.mark.parametrize("backend", BACKENDS)
def test_manager_restore_matches_flat_chain_restore(tmp_path, backend):
    mgr = _mgr(tmp_path, keep=10, delta_every=3)
    _save_drifting(mgr, range(1, 6))
    state = {"params": _tree(0), "opt": {"count": np.int32(0)}}
    restored, meta = mgr.restore(state)
    assert meta["step"] == 5
    flat = delta.restore_flat_delta(mgr.cfg.directory, 5,
                                    opts=DecodeOptions(backend=backend))
    for k, v in flat.items():
        assert np.array_equal(v, np.asarray(restored["params"][k])), k


def test_cold_manager_resumes_chain_without_cache(tmp_path):
    """A restarted manager (empty base cache) must keep writing P-frames
    by rebuilding the base levels from disk — and identically so."""
    mgr = _mgr(tmp_path, keep=10, delta_every=4)
    flat = _save_drifting(mgr, range(1, 3))
    mgr2 = _mgr(tmp_path, keep=10, delta_every=4)
    mgr2.save({"params": flat, "opt": {"count": np.int32(3)}}, 3)
    m = _meta(mgr2, 3)
    assert m["kind"] == "delta"
    assert m["base_step"] == 2 and m["chain_depth"] == 2
    # and the chain still reconstructs
    chain = delta.resolve_chain(mgr2.cfg.directory, 3)
    assert [c["kind"] for c in chain] == ["keyframe", "delta", "delta"]
    delta.restore_levels(mgr2.cfg.directory, 3)


# -- retention / orphan protection -------------------------------------------

def test_retention_never_orphans_a_live_chain(tmp_path):
    mgr = _mgr(tmp_path, keep=2, delta_every=4)
    flat = _save_drifting(mgr, range(1, 5))
    # keep=2 -> {3, 4}, but both are P-frames chained to 1: everything
    # up the chain must survive GC
    assert mgr.steps() == [1, 2, 3, 4]
    delta.restore_flat_delta(mgr.cfg.directory, 4)
    # once the live window re-roots on the step-5 keyframe, the old
    # chain is collectable
    mgr.save({"params": flat, "opt": {"count": np.int32(5)}}, 5)
    flat = _drift(flat, 5)
    mgr.save({"params": flat, "opt": {"count": np.int32(6)}}, 6)
    assert _meta(mgr, 5)["kind"] == "keyframe"
    assert mgr.steps() == [5, 6]


def test_missing_base_raises_descriptive_error(tmp_path):
    mgr = _mgr(tmp_path, keep=10, delta_every=4)
    _save_drifting(mgr, range(1, 4))
    shutil.rmtree(os.path.join(mgr.cfg.directory, "step_00000001"))
    with pytest.raises(DeltaBaseMissingError, match="retention"):
        delta.restore_flat_delta(mgr.cfg.directory, 3)
    # and FileNotFoundError stays the catchable base class
    with pytest.raises(FileNotFoundError):
        delta.resolve_chain(mgr.cfg.directory, 3)


def test_rewritten_base_raises_chain_error(tmp_path):
    mgr = _mgr(tmp_path, keep=10, delta_every=4)
    _save_drifting(mgr, range(1, 3))
    base_payload = os.path.join(mgr.cfg.directory, "step_00000001",
                                "params.dcbc")
    with open(base_payload, "ab") as f:
        f.write(b"\x00")
    with pytest.raises(DeltaChainError, match="rewritten"):
        delta.resolve_chain(mgr.cfg.directory, 2)


def test_sharded_restore_helpers_reject_delta_manifests(tmp_path):
    mgr = _mgr(tmp_path, keep=10, delta_every=4)
    _save_drifting(mgr, range(1, 3))
    d = os.path.join(mgr.cfg.directory, "step_00000002")
    mesh = MeshSpec.from_any({"data": 1})
    for call in (lambda: sharded.restore_flat(d),
                 lambda: sharded.restore_on_mesh(d, mesh),
                 lambda: sharded.restore_local_slices(d, mesh, [0])):
        with pytest.raises(ValueError, match="P-frame"):
            call()


# -- sharded keyframe + mesh restore of a chained step -----------------------

def _model_state(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    return {"params": params, "opt": {"count": np.int32(0)}}


@skip_on_forced_numpy
def test_delta_chain_restores_across_mesh_reshape(tmp_path):
    """Keyframe written sharded over a 2-way mesh, P-frame on top; the
    chain must restore onto a different (1x1) jax mesh bit-identically
    to the host-flat chain restore."""
    cfg = get_smoke_config("llama3-8b")
    state = _model_state(cfg)
    mgr = _mgr(tmp_path, keep=4, delta_every=4, sharded=True,
               shard_workers=2)
    mgr.save(state, 1, mesh=MeshSpec(("data", "model"), (2, 1)))
    flat = dict(compression.flatten_tree(jax.device_get(state["params"])))
    pert = _drift(flat, 1)
    state2 = {"params": compression.unflatten_like(pert, state["params"]),
              "opt": {"count": np.int32(1)}}
    mgr.save(state2, 2)
    assert _meta(mgr, 2)["kind"] == "delta"

    ref = delta.restore_flat_delta(mgr.cfg.directory, 2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    on_mesh = delta.restore_on_mesh_delta(mgr.cfg.directory, 2, mesh)
    assert sorted(on_mesh) == sorted(ref)
    for k, arr in on_mesh.items():
        assert isinstance(arr, jax.Array), k
        np.testing.assert_array_equal(np.asarray(arr), ref[k], err_msg=k)

    # the manager's own restore resolves the chain too
    restored, meta = mgr.restore(state)
    rflat = dict(compression.flatten_tree(jax.device_get(
        restored["params"])))
    for k, v in ref.items():
        assert np.array_equal(v, np.asarray(rflat[k])), k


# -- live weight swap into serving -------------------------------------------

@skip_on_forced_numpy
def test_swap_weights_bitwise_equals_cold_start_with_inflight(tmp_path):
    cfg = get_smoke_config("llama3-8b")
    state = _model_state(cfg)
    mgr = _mgr(tmp_path, keep=4, delta_every=4)
    mgr.save(state, 1)
    flat = dict(compression.flatten_tree(jax.device_get(state["params"])))
    pert = _drift(flat, 7)
    mgr.save({"params": compression.unflatten_like(pert, state["params"]),
              "opt": {"count": np.int32(1)}}, 2)
    kf_dir = os.path.join(mgr.cfg.directory, "step_00000001")
    delta_dir = os.path.join(mgr.cfg.directory, "step_00000002")
    with open(os.path.join(kf_dir, "params.dcbc"), "rb") as f:
        kf_blob = f.read()

    backend = get_backend("container", track_levels=True)
    session = ServeSession(cfg, kf_blob, backend=backend,
                           serve_cfg=ServeConfig(slots=2, max_len=32))
    h = session.submit(np.arange(5, dtype=np.int32), max_new_tokens=8)
    session.step()
    session.step()
    pre_swap = list(h.tokens)
    n = session.swap_weights(delta_dir)
    assert n > 0
    session.run()
    assert h.done
    assert list(h.tokens)[:len(pre_swap)] == pre_swap

    # swapped-in weights must be bitwise what a cold start from the
    # direct step-locked encode of the new frame would load
    codec = mgr._codec()
    base_entries = codec.compress(flat).quantized
    ref_blob = codec.compress_entries(
        codec.quantize_like(pert, base_entries)).blob
    cold = ServeSession(cfg, ref_blob, backend="container",
                        serve_cfg=ServeConfig(slots=2, max_len=32))
    fa = compression.flatten_tree(session.params)
    fb = compression.flatten_tree(cold.params)
    assert sorted(fa) == sorted(fb)
    for k in fa:
        a, b = np.asarray(fa[k]), np.asarray(fb[k])
        assert a.dtype == b.dtype and np.array_equal(a, b), k


@skip_on_forced_numpy
def test_swap_weights_error_paths(tmp_path):
    cfg = get_smoke_config("llama3-8b")
    state = _model_state(cfg)
    mgr = _mgr(tmp_path, keep=4, delta_every=4)
    mgr.save(state, 1)
    flat = dict(compression.flatten_tree(jax.device_get(state["params"])))
    mgr.save({"params": compression.unflatten_like(_drift(flat, 3),
                                                   state["params"]),
              "opt": {"count": np.int32(1)}}, 2)
    kf_dir = os.path.join(mgr.cfg.directory, "step_00000001")
    delta_dir = os.path.join(mgr.cfg.directory, "step_00000002")

    # a backend that never tracked levels cannot patch in residuals
    with pytest.raises(RuntimeError, match="track_levels"):
        get_backend("container").apply_delta(cfg, delta_dir)
    # a keyframe step is not a delta
    backend = get_backend("container", track_levels=True)
    with open(os.path.join(kf_dir, "params.dcbc"), "rb") as f:
        backend.load(cfg, f.read())
    with pytest.raises(ValueError, match="not a delta"):
        backend.apply_delta(cfg, kf_dir)
