"""Compressed-resident serving: every matmul from q8 tiles.

Covers the fused q8 forward/decode path (gqa + mla attention, MoE expert
dispatch, tied/untied heads, ragged decode batch sizes) against the
dequantize-then-dense reference with tolerance pins, the grouped-expert
kernel against its oracle, the tile-clamp regression (cached/explicit
tiles larger than the padded operand must clamp + report, never crash),
and the dispatch_report() contract: decode shapes *route* (no fallback
records) on both the default and interpret impls, eligible tensors never
hit the loop-body dequant, ineligible ones report it once.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, kernels
from repro.kernels.dequant_matmul.ops import (default_tiles, dequant_matmul,
                                              dequant_matmul_grouped,
                                              tile_bounds)
from repro.kernels.dequant_matmul.ref import (dequant_matmul_grouped_ref,
                                              dequant_matmul_ref)
from repro.models import transformer
from repro.serve.quantized import (dequant_tree, is_q8,
                                   quantize_params_for_serving)

INTERP = kernels.KernelPolicy().override(
    "dequant_matmul", "interpret").override(
    "dequant_matmul_grouped", "interpret")


def _quantized(name):
    cfg = configs.get(name, smoke=True)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params_for_serving(params)
    dp = dequant_tree(qp, jnp.dtype(cfg.compute_dtype))
    return cfg, qp, dp


# ---------------------------------------------------------------------------
# grouped kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scale_shape", ["per_expert", "shared"])
def test_grouped_kernel_matches_ref(scale_shape):
    rng = np.random.default_rng(7)
    e, m, k, n = 4, 8, 160, 96
    x = jnp.asarray(rng.standard_normal((e, m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (e, k, n)), jnp.int8)
    sc = jnp.asarray(rng.random((e, n) if scale_shape == "per_expert"
                                else (n,)) * 0.01 + 1e-4, jnp.float32)
    want = np.asarray(dequant_matmul_grouped_ref(x, wq, sc))
    # interpret-mode pallas and the registry default (ref on cpu)
    got_i = np.asarray(dequant_matmul_grouped(x, wq, sc, interpret=True))
    got_d = np.asarray(kernels.get("dequant_matmul_grouped")(x, wq, sc))
    np.testing.assert_allclose(got_i, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got_d, want, atol=1e-4, rtol=1e-4)


def test_grouped_registry_routes_without_fallback():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 8, 128)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (2, 128, 128)), jnp.int8)
    sc = jnp.asarray(rng.random((2, 128)) * 0.01, jnp.float32)
    op = kernels.get("dequant_matmul_grouped")
    for pol in (kernels.KernelPolicy(), INTERP):
        plan = op.plan(x, wq, sc, policy=pol)
        assert plan.fallback_reason is None
    kernels.clear_dispatch_report()
    op(x, wq, sc, policy=INTERP)
    assert [r for r in kernels.dispatch_report()
            if r.get("kind") == "fallback"] == []


def test_batched_activation_flattening():
    """(B, S, K) activations flatten to the kernel's M and reshape back."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((2, 3, 160)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (160, 96)), jnp.int8)
    sc = jnp.asarray(rng.random(96) * 0.01 + 1e-4, jnp.float32)
    want = np.asarray(dequant_matmul_ref(x.reshape(6, 160), wq, sc)
                      ).reshape(2, 3, 96)
    got = np.asarray(dequant_matmul(x, wq, sc, interpret=True))
    assert got.shape == (2, 3, 96)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# tile clamp (regression: `bm or tiles["bm"]` + pow2-bucket cache winners)
# ---------------------------------------------------------------------------

def test_tile_clamp_oversized_explicit_tiles():
    """A cached winner for bucket m=64 applied verbatim to an m=3 decode
    batch must clamp to the padded operand — and say so — not crash or
    pad the batch 8x."""
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((3, 160)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (160, 96)), jnp.int8)
    sc = jnp.asarray(rng.random(96) * 0.01 + 1e-4, jnp.float32)
    want = np.asarray(dequant_matmul_ref(x, wq, sc))
    kernels.clear_dispatch_report()
    got = np.asarray(dequant_matmul(x, wq, sc, bm=64, bk=1024,
                                    interpret=True))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    (rec,) = [r for r in kernels.dispatch_report()
              if r.get("kind") == "tile_clamp"]
    assert rec["op"] == "dequant_matmul"
    assert "bm=64->8" in rec["reason"] and "bk=1024->256" in rec["reason"]


def test_tile_clamp_through_policy_tiles():
    """Policy tile pins (the same slot the tuning cache feeds) clamp at
    dispatch too."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((3, 160)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (160, 96)), jnp.int8)
    sc = jnp.asarray(rng.random(96) * 0.01 + 1e-4, jnp.float32)
    pol = INTERP.with_tiles("dequant_matmul", bm=256, bn=512, bk=1024)
    kernels.clear_dispatch_report()
    got = np.asarray(kernels.get("dequant_matmul")(x, wq, sc, policy=pol))
    np.testing.assert_allclose(got, np.asarray(dequant_matmul_ref(x, wq, sc)),
                               atol=1e-4, rtol=1e-4)
    assert any(r.get("kind") == "tile_clamp"
               for r in kernels.dispatch_report())


def test_tile_bounds_cap_default_tiles():
    b = tile_bounds(3, 160, 96)
    assert b == {"bm": 8, "bn": 128, "bk": 256}
    t = default_tiles(3, 160, 96)
    assert all(t[p] <= b[p] for p in t)
    g = dequant_matmul_grouped(
        jnp.zeros((2, 3, 160), jnp.float32),
        jnp.zeros((2, 160, 96), jnp.int8),
        jnp.ones((96,), jnp.float32), bm=128, interpret=True)
    assert g.shape == (2, 3, 96)


# ---------------------------------------------------------------------------
# fused-q8 vs dequantized-dense equivalence sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3-8b",          # gqa, untied head
                                  "deepseek-v3-671b",   # mla + moe + shared
                                  "deepseek-moe-16b"])  # gqa + moe + shared
def test_forward_equivalence(arch):
    cfg, qp, dp = _quantized(arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    transformer._reported_loop_dequant.clear()
    kernels.clear_dispatch_report()
    lo_q, _, _ = transformer.forward(qp, cfg, tokens=toks)
    lo_r, _, _ = transformer.forward(dp, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(lo_q), np.asarray(lo_r),
                               atol=2e-5, rtol=2e-5)
    assert bool(jnp.all(jnp.argmax(lo_q, -1) == jnp.argmax(lo_r, -1)))
    # every projection routed: no constraint fallbacks, no loop dequant
    recs = kernels.dispatch_report()
    assert [r for r in recs if r.get("kind") == "fallback"
            and r["op"].startswith("dequant_matmul")] == []
    assert [r for r in recs if r.get("kind") == "loop_dequant"] == []


@pytest.mark.parametrize("bsz", [1, 3, 5])
def test_ragged_decode_identity(bsz):
    """Greedy decode from q8-resident weights is token-identical to the
    dequantized-dense path across ragged decode batch sizes."""
    cfg, qp, dp = _quantized("llama3-8b")
    toks = jax.random.randint(jax.random.PRNGKey(2), (bsz, 6), 0,
                              cfg.vocab_size)
    outs = []
    for p in (qp, dp):
        lo, caches = transformer.prefill(p, cfg, tokens=toks, max_len=12)
        seq = [jnp.argmax(lo, -1)]
        pos = jnp.full((bsz,), 6, jnp.int32)
        for _ in range(3):
            lo, caches = transformer.decode_step(p, cfg, caches, pos,
                                                 tokens=seq[-1])
            seq.append(jnp.argmax(lo, -1))
            pos = pos + 1
        outs.append(np.asarray(jnp.stack(seq)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_decode_shapes_route_not_fallback():
    """Decode-row shapes resolve cleanly on both the platform default and
    the pallas-interpret impl — routing, not constraint fallback."""
    rng = np.random.default_rng(12)
    wq = jnp.asarray(rng.integers(-127, 127, (128, 256)), jnp.int8)
    sc = jnp.asarray(rng.random(256) * 0.01 + 1e-4, jnp.float32)
    op = kernels.get("dequant_matmul")
    for m in (1, 3, 5, 8):
        x = jnp.asarray(rng.standard_normal((m, 128)), jnp.float32)
        for pol in (kernels.KernelPolicy(), INTERP):
            plan = op.plan(x, wq, sc, policy=pol)
            assert plan.fallback_reason is None
            got = np.asarray(op(x, wq, sc, policy=pol))
            np.testing.assert_allclose(
                got, np.asarray(dequant_matmul_ref(x, wq, sc)),
                atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# loop-body dequant: explicit, reported once, never for eligible tensors
# ---------------------------------------------------------------------------

def test_tied_head_fallback_reported_once():
    cfg = configs.get("llama3-8b", smoke=True).replace(tie_embeddings=True)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params_for_serving(params)
    dp = dequant_tree(qp, jnp.dtype(cfg.compute_dtype))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                              cfg.vocab_size)
    transformer._reported_loop_dequant.clear()
    kernels.clear_dispatch_report()
    lo_q, _, _ = transformer.forward(qp, cfg, tokens=toks)
    lo_r, _, _ = transformer.forward(dp, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(lo_q), np.asarray(lo_r),
                               atol=2e-5, rtol=2e-5)
    recs = [r for r in kernels.dispatch_report()
            if r.get("kind") == "loop_dequant"]
    assert len(recs) == 1 and "tied" in recs[0]["reason"]
    # reported once per tensor, not once per compile/step
    transformer.forward(qp, cfg, tokens=toks)
    assert len([r for r in kernels.dispatch_report()
                if r.get("kind") == "loop_dequant"]) == 1


def test_ineligible_ssm_tensors_report_loop_dequant():
    cfg, qp, dp = _quantized("mamba2-2.7b")
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0,
                              cfg.vocab_size)
    transformer._reported_loop_dequant.clear()
    kernels.clear_dispatch_report()
    lo_q, _, _ = transformer.forward(qp, cfg, tokens=toks)
    lo_r, _, _ = transformer.forward(dp, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(lo_q), np.asarray(lo_r),
                               atol=2e-5, rtol=2e-5)
    recs = [r for r in kernels.dispatch_report()
            if r.get("kind") == "loop_dequant"]
    names = {r["reason"].split(":", 1)[0] for r in recs}
    assert names, "ssm mixer tensors must report their loop-body dequant"
    # the eligible set never hits the loop-body path
    assert not names & transformer._FUSED_ELIGIBLE
