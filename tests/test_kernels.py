"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle vs
numpy reference."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import nearest_level, rd_assign
from repro.core.rate_model import build_rate_table, estimate_bin_probs
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.dequant_matmul.ref import dequant_matmul_ref
from repro.kernels.rd_quant import rd_quant


def _weights(seed, n, sparsity=0.5, dtype=np.float32):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal(n) * 0.05).astype(dtype)
    w[rng.random(n) < sparsity] = 0
    return w


@pytest.mark.parametrize("n", [100, 4096, 262144 + 17])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_rd_quant_kernel_vs_oracle(n, dtype):
    w = _weights(n, n, dtype=dtype)
    step, lam = 0.008, 2e-4
    nn = nearest_level(w, step)
    probs = estimate_bin_probs(nn)
    max_level = int(np.abs(nn).max()) + 8
    table = build_rate_table(probs, max_level)
    oracle = rd_assign(w.astype(np.float64), None, step, lam, table,
                       window=4, max_level=max_level, passes=2)
    pallas = np.asarray(rd_quant(w, None, probs, step=step, lam=lam,
                                 window=4, max_level=max_level, passes=2,
                                 interpret=True))
    ref = np.asarray(rd_quant(w, None, probs, step=step, lam=lam,
                              window=4, max_level=max_level, passes=2,
                              use_ref=True))
    assert np.array_equal(pallas, ref), "pallas must match jnp ref exactly"
    agree = np.mean(pallas == oracle)
    assert agree > 0.999, f"kernel vs numpy oracle agreement {agree}"


@pytest.mark.parametrize("window", [1, 2, 6])
def test_rd_quant_windows(window):
    w = _weights(11, 20000)
    step = 0.01
    nn = nearest_level(w, step)
    probs = estimate_bin_probs(nn)
    out = np.asarray(rd_quant(w, None, probs, step=step, lam=1e-4,
                              window=window, interpret=True))
    # candidates are NN +- window plus the zero level (large-lambda escape)
    within = np.abs(out - nn) <= window
    assert np.all(within | (out == 0))


def test_rd_quant_fisher():
    w = _weights(12, 30000)
    fisher = np.ones(30000)
    fisher[:15000] = 1e5
    step = 0.01
    nn = nearest_level(w, step)
    probs = estimate_bin_probs(nn)
    out = np.asarray(rd_quant(w, fisher, probs, step=step, lam=1e-2,
                              interpret=True))
    hi = np.mean((w[:15000] - out[:15000] * step) ** 2)
    lo = np.mean((w[15000:] - out[15000:] * step) ** 2)
    assert hi < lo


@pytest.mark.parametrize("m,k,n", [(8, 512, 256), (130, 1024, 300),
                                   (256, 2048, 512), (1, 512, 512)])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_sweep(m, k, n, xdtype):
    rng = np.random.default_rng(m * 7 + n)
    x = jnp.asarray(rng.standard_normal((m, k)), xdtype)
    wq = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.01, jnp.float32)
    ref = np.asarray(dequant_matmul_ref(x, wq, sc))
    out = np.asarray(dequant_matmul(x, wq, sc, interpret=True))
    tol = 2e-4 if xdtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * np.abs(ref).max())


def test_dequant_matmul_matches_dequantized_dense():
    """Fixed-point path == dequantize-then-matmul (paper §III-C-1)."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((64, 256)).astype(np.float32)
    w = (rng.standard_normal((256, 128)) * 0.04).astype(np.float32)
    step = 0.002
    wq = np.clip(np.rint(w / step), -127, 127).astype(np.int8)
    sc = np.full(128, step, np.float32)
    dense = x @ (wq.astype(np.float32) * step)
    out = np.asarray(dequant_matmul(x, wq, sc, interpret=True))
    np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("step,lam", [(0.004, 1e-5), (0.008, 2e-4),
                                      (0.016, 1e-3)])
def test_rd_quant_kernel_vs_rd_assign_grid(step, lam):
    """Differential pin over a lambda/step grid: the interpret-mode kernel
    and the core.quant.rd_assign numpy oracle can't drift."""
    w = _weights(int(step * 1e4) + int(lam * 1e6), 20000)
    nn = nearest_level(w, step)
    probs = estimate_bin_probs(nn)
    max_level = int(np.abs(nn).max()) + 8
    table = build_rate_table(probs, max_level)
    oracle = rd_assign(w.astype(np.float64), None, step, lam, table,
                       window=4, max_level=max_level, passes=2)
    kern = np.asarray(rd_quant(w, None, probs, step=step, lam=lam,
                               window=4, max_level=max_level, passes=2,
                               interpret=True))
    agree = np.mean(kern == oracle)
    assert agree > 0.999, \
        f"kernel vs rd_assign agreement {agree} at step={step} lam={lam}"
    # distortion sanity: chosen levels never leave the clip range
    assert np.abs(kern).max() <= max_level


def test_dequant_matmul_adaptive_bm_matches_fixed():
    """Tile choice must not change the math: decode-clamped bm == old
    fixed bm=256 result."""
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((4, 384)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (384, 256)), jnp.int8)
    sc = jnp.asarray(rng.random(256) * 0.01, jnp.float32)
    small = np.asarray(dequant_matmul(x, wq, sc, interpret=True))   # bm=8
    fixed = np.asarray(dequant_matmul(x, wq, sc, bm=256, bn=256, bk=384,
                                      interpret=True))
    np.testing.assert_allclose(small, fixed, rtol=1e-5, atol=1e-5)
