"""Quantizer invariants: uniform, weighted Lloyd, RD assignment, rate model."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.cabac import RangeEncoder
from repro.core import binarization as B
from repro.core.quant import (assign_nearest, nearest_level, rd_assign,
                              uniform_quantize, weighted_lloyd)
from repro.core.rate_model import (build_rate_table, estimate_bin_probs,
                                   level_rates)


def _sparse_weights(seed=0, n=20000, sparsity=0.6, scale=0.05):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(n) * scale
    w[rng.random(n) < sparsity] = 0.0
    return w


def _actual_bits(levels):
    enc = RangeEncoder(B.make_contexts())
    B.encode_levels(enc, levels)
    return 8 * len(enc.finish())


def test_uniform_keeps_zero_center():
    w = _sparse_weights()
    a, centers = uniform_quantize(w, 64)
    assert 0.0 in centers
    # zeros stay exactly zero
    assert np.all(centers[a[w == 0.0]] == 0.0)


def test_uniform_idempotent():
    w = _sparse_weights()
    a, centers = uniform_quantize(w, 32)
    q = centers[a]
    a2 = assign_nearest(q, centers)
    assert np.array_equal(a, a2)


def test_lloyd_objective_decreases():
    w = _sparse_weights(1)
    res = weighted_lloyd(w, None, 16, lam=0.01, iters=20)
    obj = res.objective
    assert all(obj[i + 1] <= obj[i] * (1 + 1e-9) for i in range(len(obj) - 1))


def test_lloyd_importance_pulls_centers():
    rng = np.random.default_rng(2)
    w = np.concatenate([rng.normal(1.0, 0.01, 1000),
                        rng.normal(-1.0, 0.01, 1000)])
    f = np.concatenate([np.full(1000, 100.0), np.full(1000, 1e-4)])
    res = weighted_lloyd(w, f, 3, lam=0.0, iters=30, ensure_zero=False)
    # a center must sit near the high-importance cluster
    assert np.min(np.abs(res.centers - 1.0)) < 0.05


def test_rd_lambda_zero_is_nearest_neighbour():
    w = _sparse_weights(3)
    step = 0.01
    nn = nearest_level(w, step)
    table = build_rate_table(estimate_bin_probs(nn), int(np.abs(nn).max()) + 8)
    lv = rd_assign(w, None, step, 0.0, table)
    assert np.array_equal(lv, nn)


def test_rd_rate_monotone_in_lambda():
    w = _sparse_weights(4)
    step = 0.008
    nn = nearest_level(w, step)
    table = build_rate_table(estimate_bin_probs(nn), int(np.abs(nn).max()) + 8)
    rates, dists = [], []
    for lam in [0.0, 1e-5, 1e-4, 1e-3, 1e-2]:
        lv = rd_assign(w, None, step, lam, table)
        rates.append(_actual_bits(lv))
        dists.append(float(np.mean((w - lv * step) ** 2)))
    # the RD objective guarantees monotonicity of the *estimated* rate (the
    # static table it optimizes); actual adaptive-coder bits track it up to
    # the rate-model mismatch at large lambda, where the assignment shifts
    # the distribution away from the NN statistics the table was built from
    # (the paper's Fig.-5 outer loop re-evaluates per (Delta, lambda))
    assert all(rates[i + 1] <= rates[i] * 1.15 + 64
               for i in range(len(rates) - 1))
    assert min(rates) < rates[0] * 0.75
    assert dists[-1] >= dists[0]


def test_rd_fisher_protects_important_weights():
    rng = np.random.default_rng(5)
    w = rng.standard_normal(4000) * 0.03
    fisher = np.ones(4000)
    fisher[:2000] = 1e4            # first half is important
    step = 0.01
    nn = nearest_level(w, step)
    table = build_rate_table(estimate_bin_probs(nn), int(np.abs(nn).max()) + 8)
    lv = rd_assign(w, fisher, step, 1e-2, table)
    err_hi = np.mean((w[:2000] - lv[:2000] * step) ** 2)
    err_lo = np.mean((w[2000:] - lv[2000:] * step) ** 2)
    assert err_hi < err_lo


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31), st.floats(0.002, 0.05))
def test_rate_model_matches_coder(seed, step):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(8000) * 0.05
    w[rng.random(8000) < 0.5] = 0
    nn = nearest_level(w, step)
    probs = estimate_bin_probs(nn)
    sig = nn != 0
    prev = np.concatenate([[0], sig[:-1].astype(int)])
    table = build_rate_table(probs, int(np.abs(nn).max()) + 2)
    est = table.lookup(nn, prev).sum()
    actual = _actual_bits(nn)
    assert abs(actual - est) / max(actual, 1) < 0.08


def test_level_rates_match_binarize_cost():
    """Closed-form vectorized rates == per-value bin-walk costs."""
    rng = np.random.default_rng(7)
    lv = (rng.standard_t(2, 500) * 50).astype(np.int64)
    probs = estimate_bin_probs(lv)
    vec = level_rates(lv, probs, prev_sig=0)
    import math
    for i, v in enumerate(lv.tolist()):
        cost = 0.0
        for ctx, bit in B.binarize_value(int(v), probs.num_gr, prev_sig=0):
            if ctx == -1:
                cost += 1.0
                continue
            if ctx in (0, 1):
                p1 = probs.p_sig[ctx]
            elif ctx == B.CTX_SIGN:
                p1 = probs.p_sign
            elif B.CTX_GR_BASE <= ctx < B.CTX_GR_BASE + probs.num_gr:
                p1 = probs.p_gr[ctx - B.CTX_GR_BASE]
            else:
                p1 = probs.p_eg[ctx - B.ctx_eg_base(probs.num_gr)]
            cost += -math.log2(p1 if bit else 1 - p1)
        assert abs(cost - vec[i]) < 1e-6, (v, cost, vec[i])
