"""Differential tests: lane-parallel CABAC vs the scalar coder, per lane.

Every decode (and encode) the vectorized engines produce is cross-checked
bit-exact against ``RangeEncoder``/``RangeDecoder`` — including a
randomized adaptation-trajectory test that drives the raw lockstep bin
coder over arbitrary context schedules and compares the full context
banks afterwards.  Both backends (numpy lockstep, compiled C lane kernel
when a toolchain exists) run the same assertions.
"""

import numpy as np
import pytest

from repro.core import binarization as B
from repro.core import cabac_vec as V
from repro.core.cabac import ContextSet, RangeDecoder, RangeEncoder
from repro.core.codec import (DecodeOptions, decode_level_chunks,
                              decode_level_chunks_batched,
                              encode_level_chunks,
                              encode_level_chunks_batched)

BACKENDS = V.available_backends()


def _scalar_payloads(lanes, num_gr):
    out = []
    for lv in lanes:
        enc = RangeEncoder(B.make_contexts(num_gr))
        B.encode_levels(enc, np.asarray(lv, dtype=np.int64), num_gr)
        out.append(enc.finish())
    return out


def _ragged_lanes(seed: int):
    rng = np.random.default_rng(seed)
    lanes = [
        np.zeros(64, np.int64),                                  # all-zero
        np.array([], np.int64),                                  # empty
        np.array([5], np.int64),                                 # 1 element
        (rng.standard_t(2, 257) * 3).astype(np.int64),           # heavy tail
        (rng.standard_t(2, 100) * 2000).astype(np.int64),        # big levels
        np.array([0, 0, 1 << 40, 0, -(1 << 40), 7], np.int64),   # wide spike
        rng.integers(-1, 2, 513).astype(np.int64),               # dense +-1
    ]
    return lanes


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("num_gr", [1, 5, 10])
def test_decode_lanes_bit_exact_vs_scalar(backend, num_gr):
    lanes = _ragged_lanes(seed=num_gr)
    payloads = _scalar_payloads(lanes, num_gr)
    got = V.decode_lanes(payloads, [len(l) for l in lanes], num_gr,
                         backend=backend)
    for i, (g, ref) in enumerate(zip(got, lanes)):
        assert np.array_equal(g, ref), f"{backend} lane {i} diverged"


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("num_gr", [1, 10])
def test_encode_lanes_byte_exact_vs_scalar(backend, num_gr):
    lanes = _ragged_lanes(seed=17 + num_gr)
    ref = _scalar_payloads(lanes, num_gr)
    got = V.encode_lanes(lanes, num_gr, backend=backend)
    for i, (g, r) in enumerate(zip(got, ref)):
        assert g == r, f"{backend} lane {i}: {g.hex()} != {r.hex()}"


@pytest.mark.parametrize("backend", BACKENDS)
def test_cross_engine_interop(backend):
    # scalar encode -> vec decode and vec encode -> scalar decode
    rng = np.random.default_rng(3)
    lv = (rng.standard_t(2, 300) * 5).astype(np.int64)
    vec_payload = V.encode_lanes([lv], backend=backend)[0]
    dec = RangeDecoder(vec_payload, B.make_contexts(10))
    assert np.array_equal(B.decode_levels(dec, lv.size, 10), lv)


def test_adaptation_trajectory_lockstep_vs_scalar():
    """Random context schedules (with bypass bins mixed in) through the raw
    lockstep bin coder: bits and the full per-lane context banks must track
    the scalar coder exactly at every adaptation step."""
    rng = np.random.default_rng(11)
    nctx = 7
    n_lanes, n_bins = 9, 400
    schedules = []
    for lane in range(n_lanes):
        ctx = rng.integers(0, nctx, n_bins)
        byp = rng.random(n_bins) < 0.25
        # skew bits per context so the banks adapt away from PROB_HALF
        bits = (rng.random(n_bins) < (0.1 + 0.8 * (ctx % 3) / 2)).astype(int)
        schedules.append((ctx, byp, bits))

    payloads, scalar_banks = [], []
    for ctx, byp, bits in schedules:
        cs = ContextSet(nctx)
        enc = RangeEncoder(cs)
        for c, bp, b in zip(ctx, byp, bits):
            if bp:
                enc.encode_bypass(int(b))
            else:
                enc.encode_bin(int(c), int(b))
        payloads.append(enc.finish())
        scalar_banks.append(list(cs.probs))

    vdec = V.VecRangeDecoder(payloads, nctx)
    sdecs = [RangeDecoder(p, ContextSet(nctx)) for p in payloads]
    for t in range(n_bins):
        ctx_t = np.asarray([s[0][t] for s in schedules], dtype=np.int64)
        byp_t = np.asarray([s[1][t] for s in schedules], dtype=bool)
        got = vdec.decode_bins(ctx_t, byp_t)
        for lane, sdec in enumerate(sdecs):
            want = (sdec.decode_bypass() if byp_t[lane]
                    else sdec.decode_bin(int(ctx_t[lane])))
            assert got[lane] == want == schedules[lane][2][t], \
                f"lane {lane} bin {t}"
        # bank must agree with each scalar decoder at every step
        bank = vdec.bank_snapshot()
        for lane, sdec in enumerate(sdecs):
            assert bank[lane].tolist() == sdec.ctx.probs, \
                f"lane {lane} bank diverged at bin {t}"
    # ... and with the encoder-side banks after the full trajectory
    for lane in range(n_lanes):
        assert vdec.bank_snapshot()[lane].tolist() == scalar_banks[lane]


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_chunk_api_matches_serial(backend):
    rng = np.random.default_rng(5)
    lv = (rng.standard_t(2, 5000) * 4).astype(np.int64)
    for chunk in (64, 1000, 8192):
        ref_chunks = encode_level_chunks(lv, 10, chunk)
        chunks, counts = encode_level_chunks_batched(lv, 10, chunk,
                                                     backend=backend)
        assert chunks == ref_chunks
        assert sum(counts) == lv.size
        ref = decode_level_chunks(ref_chunks, lv.size, 10, chunk)
        for lanes in (1, 3, 64):
            got = decode_level_chunks_batched(
                chunks, counts, 10,
                DecodeOptions(lanes=lanes, backend=backend))
            assert np.array_equal(got, ref)


def test_scalar_worker_pool_matches_serial():
    rng = np.random.default_rng(7)
    lv = (rng.standard_t(2, 2000) * 3).astype(np.int64)
    chunks, counts = encode_level_chunks_batched(lv, 10, 256)
    ref = decode_level_chunks_batched(chunks, counts, 10,
                                      DecodeOptions(backend="scalar"))
    pooled = decode_level_chunks_batched(
        chunks, counts, 10,
        DecodeOptions(backend="scalar", workers=2, pool="thread"))
    assert np.array_equal(pooled, ref)
    assert np.array_equal(ref, lv)


def test_encode_lanes_rejects_overflowing_levels():
    with pytest.raises(OverflowError):
        V.encode_lanes([np.array([1 << 62], dtype=np.int64)])


@pytest.mark.parametrize("backend", BACKENDS)
def test_decode_lanes_refuses_overflowing_streams(backend):
    # the arbitrary-precision scalar coder legally writes levels the lane
    # engines cannot represent; lane decode must refuse, never wrap int64
    wide = np.array([0, 3, 1 << 62, -5], dtype=np.int64)
    payloads = _scalar_payloads([wide], 10)
    with pytest.raises(OverflowError):
        V.decode_lanes(payloads, [wide.size], 10, backend=backend)


def test_batched_decode_falls_back_to_scalar_on_wide_v1_records():
    # regression: a v1 blob with beyond-lane-range levels must decode
    # exactly through every batched entry point (scalar fallback), incl.
    # the CheckpointManager.restore path (decompress(batched=True))
    from repro.core.codec import (QuantizedTensor, decode_state_dict_batched,
                                  encode_state_dict)
    wide = np.array([1 << 62, 0, -(1 << 62), 7, -1], dtype=np.int64)
    blob = encode_state_dict({"t": QuantizedTensor(wide, 1.0)}, chunk_size=2)
    for workers in (0, 2):
        out = decode_state_dict_batched(
            blob, dequantize=False,
            opts=DecodeOptions(workers=workers))["t"]
        assert np.array_equal(out.levels, wide)


def test_default_lanes_rereads_environment(monkeypatch):
    monkeypatch.setenv("REPRO_CABAC_LANES", "512")
    assert DecodeOptions().lanes == 512
    monkeypatch.delenv("REPRO_CABAC_LANES")
    assert DecodeOptions().lanes == 64


def test_backend_resolution():
    assert V.resolve_backend("auto") in ("c", "numpy")
    assert V.resolve_backend("numpy") == "numpy"
    with pytest.raises(ValueError):
        V.resolve_backend("fpga")
