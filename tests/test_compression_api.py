"""repro.compression Codec API: registry round-trips across codecs and
dtypes, mixed quantized/raw trees, shared q8 primitives, and a regression
check that CheckpointManager through the codec stays bit-identical to the
pre-refactor encode path."""

import jax
import ml_dtypes
import numpy as np
import pytest

from repro import compression
from repro.core.codec import (Q8Tensor, QuantizedTensor,
                              compressed_size_report, encode_state_dict,
                              resolve_dtype)
from repro.core.quant import nearest_level

CODECS = ["ckpt-nearest", "deepcabac-v2", "huffman", "raw", "serve-q8"]
DTYPES = [np.float32, np.float16, ml_dtypes.bfloat16]


def make_tree(dtype, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"attn": {
            "wq": (rng.standard_normal((2, 16, 32)) * 0.1).astype(dtype)}},
        "embed": (rng.standard_normal((64, 32)) * 0.1).astype(dtype),
        "norm": np.ones(32, dtype=dtype),
        "step_count": np.array([3], dtype=np.int32),
    }


def test_registry_names():
    assert set(CODECS) <= set(compression.available())
    with pytest.raises(KeyError):
        compression.get("no-such-codec")


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize("name", CODECS)
def test_registry_roundtrip(name, dtype):
    tree = make_tree(dtype)
    codec = compression.get(name)
    art = codec.compress(tree)

    # container decode matches the quantizer's reconstruction bit-exactly
    flat_dec = compression.decompress(art.blob)
    recon = art.reconstructed()
    assert set(flat_dec) == set(recon)
    for k, v in recon.items():
        assert flat_dec[k].dtype == np.asarray(v).dtype, k
        np.testing.assert_array_equal(np.asarray(flat_dec[k]),
                                      np.asarray(v), err_msg=k)

    # tree restore: structure, dtype and shape of every leaf
    rec = codec.decompress(art.blob, like=tree)
    flat_in = compression.flatten_tree(tree)
    flat_out = compression.flatten_tree(rec)
    assert set(flat_out) == set(flat_in)
    for k in flat_in:
        assert flat_out[k].dtype == flat_in[k].dtype, k
        assert flat_out[k].shape == flat_in[k].shape, k

    # unquantized leaves pass through bit-exactly in every codec
    np.testing.assert_array_equal(flat_out["norm"], flat_in["norm"])
    np.testing.assert_array_equal(flat_out["step_count"],
                                  flat_in["step_count"])


@pytest.mark.parametrize("name", ["ckpt-nearest", "deepcabac-v2", "huffman"])
def test_quantized_error_bounded(name):
    tree = make_tree(np.float32)
    codec = compression.get(name)
    art = codec.compress(tree)
    rec = codec.decompress(art.blob, like=tree)
    w_in = tree["embed"].astype(np.float64)
    w_out = np.asarray(rec["embed"]).astype(np.float64)
    step = art.quantized["embed"].step
    lam = art.hyperparams.get("lam", 0.0)
    if lam == 0.0:   # nearest-level: half-step error bound
        assert np.max(np.abs(w_in - w_out)) <= step / 2 * (1 + 1e-3) + 1e-7


def test_mixed_quantized_raw_tree():
    rng = np.random.default_rng(3)
    tree = {
        "w": (rng.standard_normal((16, 16)) * 0.1).astype(np.float32),
        "bias": rng.standard_normal(16).astype(np.float32),      # 1-D: raw
        "ids": np.arange(64, dtype=np.int64).reshape(8, 8),      # int: raw
    }
    art = compression.get("ckpt-nearest").compress(tree)
    assert isinstance(art.quantized["w"], QuantizedTensor)
    assert isinstance(art.quantized["bias"], np.ndarray)
    assert isinstance(art.quantized["ids"], np.ndarray)
    rec = compression.decompress(art.blob, like=tree)
    np.testing.assert_array_equal(rec["bias"], tree["bias"])
    np.testing.assert_array_equal(rec["ids"], tree["ids"])


def test_serve_q8_codec_matches_serving_tree_pass():
    """The serve-q8 container path and the in-memory {"q8","q8s"} tree pass
    share one quantizer — levels/scales must agree exactly."""
    from repro.serve.quantized import dequant_leaf, is_q8, \
        quantize_params_for_serving
    tree = make_tree(np.float32)
    qp = quantize_params_for_serving(tree)
    assert is_q8(qp["layers"]["attn"]["wq"])
    assert is_q8(qp["embed"])
    assert not is_q8(qp["norm"])

    art = compression.get("serve-q8").compress(tree)
    q = compression.decompress(art.blob, dequantize=False)
    assert isinstance(q["embed"], Q8Tensor)
    np.testing.assert_array_equal(q["embed"].levels,
                                  np.asarray(qp["embed"]["q8"]))
    np.testing.assert_array_equal(q["embed"].scale,
                                  np.asarray(qp["embed"]["q8s"]))
    np.testing.assert_array_equal(
        q["embed"].dequantize(),
        np.asarray(dequant_leaf(qp["embed"], np.float32)))
    np.testing.assert_array_equal(
        q["layers/attn/wq"].levels,
        np.asarray(qp["layers"]["attn"]["wq"]["q8"]))


def test_checkpoint_codec_bit_identical_to_legacy(tmp_path):
    """CheckpointManager.save through `ckpt-nearest` must produce the same
    container bytes as the pre-refactor private _encode_params, and restore
    must round-trip it."""
    from repro.checkpoint.manager import (CheckpointConfig,
                                          CheckpointManager, flatten_tree)
    rng = np.random.default_rng(11)
    params = {
        "layers": {"w": (rng.standard_normal((4, 32, 16)) * 0.05
                         ).astype(np.float32)},
        "embed": (rng.standard_normal((64, 16)) * 0.05).astype(np.float32),
        "norm": np.ones(16, np.float32),
    }
    state = {"params": params, "step": np.zeros((), np.int32)}
    delta_rel = 1e-3
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             delta_rel=delta_rel))
    mgr.save(state, 1)

    # the exact pre-refactor CheckpointManager._encode_params
    entries = {}
    for name, w in flatten_tree(params).items():
        if w.ndim >= 2 and np.issubdtype(w.dtype, np.floating):
            wf = w.astype(np.float64)
            step = max(delta_rel * float(wf.std()), 1e-12)
            levels = nearest_level(wf.ravel(), step).reshape(w.shape)
            entries[name] = QuantizedTensor(levels, step, str(w.dtype))
        else:
            entries[name] = w
    legacy_blob = encode_state_dict(entries)

    with open(tmp_path / "step_00000001" / "params.dcbc", "rb") as f:
        assert f.read() == legacy_blob

    restored, meta = mgr.restore(state)
    assert meta["codec"] == "ckpt-nearest"
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        assert np.asarray(a).shape == np.asarray(b).shape
    np.testing.assert_array_equal(np.asarray(restored["params"]["norm"]),
                                  params["norm"])


def test_checkpoint_bf16_params_quantize_with_bounded_error(tmp_path):
    """Intentional change vs the pre-refactor path: bf16 params (every
    real config's param_dtype) now quantize like any other float instead
    of falling through np.issubdtype's False into raw storage.  Guard the
    error bound: step/2 + one bf16 ulp of re-rounding."""
    import ml_dtypes
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    rng = np.random.default_rng(7)
    w = (rng.standard_normal((32, 32)) * 0.1).astype(ml_dtypes.bfloat16)
    state = {"params": {"w": w}, "step": np.zeros((), np.int32)}
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), delta_rel=1e-3))
    mgr.save(state, 1)
    restored, meta = mgr.restore(state)
    out = np.asarray(restored["params"]["w"])
    assert out.dtype == np.dtype(ml_dtypes.bfloat16)
    wf = w.astype(np.float64)
    step = max(1e-3 * wf.std(), 1e-12)
    ulp = np.abs(wf) * 2.0 ** -8   # bf16 has 8 significand bits
    assert np.all(np.abs(wf - out.astype(np.float64)) <= step / 2 + ulp)
    assert meta["params_compressed_bytes"] < meta["params_raw_bytes"]


def test_decompress_like_with_dequantize_false():
    """like= and dequantize=False compose: quantized leaves land in the
    tree structure as QuantizedTensor/Q8Tensor objects."""
    tree = make_tree(np.float32)
    for name in ["ckpt-nearest", "serve-q8"]:
        blob = compression.get(name).compress(tree).blob
        rec = compression.get(name).decompress(blob, like=tree,
                                               dequantize=False)
        emb = rec["embed"]
        assert hasattr(emb, "dequantize") and emb.shape == (64, 32), name
        np.testing.assert_array_equal(rec["norm"], tree["norm"])


def test_checkpoint_accepts_registry_codec_name(tmp_path):
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    tree = make_tree(np.float32)
    state = {"params": tree, "step": np.zeros((), np.int32)}
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), codec="raw"))
    mgr.save(state, 2)
    restored, meta = mgr.restore(state)
    assert meta["codec"] == "raw"
    for a, b in zip(jax.tree.leaves(tree),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_forwards_hyperparams_to_named_codec(tmp_path):
    """delta_rel reaches any codec that accepts it (not just the default),
    and meta records the codec's actual hyperparams."""
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), codec="huffman",
                                             delta_rel=0.05))
    assert mgr._codec().quantizer.delta_rel == 0.05
    tree = make_tree(np.float32)
    state = {"params": tree, "step": np.zeros((), np.int32)}
    mgr.save(state, 1)
    _, meta = mgr.restore(state)
    assert meta["codec"] == "huffman"
    assert meta["delta_rel"] == 0.05
    assert meta["codec_hyperparams"]["delta_rel"] == 0.05
    # codecs without the knob ignore it instead of crashing or lying
    mgr2 = CheckpointManager(CheckpointConfig(str(tmp_path) + "2",
                                              codec="serve-q8",
                                              delta_rel=0.05))
    mgr2.save(state, 1)
    _, meta2 = mgr2.restore(state)
    assert "delta_rel" not in meta2
    assert "params_mode" not in meta2   # codec= supersedes the legacy knob
    # deepcabac-v2 honors delta_rel as a relative RD step (not the
    # absolute default delta, which would wreck small-std weights)
    mgr3 = CheckpointManager(CheckpointConfig(str(tmp_path) + "3",
                                              codec="deepcabac-v2",
                                              delta_rel=1e-3))
    codec3 = mgr3._codec()
    assert codec3.hyperparams["delta_rel"] == 1e-3
    mgr3.save(state, 1)
    restored3, meta3 = mgr3.restore(state)
    assert meta3["delta_rel"] == 1e-3
    w = np.asarray(tree["embed"], dtype=np.float64)
    err = np.max(np.abs(w - np.asarray(restored3["params"]["embed"],
                                       dtype=np.float64)))
    assert err <= 1e-3 * w.std() * 2  # relative grid, not delta=0.01


def test_constant_tensor_quantizes_sanely():
    """std(w) ~ 0 falls back to max|w| scaling instead of ~1e12 levels —
    including constant-up-to-noise tensors, not just exact constants."""
    const = np.full((4, 8), 0.5, np.float32)
    near = const.copy()
    near[0, 0] += 1e-6
    for tree in [{"w": const}, {"w": near}]:
        for name in ["ckpt-nearest", "huffman"]:
            art = compression.get(name).compress(tree)
            assert np.abs(art.quantized["w"].levels).max() <= 2000
            rec = compression.decompress(art.blob, like=tree)
            np.testing.assert_allclose(rec["w"], tree["w"], atol=0.5 * 1e-3)
    zero = {"w": np.zeros((4, 8), np.float32)}
    art = compression.get("ckpt-nearest").compress(zero)
    rec = compression.decompress(art.blob, like=zero)
    np.testing.assert_array_equal(rec["w"], zero["w"])


def test_zero_size_tensor_roundtrips_every_codec():
    tree = {"w": np.zeros((0, 4), np.float32)}
    for name in CODECS:
        art = compression.get(name).compress(tree)
        rec = compression.decompress(art.blob, like=tree)
        assert rec["w"].shape == (0, 4), name
        assert rec["w"].dtype == np.float32, name


def test_truncated_huffman_payload_raises_named_error():
    tree = {"w": (np.random.default_rng(9).standard_normal((64, 64)) * 0.1
                  ).astype(np.float32)}
    blob = compression.get("huffman", delta_rel=0.1).compress(tree).blob
    with pytest.raises(ValueError, match="truncated"):
        compression.decompress(blob[:-20])


def test_raw_codec_has_no_coder():
    codec = compression.get("raw")
    assert codec.coder is None and codec.quantizer is None


def test_q8_primitives_shared():
    """optim/distributed/serve pull one q8 implementation from
    compression.q8 (no more private cross-module imports)."""
    from repro.compression.q8 import q8_decode, q8_encode
    from repro.optim import adamw
    assert adamw._q8_encode is q8_encode
    assert adamw._q8_decode is q8_decode
    rng = np.random.default_rng(5)
    x = rng.standard_normal((4, 256)).astype(np.float32)
    codes, scale = q8_encode(x)
    back = np.asarray(q8_decode(codes, scale))
    assert np.asarray(codes).dtype == np.int8
    assert np.max(np.abs(back - x)) <= np.max(np.abs(x)) / 127.0 + 1e-6


def test_size_report_respects_dtype():
    """orig_mb/ratio_pct derive from each entry's dtype, not 4 B/param."""
    w16 = np.ones((32, 32), np.float16)
    blob = encode_state_dict({"w": w16})
    rep = compressed_size_report({"w": w16}, blob)
    assert rep["orig_mb"] == pytest.approx(32 * 32 * 2 / 2**20)
    qt = QuantizedTensor(np.zeros((8, 8), np.int64), 0.1, "bfloat16")
    rep2 = compressed_size_report({"q": qt}, b"\0" * 16)
    assert rep2["orig_mb"] == pytest.approx(8 * 8 * 2 / 2**20)
    assert rep2["bits_per_param"] == pytest.approx(8 * 16 / 64)
    f32 = np.ones((16, 16), np.float32)
    rep3 = compressed_size_report({"w": f32}, b"\0" * 64)
    assert rep3["orig_mb"] == pytest.approx(16 * 16 * 4 / 2**20)


def test_artifact_blob_is_v1_when_no_new_encodings():
    """Cabac/raw-only containers keep the version-1 header so pre-existing
    blobs and readers stay byte-compatible."""
    tree = make_tree(np.float32)
    import struct
    for name, want in [("ckpt-nearest", 1), ("raw", 1),
                       ("huffman", 2), ("serve-q8", 2)]:
        blob = compression.get(name).compress(tree).blob
        (version,) = struct.unpack_from("<H", blob, 4)
        assert version == want, name
