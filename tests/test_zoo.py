"""Multi-tenant model-zoo serving: the configs string registry,
content-addressed cross-model shard dedup (byte-level), refcounted
object GC across variant eviction, per-process base-hash memoization,
cold vs delta-warm admission identity, cancel-releases-parked-blobs,
and the 10-config routed acceptance run under an eviction-forcing HBM
budget."""

import json
import os

import jax
import numpy as np
import pytest

from repro import compression, configs
from repro.checkpoint import delta
from repro.checkpoint.delta import DeltaChainError
from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.checkpoint.sharded import MANIFEST_NAME
from repro.compression.tree import flatten_tree
from repro.models.transformer import init_params
from repro.serve.backends import BlobGC, get_backend, get_kv_store
from repro.serve.session import ServeConfig, ServeSession
from repro.serve.zoo import (AdmissionStall, ModelZoo, ShardStore, ZooConfig,
                             ZooError, ZooRouter, model_resident_bytes)

# the zoo integration tests decode full smoke-model containers, which is
# impractical on the forced numpy lane engine (same policy as
# test_delta_checkpoint); store/GC/registry tests below run everywhere
skip_on_forced_numpy = pytest.mark.skipif(
    os.environ.get("REPRO_CABAC_BACKEND") == "numpy",
    reason="smoke-model decode is impractical on the forced numpy lane "
           "engine; the store/registry tests in this file still run")


def _write_variant(root: str, step: int, flat: dict, base_entries: dict,
                   codec, seed: int) -> None:
    """One finetune variant: a delta (P-frame) step chained straight to
    the keyframe at step 1 (star topology, like N finetunes of one
    base).  Only ~a quarter of the tensors are perturbed — a partial
    finetune — so the delta stream stays small next to the keyframe."""
    rng = np.random.default_rng(seed)
    names = sorted(k for k, v in flat.items() if v.dtype.kind == "f")
    touched = set(names[:max(1, len(names) // 4)])
    pert = {k: (v * (1 + 5e-4 * rng.standard_normal(v.shape))).astype(v.dtype)
            if k in touched else v
            for k, v in flat.items()}
    dentries = codec.delta_entries(pert, base_entries)
    payloads, manifest = delta.write_delta(
        dentries, codec_name=codec.name, base=delta.base_ref(root, 1),
        num_gr=codec.coder.num_gr, chunk_size=codec.coder.chunk_size)
    d = delta.step_dir(root, step)
    os.makedirs(d)
    for fname, blob in payloads.items():
        with open(os.path.join(d, fname), "wb") as f:
            f.write(blob)
    with open(os.path.join(d, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1)


@pytest.fixture(scope="module")
def variant_root(tmp_path_factory):
    """llama3 smoke keyframe (sharded, step 1) + three delta variants
    (steps 2-4) chained to it, plus the base params tree."""
    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    root = str(tmp_path_factory.mktemp("zoo-ckpt"))
    mgr = CheckpointManager(CheckpointConfig(
        directory=root, sharded=True, codec="deepcabac-delta"))
    mgr.save({"params": params}, step=1)
    codec = compression.get("deepcabac-delta")
    base_entries = codec.quantize_entries(flatten_tree(params))
    for i, step in enumerate((2, 3, 4)):
        _write_variant(root, step, flatten_tree(params), base_entries,
                       codec, seed=100 + i)
    return cfg, params, root


def _dedicated_tokens(cfg, root, step, prompts, serve_cfg):
    """Reference: a dedicated single-model session cold-started from the
    original checkpoint, fed the same prompts in the same order."""
    backend = get_backend("container", track_levels=True)
    params = backend.load_entries(cfg, delta.restore_levels(root, step))
    sess = ServeSession.from_loaded(cfg, params, backend=backend,
                                    serve_cfg=serve_cfg)
    handles = [sess.submit(p, max_new_tokens=n) for p, n in prompts]
    sess.run(max_steps=2000)
    out = [list(map(int, h.result())) for h in handles]
    sess.close()
    return out


# ---------------------------------------------------------------------------
# configs string registry
# ---------------------------------------------------------------------------

def test_configs_registry_names_and_get():
    assert configs.names() == configs.ARCH_IDS
    cfg = configs.get("llama3-8b", smoke=True)
    assert cfg == configs.get_smoke_config("llama3-8b")
    assert configs.get("llama3-8b") == configs.get_config("llama3-8b")


def test_configs_registry_unknown_name_lists_available():
    with pytest.raises(KeyError, match="llama3-8b"):
        configs.get("no-such-arch")


# ---------------------------------------------------------------------------
# BlobGC
# ---------------------------------------------------------------------------

def test_blob_gc_refcounts_and_drop_order():
    dropped = []
    gc = BlobGC(dropped.append)
    gc.hold("a")
    gc.hold("a")
    gc.hold("b")
    assert not gc.release("a") and dropped == []
    assert gc.release("a") and dropped == ["a"]
    assert not gc.release("missing")         # idempotent cleanup
    assert gc.refs("b") == 1 and gc.live() == ["b"]
    gc.clear()
    assert dropped == ["a", "b"] and gc.live() == []


# ---------------------------------------------------------------------------
# ShardStore: cross-model dedup + eviction-safe GC  (satellite: dedup tests)
# ---------------------------------------------------------------------------

def test_shard_store_dedups_shared_keyframe_bytes(variant_root, tmp_path):
    cfg, _params, root = variant_root
    store = ShardStore(str(tmp_path / "store"))
    rec_a = store.add("var-a", delta.step_dir(root, 2))
    rec_b = store.add("var-b", delta.step_dir(root, 3))

    # the shared keyframe files (shard payloads + manifest) appear in
    # both chains with identical hashes...
    shared = set(rec_a["objects"]) & set(rec_b["objects"])
    kf = delta.chain_files(root, 2)[0]["files"]
    assert {f["sha256"] for f in kf.values()} == shared

    # ...but are materialized exactly once: byte-for-byte, the object
    # pool holds one keyframe plus each variant's private files
    objects = os.path.join(str(tmp_path / "store"), "objects")
    on_disk = {name: os.path.getsize(os.path.join(objects, name))
               for name in os.listdir(objects)}
    assert set(on_disk) == set(rec_a["objects"]) | set(rec_b["objects"])
    private_a = set(rec_a["objects"]) - shared
    private_b = set(rec_b["objects"]) - shared
    expected_physical = (sum(on_disk[s] for s in shared)
                         + sum(on_disk[s] for s in private_a)
                         + sum(on_disk[s] for s in private_b))
    rep = store.report()
    assert rep["physical_bytes"] == expected_physical == sum(on_disk.values())
    assert (rep["logical_bytes"] ==
            rec_a["logical_bytes"] + rec_b["logical_bytes"])
    # every shared byte was deduped, none double-stored
    assert store.stats["bytes_deduped"] == sum(on_disk[s] for s in shared)
    assert rep["dedup_ratio"] > 1.0
    store.close()


def test_shard_store_eviction_does_not_gc_shared_objects(variant_root,
                                                         tmp_path):
    cfg, _params, root = variant_root
    store = ShardStore(str(tmp_path / "store"))
    rec_a = store.add("var-a", delta.step_dir(root, 2))
    rec_b = store.add("var-b", delta.step_dir(root, 3))
    shared = set(rec_a["objects"]) & set(rec_b["objects"])
    tip_b = rec_b["tip"]

    store.remove("var-a")
    objects = os.path.join(str(tmp_path / "store"), "objects")
    left = set(os.listdir(objects))
    # var-a's private delta objects are gone; every shared (keyframe)
    # object survives because var-b still references it
    assert left == set(rec_b["objects"])
    assert shared <= left

    # var-b's view still resolves its full chain (resolve_chain verifies
    # every manifest-pinned hash along the way) and every surviving view
    # file is byte-for-byte the original checkpoint file
    chain = delta.resolve_chain(tip_b)
    assert len(chain) == 2
    orig = delta.chain_files(root, 3)
    for link, vdir in zip(orig, (chain[0]["dir"], tip_b)):
        for fname in link["files"]:
            with open(os.path.join(link["dir"], fname), "rb") as f:
                want = f.read()
            with open(os.path.join(vdir, fname), "rb") as f:
                assert f.read() == want, f"{fname} diverged in the view"

    store.remove("var-b")
    assert os.listdir(objects) == []         # last reference GCs the rest
    store.close()


def test_shard_store_rejects_corrupt_ingest(variant_root, tmp_path):
    cfg, _params, root = variant_root
    victim = str(tmp_path / "bad-ckpt")
    import shutil
    shutil.copytree(root, victim)
    # corrupt a shard file *without* touching its manifest entry
    d = delta.step_dir(victim, 1)
    shard = next(f for f in os.listdir(d) if f.startswith("shard_"))
    with open(os.path.join(d, shard), "ab") as f:
        f.write(b"\0")
    store = ShardStore(str(tmp_path / "store"))
    with pytest.raises(ValueError, match="content hash"):
        store.add("bad", delta.step_dir(victim, 1))


# ---------------------------------------------------------------------------
# sha256 memoization  (satellite: resolve_chain re-hash fix)
# ---------------------------------------------------------------------------

def test_resolve_chain_memoizes_base_hash(variant_root):
    _cfg, _params, root = variant_root
    delta.clear_hash_cache()
    delta.resolve_chain(root, 2)
    first = delta.hash_cache_stats()
    assert first["misses"] >= 1              # base payload hashed once
    delta.resolve_chain(root, 3)             # sibling variant, same base
    delta.resolve_chain(root, 4)
    after = delta.hash_cache_stats()
    assert after["misses"] == first["misses"], (
        "admitting sibling variants re-hashed the shared base")
    assert after["hits"] > first["hits"]


def test_memoized_hash_still_detects_rewritten_base(variant_root, tmp_path):
    _cfg, _params, root = variant_root
    import shutil
    victim = str(tmp_path / "rewrite")
    shutil.copytree(root, victim)
    delta.clear_hash_cache()
    delta.resolve_chain(victim, 2)           # warm the cache on the base
    with open(os.path.join(delta.step_dir(victim, 1), MANIFEST_NAME),
              "ab") as f:
        f.write(b" ")
    with pytest.raises(DeltaChainError, match="rewritten"):
        delta.resolve_chain(victim, 2)


# ---------------------------------------------------------------------------
# KV cold-store blob GC  (satellite: release-on-eviction fix)
# ---------------------------------------------------------------------------

@skip_on_forced_numpy
def test_cancel_releases_parked_dir_store_blob(variant_root):
    cfg, params, _root = variant_root
    cfg = cfg.replace(q8_cache=True)
    store = get_kv_store("dir")
    serve_cfg = ServeConfig(slots=2, max_len=64, kv_page_size=8,
                            kv_pool_pages=2 * 8 + 1, kv_cold_store=store)
    sess = ServeSession(cfg, params, serve_cfg=serve_cfg)
    rng = np.random.default_rng(0)
    h1 = sess.submit(rng.integers(1, cfg.vocab_size, 16), max_new_tokens=8)
    h2 = sess.submit(rng.integers(1, cfg.vocab_size, 16), max_new_tokens=8)
    sess.step()
    sess.step()
    sess.park(h1)
    root = store._root
    assert store.nbytes() > 0 and len(os.listdir(root)) > 0
    # pre-fix behavior: the parked request finishing (here: cancelled)
    # left its blob in the store until close() — the dir store kept the
    # file on disk for the rest of the process
    assert sess.cancel(h1)
    assert h1.finish_reason == "cancelled"
    assert store.nbytes() == 0
    assert os.listdir(root) == []
    sess.run(max_steps=500)
    assert h2.done and h2.finish_reason in ("length", "eos")
    sess.close()


@skip_on_forced_numpy
def test_cancel_queued_and_active_requests(variant_root):
    cfg, params, _root = variant_root
    sess = ServeSession(cfg, params,
                        serve_cfg=ServeConfig(slots=1, max_len=64))
    active = sess.submit([1, 2, 3], max_new_tokens=8)
    queued = sess.submit([4, 5, 6], max_new_tokens=8)
    sess.step()
    assert sess.cancel(queued)               # never admitted
    assert sess.cancel(active)               # holds the slot
    assert not sess.cancel(active)           # already finished: no-op
    assert sess.num_active == 0 and sess.num_queued == 0
    with pytest.raises(ValueError, match="not known"):
        sess.cancel(
            type(active)(id=999, prompt=np.ones(1, np.int32),
                         max_new_tokens=1))
    sess.close()


# ---------------------------------------------------------------------------
# ModelZoo admission
# ---------------------------------------------------------------------------

@skip_on_forced_numpy
def test_warm_admission_matches_cold_tokens(variant_root, tmp_path):
    cfg, _params, root = variant_root
    serve_cfg = ServeConfig(slots=2, max_len=64)
    one = model_resident_bytes(cfg, serve_cfg)
    zoo = ModelZoo(str(tmp_path / "store"),
                   ZooConfig(hbm_budget=3 * one, serve=serve_cfg))
    zoo.register("base", cfg, delta.step_dir(root, 1))
    zoo.register("var-a", cfg, delta.step_dir(root, 2))
    router = ZooRouter(zoo)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 12)
    hb = router.submit("base", prompt, max_new_tokens=6)
    ha = router.submit("var-a", prompt, max_new_tokens=6)
    router.run(max_steps=500)
    # the variant warmed from the resident base (its chain prefix)...
    assert zoo.stats["admits_warm"] == 1
    assert zoo.zoo_report()["models"]["var-a"]["last_admit"] == "warm"
    # ...and produced exactly the tokens a dedicated cold session does
    ref = _dedicated_tokens(cfg, root, 2, [(prompt, 6)], serve_cfg)
    assert [list(map(int, ha.result()))] == ref
    ref_b = _dedicated_tokens(cfg, root, 1, [(prompt, 6)], serve_cfg)
    assert [list(map(int, hb.result()))] == ref_b
    zoo.close()


@skip_on_forced_numpy
def test_admission_stall_when_residents_busy(variant_root, tmp_path):
    cfg, _params, root = variant_root
    serve_cfg = ServeConfig(slots=1, max_len=64)
    one = model_resident_bytes(cfg, serve_cfg)
    zoo = ModelZoo(str(tmp_path / "store"),
                   ZooConfig(hbm_budget=int(1.5 * one), serve=serve_cfg))
    zoo.register("base", cfg, delta.step_dir(root, 1))
    zoo.register("var-a", cfg, delta.step_dir(root, 2))
    sess = zoo.admit("base")
    h = sess.submit([1, 2, 3], max_new_tokens=4)
    with pytest.raises(AdmissionStall):
        zoo.admit("var-a")                   # base is busy, budget full
    sess.run(max_steps=100)
    assert h.done
    zoo.admit("var-a")                       # base idle now: evicted
    assert zoo.resident() == ["var-a"]
    assert zoo.stats["evictions"] == 1
    with pytest.raises(ZooError, match="not registered"):
        zoo.admit("nope")
    zoo.close()


# ---------------------------------------------------------------------------
# Acceptance: 10-config zoo, interleaved routing, eviction, dedup >= 2x
# ---------------------------------------------------------------------------

@skip_on_forced_numpy
def test_zoo_acceptance_ten_configs(variant_root, tmp_path):
    cfg, _params, root = variant_root
    serve_cfg = ServeConfig(slots=2, max_len=64)
    ckpts = str(tmp_path / "ckpts")

    # the full 10-config tenancy: llama3 base + 3 delta finetune
    # variants of it, plus 3 more architectures each shipping a base
    # keyframe and one partial-finetune delta variant of their own
    others = [a for a in configs.names() if a != "llama3-8b"][:3]
    model_cfgs = {"llama3-base": cfg}
    sources = {"llama3-base": delta.step_dir(root, 1)}
    for i, step in enumerate((2, 3, 4)):
        mid = f"llama3-var-{i}"
        model_cfgs[mid] = cfg
        sources[mid] = delta.step_dir(root, step)
    codec = compression.get("deepcabac-delta")
    for j, arch in enumerate(others):
        acfg = configs.get(arch, smoke=True)
        aroot = os.path.join(ckpts, arch)
        os.makedirs(aroot)
        mgr = CheckpointManager(CheckpointConfig(
            directory=aroot, sharded=True, codec="deepcabac-delta"))
        aparams = init_params(acfg, jax.random.PRNGKey(1))
        mgr.save({"params": aparams}, step=1)
        aflat = flatten_tree(aparams)
        _write_variant(aroot, 2, aflat, codec.quantize_entries(aflat),
                       codec, seed=200 + j)
        model_cfgs[f"{arch}-base"] = acfg
        sources[f"{arch}-base"] = delta.step_dir(aroot, 1)
        model_cfgs[f"{arch}-var"] = acfg
        sources[f"{arch}-var"] = delta.step_dir(aroot, 2)
    assert len(model_cfgs) == 10

    # budget: exactly two of the routed llama3 models fit at once, so
    # serving four of them must evict
    one = model_resident_bytes(cfg, serve_cfg)
    zoo = ModelZoo(str(tmp_path / "store"),
                   ZooConfig(hbm_budget=2 * one + one // 2,
                             serve=serve_cfg))
    for mid in model_cfgs:
        zoo.register(mid, model_cfgs[mid], sources[mid])
    assert zoo.models() == sorted(model_cfgs)

    routed = ["llama3-base", "llama3-var-0", "llama3-var-1", "llama3-var-2"]
    steps = {"llama3-base": 1, "llama3-var-0": 2, "llama3-var-1": 3,
             "llama3-var-2": 4}
    rng = np.random.default_rng(11)
    # distinct prompt lengths: admissions prefill one request at a time
    # in both the zoo and the dedicated reference sessions
    prompts = {m: rng.integers(1, cfg.vocab_size, 8 + 2 * j)
               for j, m in enumerate(routed)}
    router = ZooRouter(zoo)
    order = routed + routed[::-1] + routed[:2]      # interleaved traffic
    handles = [(m, router.submit(m, prompts[m], max_new_tokens=5))
               for m in order]
    router.run(max_steps=3000)
    assert all(h.done for _m, h in handles)

    rep = zoo.zoo_report()
    assert rep["stats"]["evictions"] > 0, "budget never forced an eviction"
    assert rep["resident_bytes"] <= rep["hbm_budget"]

    # per-model outputs are token-identical to a dedicated single-model
    # session fed the same request sequence
    for m in routed:
        mine = [list(map(int, h.result())) for mid, h in handles
                if mid == m]
        ref = _dedicated_tokens(cfg, root, steps[m],
                                [(prompts[m], 5)] * len(mine), serve_cfg)
        assert mine == ref, f"{m}: zoo tokens diverged from dedicated"

    # >= 2x on-disk dedup across the base + delta variants
    assert rep["store"]["dedup_ratio"] >= 2.0, rep["store"]
    assert rep["store"]["models"] == 10
    zoo.close()


def test_q8_resident_accounting_charges_compressed_bytes():
    """model_resident_bytes with a q8-resident backend costs eligible
    tensors at int8 levels + f32 scales, not the full param dtype (the
    old accounting overcounted ~4x and forfeited the admission gains)."""
    from repro.serve.kv import kv_cache_bytes

    cfg = configs.get("llama3-8b", smoke=True)
    serve_cfg = ServeConfig(slots=2, max_len=32)
    full = model_resident_bytes(cfg, serve_cfg)
    q8 = model_resident_bytes(cfg, serve_cfg, backend="q8")
    assert q8 < full
    # weight-only ratio (KV is identical on both sides) at the int8+scale
    # width the serve bench gates on
    kv = kv_cache_bytes(cfg, serve_cfg.slots, serve_cfg.max_len)
    assert (q8 - kv) / (full - kv) <= 0.35
    # bf16/container residency keeps the full-precision accounting
    assert model_resident_bytes(cfg, serve_cfg, backend="container") == full


@skip_on_forced_numpy
def test_q8_backend_admits_more_models_same_budget(variant_root, tmp_path):
    """Same hbm_budget, strictly more models resident with the q8
    backend: the compressed-resident footprint is what admission sizes."""
    cfg, _params, root = variant_root
    serve_cfg = ServeConfig(slots=2, max_len=32)
    full = model_resident_bytes(cfg, serve_cfg)
    q8 = model_resident_bytes(cfg, serve_cfg, backend="q8")
    # fits three q8-resident models but only one full-precision one
    budget = full + q8 // 2
    assert 3 * q8 <= budget < 2 * full
    counts = {}
    for backend in ("container", "q8"):
        zoo = ModelZoo(str(tmp_path / f"store-{backend}"),
                       ZooConfig(hbm_budget=budget, backend=backend,
                                 serve=serve_cfg))
        zoo.register("base", cfg, delta.step_dir(root, 1))
        zoo.register("var-a", cfg, delta.step_dir(root, 2))
        zoo.register("var-b", cfg, delta.step_dir(root, 3))
        for m in ("base", "var-a", "var-b"):
            zoo.admit(m)
        counts[backend] = len(zoo.resident())
        assert zoo.resident_bytes() <= budget
        zoo.close()
    assert counts["container"] == 1
    assert counts["q8"] == 3
