"""Golden-bitstream fixtures for the DCBC wire format.

Every builder here is fully deterministic (arithmetic sequences, exact
binary step sizes, no RNG) so the emitted container bytes are a function
of the codec implementation alone.  tests/test_golden_bitstreams.py
asserts byte-exact encode output against the committed ``*.dcbc.hex``
fixtures and exact decode round-trips — the wire format cannot drift
silently across refactors.

Regenerate (only after an *intentional* format change, with a matching
version bump / compat note in docs/compression_api.md):

    PYTHONPATH=src python tests/golden/gen_goldens.py

Drift check (CI runs this as its own step, so wire-format drift fails
loudly and separately from the test suite):

    PYTHONPATH=src python tests/golden/gen_goldens.py --check
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
WRAP = 64


def _levels(n: int, spike: bool = False) -> np.ndarray:
    """Deterministic int levels: zero runs, signed smalls, a few larger
    Exp-Golomb-range magnitudes — every binarization branch is exercised."""
    lv = ((np.arange(n, dtype=np.int64) * 7919) % 23) - 11
    lv[::3] = 0
    lv[5::31] = 17 + (np.arange(len(lv[5::31]), dtype=np.int64) % 9) * 13
    if spike:
        lv[n // 2] = -(1 << 20)
    return lv


def v1_entries() -> dict:
    """raw + multi-chunk cabac records only -> version 1 container."""
    from repro.core.codec import QuantizedTensor
    return {
        "w": QuantizedTensor(_levels(400).reshape(20, 20), 0.125, "float32"),
        "w_bf16": QuantizedTensor(_levels(96, spike=True).reshape(8, 12),
                                  0.5, "bfloat16"),
        "bias": (np.arange(16, dtype=np.float32) - 8) / 4,
    }


def build_v1() -> bytes:
    from repro.core.codec import encode_state_dict
    return encode_state_dict(v1_entries(), num_gr=10, chunk_size=128)


def v2_parts() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    huff_levels = _levels(200)
    q8_levels = (((np.arange(48, dtype=np.int64) * 37) % 255) - 127).astype(
        np.int8).reshape(4, 12)
    q8_scale = ((np.arange(12) + 1) / 64).astype(np.float32)
    cabac_levels = _levels(150)
    return huff_levels, q8_levels, q8_scale, cabac_levels


def build_v2() -> bytes:
    """huffman + q8 + cabac records -> version 2 container."""
    from repro.core.codec import encode_level_chunks
    from repro.core.container import ContainerWriter
    from repro.core.huffman import build_huffman, pack_payload
    huff_levels, q8_levels, q8_scale, cabac_levels = v2_parts()
    w = ContainerWriter()
    w.add_huffman("huf", "float32", (10, 20), 0.25,
                  pack_payload(huff_levels, build_huffman(huff_levels)))
    w.add_q8("q8", "float32", q8_levels, q8_scale)
    w.add_cabac("cab", "float32", (150,), 0.0625, 10, 64,
                encode_level_chunks(cabac_levels, 10, 64))
    return w.tobytes()


def v3_parts() -> tuple[np.ndarray, np.ndarray]:
    return _levels(500, spike=True), _levels(33)


def build_v3() -> bytes:
    """lane-scheduled cabac records (+ one raw) -> version 3 container."""
    from repro.core.codec import encode_level_chunks_batched
    from repro.core.container import ContainerWriter
    big, small = v3_parts()
    w = ContainerWriter()
    chunks, counts = encode_level_chunks_batched(big, 10, 128)
    w.add_cabac_v3("big", "float32", (20, 25), 0.125, 10, 128,
                   chunks, counts)
    chunks, counts = encode_level_chunks_batched(small, 10, 128)
    w.add_cabac_v3("small", "bfloat16", (33,), 0.5, 10, 128,
                   chunks, counts)
    w.add_raw("raw", (np.arange(6, dtype=np.float32) / 8).reshape(2, 3))
    return w.tobytes()


def v4_parts() -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(base levels, residual, intra levels) for the delta container:
    the residual is sparse and small relative to the base — the P-frame
    shape — and the base mixes all three temporal context classes
    (zero / small / large)."""
    base = _levels(300)
    resid = ((np.arange(300, dtype=np.int64) * 31) % 7) - 3
    resid[::4] = 0
    return base, resid, _levels(40)


def build_v4() -> bytes:
    """temporal-context delta record (+ one intra v3 record) -> version 4
    container (``ENC_CABAC_DELTA``)."""
    from repro.core.codec import (encode_delta_chunks_batched,
                                  encode_level_chunks_batched)
    from repro.core.container import ContainerWriter
    base, resid, intra = v4_parts()
    w = ContainerWriter()
    chunks, counts = encode_delta_chunks_batched(resid, base, 10, 64)
    w.add_cabac_delta("delta", "float32", (20, 15), 0.125, 10, 64,
                      chunks, counts)
    chunks, counts = encode_level_chunks_batched(intra, 10, 64)
    w.add_cabac_v3("intra", "bfloat16", (40,), 0.5, 10, 64, chunks, counts)
    return w.tobytes()


BUILDERS = {
    "v1_basic": build_v1,
    "v2_mixed": build_v2,
    "v3_lanes": build_v3,
    "v4_delta": build_v4,
}


def fixture_path(name: str) -> str:
    return os.path.join(HERE, f"{name}.dcbc.hex")


def load_fixture(name: str) -> bytes:
    with open(fixture_path(name)) as f:
        return bytes.fromhex("".join(f.read().split()))


def _render(blob: bytes) -> str:
    h = blob.hex()
    return "\n".join(h[i:i + WRAP] for i in range(0, len(h), WRAP)) + "\n"


def check() -> int:
    """Regenerate every fixture in memory and diff against the committed
    hex files.  Exit 1 on any drift — the wire format changed without a
    deliberate fixture regeneration (and version bump / compat note)."""
    drifted = []
    for name, build in BUILDERS.items():
        fresh = build()
        try:
            committed = load_fixture(name)
        except FileNotFoundError:
            drifted.append(f"{name}: fixture file missing")
            continue
        if fresh == committed:
            print(f"{name}: OK ({len(fresh)} bytes, byte-identical)")
            continue
        first = next((i for i, (a, b) in enumerate(zip(fresh, committed))
                      if a != b), min(len(fresh), len(committed)))
        drifted.append(
            f"{name}: encoder output drifted from committed fixture "
            f"({len(committed)} -> {len(fresh)} bytes, first difference "
            f"at byte {first})")
    for msg in drifted:
        print(f"DRIFT {msg}", file=sys.stderr)
    if drifted:
        print("wire-format drift detected: if intentional, regenerate "
              "fixtures with gen_goldens.py and document the change in "
              "docs/compression_api.md", file=sys.stderr)
        return 1
    print("golden fixtures clean: no wire-format drift")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff regenerated fixtures against tests/golden/ "
                         "instead of overwriting them")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    for name, build in BUILDERS.items():
        blob = build()
        with open(fixture_path(name), "w") as f:
            f.write(_render(blob))
        print(f"{name}: {len(blob)} bytes -> {fixture_path(name)}")


if __name__ == "__main__":
    main()
