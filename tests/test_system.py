"""End-to-end behaviour: training converges, checkpoints resume exactly,
serving from a DeepCABAC container matches raw-weight serving, FIM pipeline
(DC-v1) produces valid compression on a trained model."""

import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      flatten_tree, unflatten_like)
from repro.configs import get_smoke_config
from repro.core.deepcabac import compress_dc_v1, compress_dc_v2
from repro.core.fim import empirical_fisher_diag
from repro.data.pipeline import make_eval_batches
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import train_loss
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import init_train_state


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    cfg = get_smoke_config("llama3-8b")
    mesh = make_local_mesh(1, 1)
    d = tmp_path_factory.mktemp("ckpt")
    loop = LoopConfig(total_steps=60, batch=8, seq=64, ckpt_every=30,
                      resume=False)
    res = train_loop(cfg, mesh, loop, opt_cfg=AdamWConfig(lr=2e-3),
                     ckpt_cfg=CheckpointConfig(str(d), params_mode="raw"))
    mgr = CheckpointManager(CheckpointConfig(str(d), params_mode="raw"))
    template = init_train_state(cfg, AdamWConfig(lr=2e-3))
    state, _ = mgr.restore(template)
    return cfg, state, res


def test_training_reduces_loss(trained):
    _, _, res = trained
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_serve_from_compressed_matches_raw(trained):
    cfg, state, _ = trained
    params = state["params"]
    flat = flatten_tree(params)
    res = compress_dc_v2(flat, delta=1e-4, lam=0.0)
    eng_raw = ServeEngine(cfg, params, max_len=96)
    eng_c = ServeEngine.from_compressed(cfg, res.blob, max_len=96)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)
    out_raw = eng_raw.generate(prompts, steps=8)
    out_c = eng_c.generate(prompts, steps=8)
    # near-lossless quantization -> identical greedy tokens
    assert np.array_equal(out_raw, out_c)
    assert out_raw.shape == (4, 24)


def test_compression_accuracy_tradeoff(trained):
    """Coarser steps compress more; quality degrades monotonically-ish."""
    cfg, state, _ = trained
    flat = flatten_tree(state["params"])
    evals = make_eval_batches(cfg, 2, batch=8, seq=64)

    def nll(params_flat):
        p = unflatten_like(
            {k: np.asarray(v) for k, v in params_flat.items()},
            state["params"])
        return float(np.mean([train_loss(p, b, cfg) for b in evals]))

    fine = compress_dc_v2(flat, delta=1e-4, lam=0.0)
    coarse = compress_dc_v2(flat, delta=2e-2, lam=1e-4)
    assert len(coarse.blob) < len(fine.blob)
    assert nll(coarse.reconstructed()) >= nll(fine.reconstructed()) - 1e-3


def test_dc_v1_with_empirical_fisher(trained):
    cfg, state, _ = trained
    params = state["params"]
    batches = make_eval_batches(cfg, 2, batch=4, seq=32)
    fim = empirical_fisher_diag(
        lambda p, b: train_loss(p, b, cfg), params, batches)
    flat_p = flatten_tree(params)
    flat_f = flatten_tree(fim)
    sigma = {k: 1.0 / np.sqrt(np.asarray(v) + 1e-8)
             for k, v in flat_f.items()}
    res = compress_dc_v1(flat_p, sigma, s=64.0, lam=1e-4)
    assert res.report["bits_per_param"] < 32
    rec = res.reconstructed()
    assert set(rec) == set(flat_p)
