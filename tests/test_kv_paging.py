"""Paged KV cache (``repro.serve.kv``): the ``kv-q8-cabac`` page codec
round trip, token identity through forced eviction + re-admission and
manual park/resume, copy-on-write prefix sharing, compacted decode
batches (free slots burn no decode FLOPs), the cold-store registry, and
capacity accounting."""

import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import compression
from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.backends import (DirKVStore, available_kv_stores,
                                  get_backend, get_kv_store,
                                  resolve_kv_store)
from repro.serve.kv import PagedKV, kv_cache_bytes
from repro.serve.session import ServeConfig, ServeSession

skip_on_forced_numpy = pytest.mark.skipif(
    os.environ.get("REPRO_CABAC_BACKEND") == "numpy",
    reason="smoke-model serving decode is impractical on the forced "
           "numpy lane engine; codec-level paging coverage runs above")


@pytest.fixture(scope="module")
def smoke():
    # int8 KV cache: the eviction codec is lossless on cache levels, so
    # paged serving is *token-identical* to unpaged (the acceptance bar)
    cfg = get_smoke_config("llama3-8b").replace(q8_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_session(cfg, params, prompts, serve_cfg, max_new=8):
    s = ServeSession(cfg, params, serve_cfg=serve_cfg)
    handles = [s.submit(p, max_new_tokens=max_new) for p in prompts]
    s.run(max_steps=2000)
    assert all(h.done for h in handles)
    outs = [list(h.result()) for h in handles]
    return s, outs


# -- kv-q8-cabac page codec (satellite: registered + round-trip) -------------

def test_kv_codec_registered():
    assert "kv-q8-cabac" in compression.available()
    codec = compression.get("kv-q8-cabac")
    assert codec.name == "kv-q8-cabac"


def test_kv_codec_int8_pages_lossless():
    rng = np.random.default_rng(0)
    # cache levels are small-magnitude (activations on the kv_cache_delta
    # grid), which is what the CABAC bin model compresses
    pages = {"k": np.clip(rng.normal(0, 8, (2, 3, 8, 2, 4)), -127,
                          127).astype(np.int8),
             "v": rng.integers(-20, 20, (2, 3, 8, 2, 4)).astype(np.int8)}
    codec = compression.get("kv-q8-cabac", step=1 / 16)
    art = codec.compress(pages)
    assert art.report["compressed_bytes"] < art.report["raw_bytes"]
    out = codec.decompress(art.blob, like=pages)
    for k in pages:
        assert out[k].dtype == np.int8
        assert np.array_equal(out[k], pages[k])


def test_kv_codec_float_pages_match_q8_reconstruction():
    """Float pages are q8-block-quantized before entropy coding: the
    restore equals the q8 reconstruction exactly (levels and scales both
    round-trip bit-exactly through the container)."""
    rng = np.random.default_rng(1)
    x32 = rng.standard_normal((2, 4, 16, 8)).astype(np.float32)
    x16 = rng.standard_normal((2, 4, 16, 8)).astype(ml_dtypes.bfloat16)
    codec = compression.get("kv-q8-cabac")
    art = codec.compress({"a": x32, "b": x16})
    out = codec.decompress(art.blob)
    for name, x in (("a", x32), ("b", x16)):
        codes, scale = compression.q8_encode(jnp.asarray(x))
        want = np.asarray(compression.q8_decode(codes, scale)).astype(x.dtype)
        assert out[name].dtype == x.dtype
        assert np.array_equal(out[name], want), name


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       page=st.sampled_from([1, 3, 8, 16]),
       dtype=st.sampled_from(["int8", "float32", "bfloat16"]))
def test_kv_codec_roundtrip_property(seed, page, dtype):
    """Property (satellite): compress/evict/restore round-trips bit-exact
    q8 levels for any page size and cache dtype."""
    rng = np.random.default_rng(seed)
    shape = (2, rng.integers(1, 4), page, 4)
    if dtype == "int8":
        x = rng.integers(-128, 128, shape).astype(np.int8)
    else:
        x = (rng.standard_normal(shape) * rng.uniform(0.1, 4)).astype(
            ml_dtypes.bfloat16 if dtype == "bfloat16" else np.float32)
    codec = compression.get("kv-q8-cabac")
    out = codec.decompress(codec.compress({"p": x}).blob)["p"]
    assert out.dtype == x.dtype
    if dtype == "int8":
        assert np.array_equal(out, x)
    else:
        codes, scale = compression.q8_encode(jnp.asarray(x))
        want = np.asarray(compression.q8_decode(codes, scale)).astype(x.dtype)
        assert np.array_equal(out, want)
        if dtype == "float32":
            # f32 reconstructions re-encode to the same levels (bf16
            # storage rounding can legitimately flip boundary levels)
            codes2, _ = compression.q8_encode(jnp.asarray(out))
            assert np.array_equal(np.asarray(codes2), np.asarray(codes))


# -- cold-store registry ------------------------------------------------------

def test_kv_store_registry(tmp_path):
    assert {"host", "dir"} <= set(available_kv_stores())
    with pytest.raises(KeyError):
        get_kv_store("no-such-store")
    store = get_kv_store("dir", root=str(tmp_path))
    store.put("a", b"xyz")
    assert "a" in store and store.get("a") == b"xyz"
    assert store.nbytes() == 3
    store.drop("a")
    assert "a" not in store and store.nbytes() == 0
    store.close()
    # resolve passes instances through
    inst = DirKVStore(root=str(tmp_path))
    assert resolve_kv_store(inst) is inst
    inst.close()


# -- scheduler: token identity (acceptance) ----------------------------------

@skip_on_forced_numpy
def test_paged_matches_unpaged_no_pressure(smoke):
    cfg, params = smoke
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7, 12)]
    _, ref = _run_session(cfg, params, prompts,
                          ServeConfig(slots=2, max_len=64))
    s, out = _run_session(cfg, params, prompts,
                          ServeConfig(slots=2, max_len=64, kv_page_size=8))
    assert out == ref
    s.close()


@skip_on_forced_numpy
def test_paged_token_identity_under_forced_eviction(smoke):
    """Acceptance: a pool too small for the active set forces compressed
    eviction (park) and re-admission (restore) mid-generation; every
    request's greedy tokens still equal the unpaged session's."""
    cfg, params = smoke
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 10, 9, 11)]
    _, ref = _run_session(cfg, params, prompts,
                          ServeConfig(slots=4, max_len=64), max_new=16)
    s, out = _run_session(
        cfg, params, prompts,
        ServeConfig(slots=4, max_len=64, kv_page_size=4, kv_pool_pages=20,
                    kv_restore_workers=1), max_new=16)
    assert s.stats["parks"] > 0, "pool must be tight enough to force parks"
    assert s._kv.stats["pages_restored"] > 0
    assert s._kv.stats["bytes_to_host"] > 0
    assert out == ref
    s.close()


@skip_on_forced_numpy
def test_parked_then_resumed_request_is_token_identical(smoke):
    """Scheduler test (satellite): manual park -> later resume produces a
    token stream identical to a never-parked unpaged run."""
    cfg, params = smoke
    rng = np.random.default_rng(4)
    p1 = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    _, ref = _run_session(cfg, params, [p1, p2],
                          ServeConfig(slots=2, max_len=64), max_new=10)

    s = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=2, max_len=64, kv_page_size=4))
    h1 = s.submit(p1, max_new_tokens=10)
    h2 = s.submit(p2, max_new_tokens=10)
    s.step()
    s.step()
    assert not h1.done
    s.park(h1)                       # mid-generation, KV leaves the device
    assert s.num_parked == 1
    assert s._kv.stats["pages_evicted"] > 0
    s.run()                          # h2 finishes; h1 stays parked
    assert h2.done and not h1.done
    s.resume(h1)
    s.run()
    assert h1.done
    assert [list(h1.result()), list(h2.result())] == ref
    s.close()


def test_park_requires_paged_mode(smoke):
    cfg, params = smoke
    s = ServeSession(cfg, params,
                     serve_cfg=ServeConfig(slots=1, max_len=16))
    h = s.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="paged"):
        s.park(h)


# -- prefix sharing -----------------------------------------------------------

@skip_on_forced_numpy
def test_prefix_sharing_prefills_once_with_cow(smoke):
    """Two requests with a shared system prompt: the shared pages prefill
    once (the second admission runs a suffix-only partial prefill), the
    page tables alias only the read-only prefix pages, and tokens match
    the unpaged session."""
    cfg, params = smoke
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    pa = np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, 3)
                         .astype(np.int32)])
    pb = np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, 5)
                         .astype(np.int32)])
    _, ref = _run_session(cfg, params, [pa, pb],
                          ServeConfig(slots=2, max_len=64), max_new=6)

    s = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=2, max_len=64, kv_page_size=4))
    ha = s.submit(pa, max_new_tokens=6)
    hb = s.submit(pb, max_new_tokens=6)
    s.step()                          # admits both; b hits a's prefix
    assert s._kv.stats["prefix_hits"] == 1
    assert s._kv.stats["prefix_pages_reused"] == 2        # 8 tokens / page 4
    assert s.stats["prefix_reused_tokens"] == 8
    # only the suffixes prefilled on the second admission
    assert s.stats["prefill_tokens"] == pa.size + (pb.size - 8)
    ids_a, ids_b = s._kv.slot_ids(0), s._kv.slot_ids(1)
    assert ids_a[:2] == ids_b[:2], "prefix pages must be aliased"
    assert not (set(ids_a[2:]) & set(ids_b[2:])), \
        "writable pages must never alias"
    s.run()
    assert [list(ha.result()), list(hb.result())] == ref
    s.close()


@skip_on_forced_numpy
def test_prefix_sharing_disabled_never_aliases(smoke):
    cfg, params = smoke
    rng = np.random.default_rng(6)
    p = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    s = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=2, max_len=64, kv_page_size=4, kv_prefix_sharing=False))
    s.submit(p, max_new_tokens=4)
    s.submit(p.copy(), max_new_tokens=4)
    s.step()
    assert s._kv.stats["prefix_hits"] == 0
    assert not (set(s._kv.slot_ids(0)) & set(s._kv.slot_ids(1)))
    s.run()
    s.close()


# -- decode FLOPs on free slots (satellite) -----------------------------------

@skip_on_forced_numpy
def test_free_slots_burn_no_decode_rows(smoke):
    """Paged decode batches are compacted: one active request in a
    4-slot session decodes batch rows for itself only, and an all-free
    tick skips the decode call entirely.  The slot-mode counter shows
    the contrast (free slots ride every batch there)."""
    cfg, params = smoke
    p = np.arange(6, dtype=np.int32)

    sp = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=4, max_len=32, kv_page_size=8))
    h = sp.submit(p, max_new_tokens=5)
    sp.run()
    assert h.done
    assert sp.stats["free_slot_rows"] == 0
    assert sp.stats["decode_rows"] == sp.stats["decode_steps"]  # batch of 1
    before = sp.stats["decode_steps"]
    sp.step()                                   # all slots free
    assert sp.stats["decode_steps"] == before
    assert sp.stats["skipped_all_free_steps"] >= 1
    sp.close()

    su = ServeSession(cfg, params,
                      serve_cfg=ServeConfig(slots=4, max_len=32))
    h = su.submit(p, max_new_tokens=5)
    su.run()
    assert h.done
    assert su.stats["free_slot_rows"] > 0       # slot mode pays for them


# -- composition with the rest of the serving stack ---------------------------

@skip_on_forced_numpy
def test_swap_weights_composes_with_paged_cache(smoke, tmp_path):
    """Live delta weight swap mid-generation on a *paged* session: same
    tokens as the identical swap sequence on an unpaged session."""
    from repro.checkpoint import CheckpointConfig, CheckpointManager
    cfg, params = smoke
    flat = dict(compression.flatten_tree(jax.device_get(params)))
    rng = np.random.default_rng(7)
    pert = {k: (v * (1 + 1e-4 * rng.standard_normal(v.shape))).astype(v.dtype)
            if np.asarray(v).dtype.kind == "f" else v
            for k, v in flat.items()}
    mgr = CheckpointManager(CheckpointConfig(
        str(tmp_path / "ckpt"), codec="deepcabac-delta", delta_every=4))
    mgr.save({"params": params, "opt": {"count": np.int32(0)}}, 1)
    mgr.save({"params": compression.unflatten_like(pert, params),
              "opt": {"count": np.int32(1)}}, 2)
    kf_dir = os.path.join(mgr.cfg.directory, "step_00000001")
    delta_dir = os.path.join(mgr.cfg.directory, "step_00000002")
    with open(os.path.join(kf_dir, "params.dcbc"), "rb") as f:
        kf_blob = f.read()

    def run(serve_cfg):
        backend = get_backend("container", track_levels=True)
        s = ServeSession(cfg, kf_blob, backend=backend, serve_cfg=serve_cfg)
        h = s.submit(np.arange(5, dtype=np.int32), max_new_tokens=8)
        s.step()
        s.step()
        assert s.swap_weights(delta_dir) > 0
        s.run()
        assert h.done
        return list(h.result())

    paged = run(ServeConfig(slots=2, max_len=32, kv_page_size=4))
    unpaged = run(ServeConfig(slots=2, max_len=32))
    assert paged == unpaged


def test_paged_rejects_stateful_families():
    cfg = get_smoke_config("mamba2-2.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="token axis"):
        ServeSession(cfg, params, serve_cfg=ServeConfig(
            slots=1, max_len=32, kv_page_size=8))


def test_pool_must_hold_one_full_slot(smoke):
    cfg, params = smoke
    with pytest.raises(Exception, match="kv_pool_pages"):
        ServeSession(cfg, params, serve_cfg=ServeConfig(
            slots=2, max_len=64, kv_page_size=8, kv_pool_pages=4))


# -- capacity accounting (satellite) ------------------------------------------

def test_kv_capacity_reporting(smoke):
    """kv_bytes_per_slot derives from the real cache shapes; the paged
    report accounts device + compressed-host bytes from one source."""
    from repro.models.transformer import init_cache
    cfg, params = smoke
    per_slot = kv_cache_bytes(cfg, 1, 64)
    want = int(sum(l.nbytes for l in
                   jax.tree.leaves(init_cache(cfg, 1, 64))))
    assert per_slot == want

    s = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=2, max_len=64, kv_page_size=8))
    assert s.kv_bytes_per_slot() == per_slot
    r = s.kv_report()
    assert r["mode"] == "paged"
    assert r["device_bytes"] == int(sum(
        l.nbytes for l in jax.tree.leaves(s._kv.pools)))
    assert r["host_compressed_bytes"] == 0
    assert r["bytes_per_slot"] == per_slot
    assert "scheduler" in r and "free_pages" in r
    s.close()

    su = ServeSession(cfg, params,
                      serve_cfg=ServeConfig(slots=2, max_len=64))
    ru = su.kv_report()
    assert ru["mode"] == "slots"
    assert ru["device_bytes"] == 2 * per_slot
    assert ru["bytes_per_slot"] == per_slot


@skip_on_forced_numpy
def test_park_moves_bytes_to_host(smoke):
    cfg, params = smoke
    s = ServeSession(cfg, params, serve_cfg=ServeConfig(
        slots=1, max_len=32, kv_page_size=4))
    h = s.submit(np.arange(6, dtype=np.int32), max_new_tokens=6)
    s.step()
    s.park(h)
    r = s.kv_report()
    assert r["host_compressed_bytes"] > 0
    # compressed eviction actually compresses
    assert r["host_compressed_bytes"] < r["stats"]["pages_evicted"] * \
        (r["device_bytes"] // r["pool_pages"])
    s.resume(h)
    s.run()
    assert h.done and s.kv_report()["host_compressed_bytes"] == 0
    s.close()
