"""GPipe pipeline over a mesh axis: numerical equivalence with sequential
stage application (subprocess with 8 fake devices)."""

import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.pipeline_stage import gpipe_apply, split_stages

mesh = jax.make_mesh((4, 2), ("pod", "data"))
S, L, M, MB, D = 4, 8, 6, 4, 32
rng = np.random.default_rng(0)
layers = {"w": jnp.asarray(rng.standard_normal((L, D, D)) * (D ** -0.5)),
          "b": jnp.asarray(rng.standard_normal((L, D)) * 0.01)}
x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

def block(p, h):
    def body(hh, lp):
        return jnp.tanh(hh @ lp["w"] + lp["b"]), None
    out, _ = jax.lax.scan(body, h, p)
    return out

stages = split_stages(layers, S)
got = gpipe_apply(block, stages, x, mesh, axis="pod")

# sequential reference: all L layers over each microbatch
ref = jax.vmap(lambda xb: block(layers, xb))(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINE_OK" in proc.stdout
