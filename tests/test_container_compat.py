"""Container version negotiation and reader error paths.

Compat matrix under test (writer version x reader generation):

    writer \\ reader | v1-era | v2-era | v3-era | v4-era
    v1 (raw+cabac)   |  reads |  reads |  reads |  reads
    v2 (+huff, q8)   | reject |  reads |  reads |  reads
    v3 (+lane cabac) | reject | reject |  reads |  reads
    v4 (+delta)      | reject | reject | reject |  reads

Older reader generations are emulated with ``max_version`` — the version
gate is the same code path a pre-v4 checkout runs.
"""

import numpy as np
import pytest

from repro.core.codec import (QuantizedTensor, decode_state_dict,
                              encode_delta_chunks_batched,
                              encode_level_chunks,
                              encode_level_chunks_batched, encode_state_dict)
from repro.core.container import (HEADER_LEN, MAGIC, VERSION, VERSION_V2,
                                  VERSION_V3, VERSION_V4, ContainerReader,
                                  ContainerWriter, read_record_at)


def _v1_blob() -> bytes:
    lv = (np.arange(60, dtype=np.int64) % 7) - 3
    return encode_state_dict({"w": QuantizedTensor(lv.reshape(6, 10), 0.5)},
                             chunk_size=16)


def _v2_blob() -> bytes:
    w = ContainerWriter()
    w.add_q8("q", "float32", np.arange(-6, 6, dtype=np.int8).reshape(3, 4),
             np.ones(4, dtype=np.float32))
    return w.tobytes()


def _v3_blob() -> bytes:
    lv = (np.arange(90, dtype=np.int64) % 11) - 5
    chunks, counts = encode_level_chunks_batched(lv, 10, 32)
    w = ContainerWriter()
    w.add_cabac_v3("w", "float32", (90,), 0.25, 10, 32, chunks, counts)
    return w.tobytes()


def _v4_blob() -> bytes:
    base = (np.arange(90, dtype=np.int64) % 11) - 5
    resid = (np.arange(90, dtype=np.int64) % 3) - 1
    chunks, counts = encode_delta_chunks_batched(resid, base, 10, 32)
    w = ContainerWriter()
    w.add_cabac_delta("w", "float32", (90,), 0.25, 10, 32, chunks, counts)
    return w.tobytes()


def test_writer_emits_lowest_sufficient_version():
    assert ContainerReader(_v1_blob()).version == VERSION
    assert ContainerReader(_v2_blob()).version == VERSION_V2
    assert ContainerReader(_v3_blob()).version == VERSION_V3
    assert ContainerReader(_v4_blob()).version == VERSION_V4


@pytest.mark.parametrize("max_version", [VERSION, VERSION_V2, VERSION_V3])
def test_every_reader_generation_reads_v1(max_version):
    r = ContainerReader(_v1_blob(), max_version=max_version)
    names = [hdr.name for hdr, _ in r]
    assert names == ["w"]


def test_older_readers_reject_newer_blobs_with_versioned_error():
    cases = [(_v2_blob(), VERSION, 2), (_v3_blob(), VERSION, 3),
             (_v3_blob(), VERSION_V2, 3), (_v4_blob(), VERSION, 4),
             (_v4_blob(), VERSION_V2, 4), (_v4_blob(), VERSION_V3, 4)]
    for blob, max_version, written in cases:
        with pytest.raises(ValueError, match=f"version {written}"):
            ContainerReader(blob, max_version=max_version)


def test_v3_reader_roundtrips_v3():
    out = decode_state_dict(_v3_blob(), dequantize=False)
    assert np.array_equal(out["w"].levels,
                          (np.arange(90, dtype=np.int64) % 11) - 5)


def test_v3_chunk_streams_byte_identical_to_v1():
    # lane scheduling is header-only: the entropy-coded chunk payloads of
    # a v3 record must be the exact bytes a v1 record would carry
    lv = ((np.arange(200, dtype=np.int64) * 13) % 17) - 8
    v1 = encode_level_chunks(lv, 10, 64)
    v3, counts = encode_level_chunks_batched(lv, 10, 64)
    assert v1 == v3
    assert counts == [64, 64, 64, 8]


def test_every_current_reader_generation_reads_v4():
    r = ContainerReader(_v4_blob(), max_version=VERSION_V4)
    names = [hdr.name for hdr, _ in r]
    assert names == ["w"]


# -- reader error paths ------------------------------------------------------

def test_reader_rejects_short_input_with_descriptive_error():
    # regression: used to surface a bare struct.error / silent misparse on
    # inputs shorter than the 10-byte header
    for n in range(HEADER_LEN):
        with pytest.raises(ValueError, match="truncated DCBC container"):
            ContainerReader(b"\x00" * n)
        with pytest.raises(ValueError, match="truncated DCBC container"):
            ContainerReader(MAGIC[:min(n, 4)] + b"\x00" * max(0, n - 4))


def test_reader_rejects_bad_magic():
    with pytest.raises(ValueError, match="not a DCBC container"):
        ContainerReader(b"NOPE" + b"\x00" * 16)


def test_reader_rejects_unknown_future_version():
    blob = MAGIC + (9).to_bytes(2, "little") + (0).to_bytes(4, "little")
    with pytest.raises(ValueError, match="version 9"):
        ContainerReader(blob)


def test_reader_rejects_truncated_payload():
    blob = _v1_blob()
    with pytest.raises(ValueError, match="truncated DCBC record payload"):
        list(ContainerReader(blob[:-7]))


def test_reader_rejects_truncated_record_header():
    blob = _v3_blob()
    # cut inside the lane-metadata tables, before the payload length field
    with pytest.raises(ValueError, match="truncated DCBC record"):
        list(ContainerReader(blob[:HEADER_LEN + 20]))


# -- byte-range record reads (sharded-checkpoint manifest path) --------------

def _mixed_writer() -> ContainerWriter:
    w = ContainerWriter()
    lv = (np.arange(90, dtype=np.int64) % 11) - 5
    chunks, counts = encode_level_chunks_batched(lv, 10, 32)
    w.add_cabac_v3("w", "float32", (90,), 0.25, 10, 32, chunks, counts)
    w.add_raw("bias", np.arange(6, dtype=np.float32))
    w.add_q8("q", "float32", np.arange(-6, 6, dtype=np.int8).reshape(3, 4),
             np.ones(4, dtype=np.float32))
    return w


def test_record_spans_pread_every_record():
    """Each (offset, length) span must parse standalone via read_record_at
    and agree with the whole-container iterator — the contract the
    sharded manifest relies on to avoid mapping whole shard files."""
    w = _mixed_writer()
    blob = w.tobytes()
    spans = w.record_spans()
    assert len(spans) == 3
    assert spans[0][0] == HEADER_LEN
    assert spans[-1][0] + spans[-1][1] == len(blob)
    for (hdr_it, payload_it), (off, length) in zip(ContainerReader(blob),
                                                   spans):
        hdr, payload = read_record_at(blob[off:off + length])
        assert hdr == hdr_it
        assert bytes(payload) == bytes(payload_it)


def test_read_record_at_nonzero_offset():
    w = _mixed_writer()
    blob = w.tobytes()
    off, length = w.record_spans()[1]
    hdr, _ = read_record_at(b"\xaa" * 7 + blob[off:off + length], offset=7)
    assert hdr.name == "bias"


def test_read_record_at_rejects_truncated_shard_reads():
    """A shard file cut mid-record must fail loudly on the byte-range
    path, in both the header and the payload region."""
    w = _mixed_writer()
    blob = w.tobytes()
    off, length = w.record_spans()[0]
    rec = blob[off:off + length]
    with pytest.raises(ValueError, match="truncated DCBC record header"):
        read_record_at(rec[:10])
    with pytest.raises(ValueError, match="truncated DCBC record payload"):
        read_record_at(rec[:-3])
    with pytest.raises(ValueError, match="truncated DCBC record"):
        read_record_at(rec, offset=5)      # misaligned start
