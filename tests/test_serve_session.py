"""Request-level serving: scheduler behaviour (mixed prompt lengths,
staggered admission, EOS eviction), backend greedy-token equivalence, and
the container backend's layer-bound streaming load."""

import gc
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compression
from repro.configs import get_smoke_config
from repro.models.transformer import decode_step, init_params, prefill
from repro.serve.backends import available_backends, get_backend
from repro.serve.engine import ServeEngine
from repro.serve.quantized import (calibrate_kv_cache_delta, is_q8,
                                   quantize_params_for_serving)
from repro.serve.session import ServeConfig, ServeSession


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _isolated_greedy(cfg, params, prompt: np.ndarray, steps: int,
                     max_len: int = 64) -> list:
    """Reference: one request alone through the scalar-cache_pos path."""
    toks = jnp.asarray(prompt[None, :], jnp.int32)
    logits, caches = prefill(params, cfg, tokens=toks, max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    for k in range(steps - 1):
        logits, caches = decode_step(
            params, cfg, caches, int(prompt.size) + k,
            tokens=jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


# -- scheduler ---------------------------------------------------------------

def test_mixed_lengths_staggered_admission_matches_isolated(smoke):
    """5 requests with different prompt lengths through 2 KV slots: every
    request's continuous-batched tokens equal its isolated greedy decode,
    despite staggered admission and slot reuse."""
    cfg, params = smoke
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7, 12, 4)]
    session = ServeSession(cfg, params,
                           serve_cfg=ServeConfig(slots=2, max_len=64))
    handles = [session.submit(p, max_new_tokens=6) for p in prompts]
    assert session.num_queued == 5 and session.num_active == 0
    session.run()
    assert session.num_queued == 0 and session.num_active == 0
    for h, p in zip(handles, prompts):
        assert h.done and h.finish_reason == "length"
        assert list(h.result()) == _isolated_greedy(cfg, params, p, 6)


def test_token_streams_drain_incrementally(smoke):
    cfg, params = smoke
    session = ServeSession(cfg, params,
                           serve_cfg=ServeConfig(slots=1, max_len=32))
    h = session.submit(np.arange(4, dtype=np.int32), max_new_tokens=4)
    seen = []
    while session.pending:
        session.step()
        seen.extend(h.new_tokens())
    assert h.new_tokens() == []          # drained
    assert seen == list(h.result())
    assert len(seen) == 4


def test_eos_eviction_frees_slot_early(smoke):
    """A request that emits EOS is evicted immediately and its slot admits
    the next queued request, whose tokens still match isolated decode."""
    cfg, params = smoke
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    ref1 = _isolated_greedy(cfg, params, p1, 8)
    eos = ref1[3]                         # a token greedy decode will emit
    cut = ref1.index(eos) + 1             # ... first at this position
    session = ServeSession(
        cfg, params,
        serve_cfg=ServeConfig(slots=1, max_len=64, eos_token=eos))
    h1 = session.submit(p1, max_new_tokens=8)
    h2 = session.submit(p2, max_new_tokens=5)
    session.run()
    assert h1.finish_reason == "eos"
    assert list(h1.result()) == ref1[:cut]        # stops at (and keeps) EOS
    assert len(h1.tokens) < 8                     # evicted early
    assert h2.done
    # h2 ran in the slot h1 vacated; its stream must be unaffected
    ref2 = _isolated_greedy(cfg, params, p2, 5)
    expect2 = ref2[:ref2.index(eos) + 1] if eos in ref2 else ref2
    assert list(h2.result()) == expect2


def test_submit_validates_capacity(smoke):
    cfg, params = smoke
    session = ServeSession(cfg, params,
                           serve_cfg=ServeConfig(slots=1, max_len=16))
    with pytest.raises(ValueError):
        session.submit(np.zeros(12, np.int32), max_new_tokens=8)


def test_session_rejects_zero_slots(smoke):
    """slots=0 would make run() spin forever (nothing can ever admit)."""
    cfg, params = smoke
    with pytest.raises(ValueError, match="slots"):
        ServeSession(cfg, params, serve_cfg=ServeConfig(slots=0))


def test_submit_rejects_empty_prompt(smoke):
    cfg, params = smoke
    session = ServeSession(cfg, params,
                           serve_cfg=ServeConfig(slots=1, max_len=16))
    with pytest.raises(ValueError, match="at least one token"):
        session.submit(np.array([], np.int32), max_new_tokens=4)


def test_container_load_validates_against_template(smoke):
    """A blob for a different architecture fails at load time, not deep
    inside forward()."""
    cfg, params = smoke
    blob = compression.get("serve-q8").compress(params).blob
    other = get_smoke_config("qwen3-8b")
    with pytest.raises((ValueError, KeyError)):
        get_backend("container").load(other, blob)


def test_container_load_rejects_missing_tensors(smoke):
    cfg, params = smoke
    flat = compression.flatten_tree(params)
    flat.pop("embed")
    blob = compression.get("raw").compress(flat).blob
    with pytest.raises(KeyError, match="missing"):
        get_backend("container").load(cfg, blob)


def test_bucketed_prefill_matches_exact(smoke):
    """Padded-bucket admission (dense family): identical tokens to the
    exact-length prefill path — pad tokens are causally invisible and
    their stale KV is masked/overwritten."""
    cfg, params = smoke
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 5, 9, 14)]

    def run(buckets):
        session = ServeSession(
            cfg, params, serve_cfg=ServeConfig(slots=2, max_len=64,
                                               prefill_buckets=buckets))
        handles = [session.submit(p, max_new_tokens=6) for p in prompts]
        session.run()
        return [list(h.result()) for h in handles]

    assert run(()) == run((8, 16))


def test_prefill_buckets_rejected_for_stateful_families():
    cfg = get_smoke_config("mamba2-2.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="dense"):
        ServeSession(cfg, params,
                     serve_cfg=ServeConfig(slots=1, max_len=32,
                                           prefill_buckets=(16,)))


def test_temperature_sampling_reproducible(smoke):
    """Same seed -> same sampled tokens, across engine calls and fresh
    sessions alike."""
    cfg, params = smoke
    rng = np.random.default_rng(6)
    prompts = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    eng = ServeEngine(cfg, params, max_len=32)
    a = eng.generate(prompts, steps=6, temperature=1.0, seed=0)
    b = eng.generate(prompts, steps=6, temperature=1.0, seed=0)
    c = eng.generate(prompts, steps=6, temperature=1.0, seed=1)
    assert np.array_equal(a, b)           # reused session, same seed
    assert not np.array_equal(a, c)       # different seed re-rolls
    eng2 = ServeEngine(cfg, params, max_len=32)
    assert np.array_equal(a, eng2.generate(prompts, steps=6,
                                           temperature=1.0, seed=0))


def test_engine_wrapper_matches_session(smoke):
    """ServeEngine stays the one-shot batch API over the session."""
    cfg, params = smoke
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    eng = ServeEngine(cfg, params, max_len=32)
    out = eng.generate(prompts, steps=5)
    assert out.shape == (3, 13)
    assert np.array_equal(out[:, :8], prompts)
    for i in range(3):
        assert list(out[i, 8:]) == _isolated_greedy(
            cfg, params, prompts[i], 5, max_len=32)


# -- weight backends ---------------------------------------------------------

def test_backend_registry_lists_builtins():
    assert {"bf16", "q8", "container"} <= set(available_backends())
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_backends_identical_greedy_tokens(smoke):
    """Acceptance: bf16, q8 and container backends emit identical greedy
    tokens via ServeSession on weights representable on the q8 grid (the
    three paths then differ only in storage/dequant placement)."""
    cfg, params = smoke
    q8_tree = quantize_params_for_serving(params)
    # q8-grid-exact full-precision weights: dequantize the q8 leaves
    # (stacked (L, ..., out) scales broadcast per layer)

    def deq(leaf):
        if is_q8(leaf):
            q8, s = leaf["q8"], leaf["q8s"]
            if q8.ndim >= 3 and s.ndim == 2:
                s = s.reshape(s.shape[0], *([1] * (q8.ndim - 2)), s.shape[-1])
            return (q8.astype(jnp.float32) * s).astype(jnp.float32)
        return leaf
    fp_tree = jax.tree.map(deq, q8_tree, is_leaf=is_q8)
    blob = compression.get("serve-q8").compress(params).blob

    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 11, 8)]
    outs = {}
    for backend, src in [("bf16", fp_tree), ("q8", q8_tree),
                         ("container", blob)]:
        session = ServeSession(cfg, src, backend=backend,
                               serve_cfg=ServeConfig(slots=2, max_len=48))
        handles = [session.submit(p, max_new_tokens=8) for p in prompts]
        session.run()
        outs[backend] = [list(h.result()) for h in handles]
    assert outs["bf16"] == outs["q8"]
    assert outs["q8"] == outs["container"]


def test_container_backend_keeps_q8_records_int8(smoke):
    cfg, params = smoke
    blob = compression.get("serve-q8").compress(params).blob
    tree = get_backend("container").load(cfg, blob)
    assert is_q8(tree["layers"]["attn"]["wq"])
    assert tree["layers"]["attn"]["wq"]["q8"].dtype == jnp.int8
    assert not is_q8(tree["layers"]["attn_norm"])   # stays full precision


# -- streaming container load ------------------------------------------------

def test_iter_decompress_is_per_tensor_streaming():
    """The decode iterator yields one tensor at a time: holding only the
    current tensor keeps the python-heap peak near one record, far below
    the decoded total."""
    rng = np.random.default_rng(4)
    n_tensors, shape = 24, (64, 4096)           # 1 MiB fp32 each
    flat = {f"t{i:02d}": rng.standard_normal(shape).astype(np.float32)
            for i in range(n_tensors)}
    total = sum(v.nbytes for v in flat.values())
    blob = compression.get("raw").compress(flat).blob
    del flat
    gc.collect()

    seen = []
    tracemalloc.start()
    for name, arr in compression.iter_decompress(blob):
        seen.append((name, arr.shape))
        # arr dropped before the next record decodes
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert len(seen) == n_tensors
    assert all(s == shape for _, s in seen)
    assert peak < total / 4, (peak, total)


def test_container_backend_load_is_layer_bound(smoke, monkeypatch):
    """The container backend consumes the per-tensor iterator: peak decoded
    host memory during load stays bounded by the largest tensor (x a small
    transient factor), never the full fp32 tree."""
    cfg, _ = smoke
    big = cfg.replace(d_model=256, d_ff=1024, vocab_size=4096, num_layers=8)
    params = init_params(big, jax.random.PRNGKey(0))
    flat = compression.flatten_tree(params)
    total = sum(v.nbytes for v in flat.values())
    largest = max(v.nbytes for v in flat.values())
    assert total > 4 * largest, "fixture must discriminate layer vs model"
    blob = compression.get("raw").compress(flat).blob
    del flat, params
    gc.collect()

    import repro.serve.backends as backends
    pulled = []
    real_iter = backends.iter_decompress

    def spy(data, dequantize=True, **kw):
        for item in real_iter(data, dequantize=dequantize, **kw):
            pulled.append(item[0])
            yield item
    monkeypatch.setattr(backends, "iter_decompress", spy)

    tracemalloc.start()
    tree = get_backend("container").load(big, blob)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert pulled, "container backend must stream via iter_decompress"
    assert peak < total / 2, (peak, total)       # never the full fp32 tree
    assert peak < 3 * largest, (peak, largest)   # layer-bound transient
    assert tree["embed"].shape == (4096, 256)


def test_container_backend_cold_start_from_v3_blob(smoke):
    """Serving cold start from a lane-scheduled (container v3) deployment
    artifact: the streaming load routes every tensor's chunks through the
    batched lane decoder and must yield the same tree as decoding the
    equivalent v2 blob serially."""
    from repro.core.container import VERSION_V3, ContainerReader
    cfg, params = smoke
    v3 = compression.get("deepcabac-v3", delta_rel=1e-3).compress(params)
    v2 = compression.get("deepcabac-v2", delta_rel=1e-3).compress(params)
    assert ContainerReader(v3.blob).version == VERSION_V3
    t3 = get_backend("container").load(cfg, v3.blob)
    t2 = get_backend("container").load(cfg, v2.blob)
    for l3, l2 in zip(jax.tree.leaves(t3), jax.tree.leaves(t2)):
        assert l3.dtype == l2.dtype
        assert jnp.array_equal(l3, l2)


# -- KV-cache delta (satellite: configurable, calibrated) ---------------------

def test_kv_cache_delta_carried_by_serve_config(smoke):
    cfg, params = smoke
    session = ServeSession(
        cfg, params, serve_cfg=ServeConfig(slots=1, max_len=32,
                                           kv_cache_delta=0.031))
    assert session.cfg.kv_cache_delta == 0.031


def test_calibrated_delta_prevents_clipping(smoke):
    """The calibrated Delta covers the observed activation range (the fixed
    1/16 grid clips anything beyond |x| = 127/16 ~ 7.9)."""
    cfg, params = smoke
    tokens = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                           cfg.vocab_size))
    delta = calibrate_kv_cache_delta(cfg, params, tokens)
    # recompute the absmax the calibration saw: levels must fit in int8
    from repro.models.transformer import init_cache
    _, caches = prefill(params, cfg.replace(q8_cache=False),
                        tokens=jnp.asarray(tokens), max_len=16)
    template = init_cache(cfg.replace(q8_cache=True), 2, 16)
    amax = max(float(jnp.max(jnp.abs(g)))
               for g, w in zip(jax.tree.leaves(caches),
                               jax.tree.leaves(template))
               if w.dtype == jnp.int8)
    assert amax / delta <= 127.0
    assert delta >= amax / 127.0


def test_q8_cache_decode_respects_config_delta(smoke):
    """Same weights, two deltas: the int8 cache grid actually changes, and
    a sane calibrated delta keeps decode finite."""
    cfg, params = smoke
    qcfg = cfg.replace(q8_cache=True, kv_cache_delta=0.02)
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 8), 0,
                              cfg.vocab_size)
    _, caches_a = prefill(params, qcfg, tokens=toks, max_len=12)
    _, caches_b = prefill(params, qcfg.replace(kv_cache_delta=0.08),
                          tokens=toks, max_len=12)
    ka = np.asarray(caches_a["k"], np.int32)
    kb = np.asarray(caches_b["k"], np.int32)
    assert ka.dtype == np.int32 and not np.array_equal(ka, kb)
    lg, _ = decode_step(params, qcfg, caches_a, 8,
                        tokens=toks[:, 0])
    assert np.all(np.isfinite(np.asarray(lg)))
