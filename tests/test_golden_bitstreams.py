"""Golden-bitstream pins for the DCBC wire format (v1 / v2 / v3 / v4).

Encoding must stay byte-exact against the committed fixtures and every
fixture must decode to exactly the values its generator quantized — any
drift in the range coder, binarization, or container layout fails here
before it can corrupt checkpoints in the wild.
"""

import importlib.util
import os

import numpy as np
import pytest

from repro.core.codec import (DecodeOptions, decode_delta_record,
                              decode_record, decode_state_dict,
                              decode_state_dict_batched, resolve_dtype)
from repro.core.container import (ENC_CABAC_DELTA, VERSION, VERSION_V2,
                                  VERSION_V3, VERSION_V4, ContainerReader)

_spec = importlib.util.spec_from_file_location(
    "gen_goldens",
    os.path.join(os.path.dirname(__file__), "golden", "gen_goldens.py"))
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)


@pytest.mark.parametrize("name", sorted(gg.BUILDERS))
def test_encode_is_byte_exact(name):
    assert gg.BUILDERS[name]() == gg.load_fixture(name), (
        f"{name}: encoder output drifted from the golden fixture; if the "
        f"format change is intentional, bump the container version and "
        f"regenerate via tests/golden/gen_goldens.py")


def test_golden_versions():
    assert ContainerReader(gg.load_fixture("v1_basic")).version == VERSION
    assert ContainerReader(gg.load_fixture("v2_mixed")).version == VERSION_V2
    assert ContainerReader(gg.load_fixture("v3_lanes")).version == VERSION_V3
    assert ContainerReader(gg.load_fixture("v4_delta")).version == VERSION_V4


def test_v1_golden_decodes_exactly():
    out = decode_state_dict(gg.load_fixture("v1_basic"), dequantize=False)
    ref = gg.v1_entries()
    assert np.array_equal(out["w"].levels, ref["w"].levels)
    assert out["w"].step == ref["w"].step
    assert out["w_bf16"].dtype == "bfloat16"
    assert np.array_equal(out["w_bf16"].levels, ref["w_bf16"].levels)
    assert np.array_equal(out["bias"], ref["bias"])


def test_v2_golden_decodes_exactly():
    out = decode_state_dict(gg.load_fixture("v2_mixed"), dequantize=False)
    huff_levels, q8_levels, q8_scale, cabac_levels = gg.v2_parts()
    assert np.array_equal(out["huf"].levels.ravel(), huff_levels)
    assert out["huf"].step == 0.25
    assert np.array_equal(out["q8"].levels, q8_levels)
    assert np.array_equal(out["q8"].scale, q8_scale)
    assert np.array_equal(out["cab"].levels, cabac_levels)


@pytest.mark.parametrize("path", ["stream", "batched", "scalar"])
def test_v3_golden_decodes_exactly_on_every_path(path):
    blob = gg.load_fixture("v3_lanes")
    big, small = gg.v3_parts()
    if path == "stream":
        out = decode_state_dict(blob, dequantize=False)
    elif path == "batched":
        out = decode_state_dict_batched(blob, dequantize=False)
    else:
        out = decode_state_dict(blob, dequantize=False,
                                opts=DecodeOptions(backend="scalar"))
    assert np.array_equal(out["big"].levels.ravel(), big)
    assert out["big"].step == 0.125
    assert np.array_equal(out["small"].levels, small)
    assert out["small"].dtype == "bfloat16"
    assert out["raw"].dtype == resolve_dtype("float32")
    assert np.array_equal(out["raw"].ravel(),
                          np.arange(6, dtype=np.float32) / 8)


@pytest.mark.parametrize("backend", ["auto", "numpy", "scalar"])
def test_v4_golden_decodes_exactly_on_every_path(backend):
    base, resid, intra = gg.v4_parts()
    opts = DecodeOptions(backend=backend)
    out = {}
    for hdr, payload in ContainerReader(gg.load_fixture("v4_delta")):
        if hdr.encoding == ENC_CABAC_DELTA:
            out[hdr.name] = decode_delta_record(hdr, bytes(payload), base,
                                                dequantize=False, opts=opts)
        else:
            out[hdr.name] = decode_record(hdr, bytes(payload),
                                          dequantize=False, opts=opts)
    assert np.array_equal(out["delta"].levels.ravel(), base + resid)
    assert out["delta"].step == 0.125
    assert out["delta"].shape == (20, 15)
    assert np.array_equal(out["intra"].levels, intra)
    assert out["intra"].dtype == "bfloat16"


def test_v4_delta_record_rejects_standalone_decode():
    # residuals are meaningless without the base frame; the stream decoder
    # must say so instead of emitting garbage levels
    blob = gg.load_fixture("v4_delta")
    with pytest.raises(ValueError, match="cannot be decoded standalone"):
        decode_state_dict(blob, dequantize=False)
    hdr, payload = next(iter(ContainerReader(blob)))
    base, _, _ = gg.v4_parts()
    with pytest.raises(ValueError, match="against a base of"):
        decode_delta_record(hdr, bytes(payload), base[:-1], dequantize=False)


def test_v3_reader_reads_v1_and_v2_unchanged():
    # the v3-capable reader is the only reader; pinning that it yields
    # identical results on v1/v2 fixtures is the forward-compat half of
    # the matrix (the backward half lives in test_container_compat.py)
    for name in ("v1_basic", "v2_mixed"):
        blob = gg.load_fixture(name)
        a = decode_state_dict(blob, dequantize=False)
        b = decode_state_dict_batched(blob, dequantize=False)
        assert sorted(a) == sorted(b)
        for k in a:
            la = getattr(a[k], "levels", a[k])
            lb = getattr(b[k], "levels", b[k])
            assert np.array_equal(la, lb), k
