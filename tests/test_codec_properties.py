"""Hypothesis property suite for the codec layer.

Round-trips ``encode_state_dict``/``iter_decode_state_dict`` (and the v3
lane-scheduled records) over random dtypes (incl. bfloat16), degenerate
shapes (empty, scalar, 1-element, non-multiple-of-chunk), adversarial
level distributions (all-zero, single spike, max-magnitude) and chunk
sizes.  Deterministic edge-case pins live at the bottom so the module
keeps guarding the format when hypothesis isn't installed (the
``_hypothesis_compat`` shim skips only the ``@given`` tests).
"""

import os

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import binarization as B
from repro.core import cabac_vec
from repro.core.cabac import RangeEncoder, temporal_classes
from repro.core.codec import (DecodeOptions, QuantizedTensor,
                              decode_delta_chunks_batched, decode_delta_record,
                              decode_state_dict, decode_state_dict_batched,
                              encode_delta_chunks_batched, encode_level_chunks,
                              encode_level_chunks_batched, encode_state_dict,
                              resolve_dtype)
from repro.core.container import ContainerReader, ContainerWriter

SHAPES = [(), (0,), (1,), (5,), (37,), (130,), (3, 4), (2, 3, 4), (16, 17)]
DTYPES = ["float32", "float64", "float16", "bfloat16"]
PROFILES = ["random", "zeros", "spike", "max"]
CHUNKS = [1, 3, 16, 100, 1 << 16]
# widest magnitude the lane engines accept (scalar goes to int64 extremes,
# pinned deterministically below)
WIDE = 1 << 40


def _ex(n: int) -> int:
    """Example budget: scaled by ``REPRO_HYPOTHESIS_X`` (the nightly CI
    job sets 8, with ``--hypothesis-seed=random``) so the scheduled fuzz
    digs an order of magnitude deeper than the per-push smoke."""
    return n * int(os.environ.get("REPRO_HYPOTHESIS_X", "1"))


def _levels(shape, profile, seed):
    n = int(np.prod(shape)) if shape else 1
    rng = np.random.default_rng(seed)
    if profile == "zeros":
        flat = np.zeros(n, dtype=np.int64)
    elif profile == "spike":
        flat = np.zeros(n, dtype=np.int64)
        if n:
            flat[n // 2] = -WIDE
    elif profile == "max":
        flat = np.where(np.arange(n) % 2 == 0, WIDE, -WIDE).astype(np.int64)
    else:
        flat = (rng.standard_t(2, n) * 5).astype(np.int64)
    return flat.reshape(shape)


def _v3_blob(qt: QuantizedTensor, num_gr: int, chunk: int) -> bytes:
    chunks, counts = encode_level_chunks_batched(qt.levels, num_gr, chunk)
    w = ContainerWriter()
    w.add_cabac_v3("t", qt.dtype, qt.shape, qt.step, num_gr, chunk,
                   chunks, counts)
    return w.tobytes()


@settings(max_examples=_ex(30), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(DTYPES),
       shape=st.sampled_from(SHAPES),
       profile=st.sampled_from(PROFILES),
       chunk=st.sampled_from(CHUNKS),
       num_gr=st.sampled_from([1, 10]),
       container=st.sampled_from(["v1", "v3"]))
def test_roundtrip_any_record(seed, dtype, shape, profile, chunk, num_gr,
                              container):
    levels = _levels(shape, profile, seed)
    step = float(np.random.default_rng(seed).random() + 1e-3)
    qt = QuantizedTensor(levels, step, dtype)
    if container == "v1":
        blob = encode_state_dict({"t": qt}, num_gr=num_gr, chunk_size=chunk)
    else:
        blob = _v3_blob(qt, num_gr, chunk)
    out = decode_state_dict(blob, dequantize=False)["t"]
    assert np.array_equal(out.levels, levels)
    assert out.step == step and out.dtype == dtype
    deq = decode_state_dict(blob, dequantize=True)["t"]
    assert deq.dtype == resolve_dtype(dtype)
    assert deq.shape == levels.shape


@settings(max_examples=_ex(20), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       shape=st.sampled_from(SHAPES),
       profile=st.sampled_from(PROFILES),
       chunk=st.sampled_from(CHUNKS),
       lanes=st.sampled_from([1, 2, 64]))
def test_v3_batched_paths_agree(seed, shape, profile, chunk, lanes):
    # stream / whole-container batch / scalar residual must be identical
    levels = _levels(shape, profile, seed)
    blob = _v3_blob(QuantizedTensor(levels, 0.5, "float32"), 10, chunk)
    stream = decode_state_dict(
        blob, dequantize=False, opts=DecodeOptions(lanes=lanes))["t"]
    batched = decode_state_dict_batched(
        blob, dequantize=False, opts=DecodeOptions(lanes=lanes))["t"]
    scalar = decode_state_dict(
        blob, dequantize=False, opts=DecodeOptions(backend="scalar"))["t"]
    assert np.array_equal(stream.levels, levels)
    assert np.array_equal(batched.levels, levels)
    assert np.array_equal(scalar.levels, levels)


@settings(max_examples=_ex(20), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from(CHUNKS),
       num_gr=st.sampled_from([1, 10]),
       backend=st.sampled_from(["numpy", "auto"]))
def test_batched_encode_byte_equal_to_serial(seed, chunk, num_gr, backend):
    levels = (np.random.default_rng(seed).standard_t(2, 333) * 9).astype(
        np.int64)
    assert (encode_level_chunks_batched(levels, num_gr, chunk,
                                        backend=backend)[0]
            == encode_level_chunks(levels, num_gr, chunk))


@settings(max_examples=_ex(15), deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mixed_state_dict_roundtrip(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 9, size=rng.integers(1, 4)))
    entries = {
        "q": QuantizedTensor((rng.standard_t(2, shape) * 4).astype(np.int64),
                             0.25, "bfloat16"),
        "raw_f32": rng.standard_normal(shape).astype(np.float32),
        "raw_i32": rng.integers(-5, 5, shape).astype(np.int32),
    }
    out = decode_state_dict(encode_state_dict(entries), dequantize=False)
    assert np.array_equal(out["q"].levels, entries["q"].levels)
    assert np.array_equal(out["raw_f32"], entries["raw_f32"])
    assert np.array_equal(out["raw_i32"], entries["raw_i32"])


# -- temporal-context delta records (ENC_CABAC_DELTA) ------------------------

def _delta_blob(resid: np.ndarray, base: np.ndarray, step: float, dtype: str,
                num_gr: int, chunk: int) -> bytes:
    chunks, counts = encode_delta_chunks_batched(resid, base, num_gr, chunk)
    w = ContainerWriter()
    w.add_cabac_delta("t", dtype, np.asarray(resid).shape, step, num_gr,
                      chunk, chunks, counts)
    return w.tobytes()


@settings(max_examples=_ex(25), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(DTYPES),
       shape=st.sampled_from(SHAPES),
       base_profile=st.sampled_from(PROFILES),
       resid_profile=st.sampled_from(PROFILES),
       chunk=st.sampled_from(CHUNKS),
       num_gr=st.sampled_from([1, 10]),
       backend=st.sampled_from(["auto", "numpy", "scalar"]))
def test_delta_record_roundtrip_any_backend(seed, dtype, shape, base_profile,
                                            resid_profile, chunk, num_gr,
                                            backend):
    # the base picks the context classes, the residual is the coded signal —
    # fuzz both independently so every (class, magnitude) pairing shows up
    base = _levels(shape, base_profile, seed).ravel()
    resid = _levels(shape, resid_profile, seed + 1)
    blob = _delta_blob(resid, base, 0.5, dtype, num_gr, chunk)
    hdr, payload = next(iter(ContainerReader(blob)))
    out = decode_delta_record(hdr, bytes(payload), base, dequantize=False,
                              opts=DecodeOptions(backend=backend))
    assert np.array_equal(out.levels, base.reshape(shape) + resid)
    assert out.step == 0.5 and out.dtype == dtype


@settings(max_examples=_ex(15), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 4),
       chunk=st.sampled_from(CHUNKS),
       backend=st.sampled_from(["auto", "numpy", "scalar"]))
def test_chained_deltas_bit_identical_to_direct_levels(seed, k, chunk,
                                                       backend):
    # base + k chained P-frames must reconstruct the last frame's integer
    # levels exactly (zero drift) — the property the checkpoint chain
    # restore relies on
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 300))
    frames = [(rng.standard_t(2, n) * 5).astype(np.int64)]
    for _ in range(k):
        frames.append(frames[-1] + rng.integers(-3, 4, n).astype(np.int64))
    cur = frames[0]
    opts = DecodeOptions(backend=backend)
    for prev, new in zip(frames, frames[1:]):
        blob = _delta_blob(new - prev, prev, 0.25, "float32", 10, chunk)
        hdr, payload = next(iter(ContainerReader(blob)))
        cur = decode_delta_record(hdr, bytes(payload), cur, dequantize=False,
                                  opts=opts).levels.ravel()
    assert np.array_equal(cur, frames[-1])


@settings(max_examples=_ex(15), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from(CHUNKS),
       num_gr=st.sampled_from([1, 10]),
       backend=st.sampled_from(["numpy", "auto"]))
def test_delta_encode_backends_byte_equal(seed, chunk, num_gr, backend):
    rng = np.random.default_rng(seed)
    base = (rng.standard_t(2, 257) * 5).astype(np.int64)
    resid = rng.integers(-5, 6, 257).astype(np.int64)
    got = encode_delta_chunks_batched(resid, base, num_gr, chunk,
                                      backend=backend)[0]
    # scalar reference coder, chunk by chunk
    cls = temporal_classes(base)
    want = []
    for s in range(0, 257, chunk):
        enc = RangeEncoder(B.make_contexts_tc(num_gr))
        B.encode_levels_tc(enc, resid[s:s + chunk], cls[s:s + chunk], num_gr)
        want.append(enc.finish())
    assert got == want


# -- deterministic pins (run with or without hypothesis) ---------------------

def test_delta_empty_and_scalar_shapes_roundtrip():
    for shape in [(), (0,), (1,)]:
        base = np.zeros(shape, dtype=np.int64).ravel()
        resid = np.zeros(shape, dtype=np.int64)
        blob = _delta_blob(resid, base, 0.5, "float32", 10, 16)
        hdr, payload = next(iter(ContainerReader(blob)))
        out = decode_delta_record(hdr, bytes(payload), base,
                                  dequantize=False)
        assert out.levels.shape == shape
        assert np.array_equal(out.levels, np.zeros(shape, dtype=np.int64))


def test_wide_delta_residuals_fall_back_to_scalar_tc_decoder():
    # residuals past the lane limit must still decode via the OverflowError
    # -> scalar fallback, mirroring the intra v3 contract
    base = np.array([0, 3, 40], dtype=np.int64)
    resid = np.array([1 << 62, -(1 << 62), 7], dtype=np.int64)
    cls = temporal_classes(base)
    enc = RangeEncoder(B.make_contexts_tc(10))
    B.encode_levels_tc(enc, resid, cls, 10)
    out = decode_delta_chunks_batched([enc.finish()], [3], base, 10,
                                      DecodeOptions(backend="auto"))
    assert np.array_equal(out, resid)

def test_scalar_path_survives_int64_extremes():
    lv = np.array([np.iinfo(np.int64).max, 0, np.iinfo(np.int64).min + 1],
                  dtype=np.int64)
    chunks = encode_level_chunks(lv, 10, 8)
    got = decode_state_dict(
        encode_state_dict({"t": QuantizedTensor(lv, 1.0)}),
        dequantize=False,
        opts=DecodeOptions(backend="scalar"))["t"]
    assert np.array_equal(got.levels, lv)
    assert len(chunks) == 1


def test_empty_and_scalar_shapes_roundtrip_v3():
    for shape in [(), (0,), (1,)]:
        levels = np.zeros(shape, dtype=np.int64)
        blob = _v3_blob(QuantizedTensor(levels, 0.5, "float32"), 10, 16)
        out = decode_state_dict_batched(blob, dequantize=False)["t"]
        assert out.levels.shape == shape
        assert np.array_equal(out.levels, levels)


def test_wide_levels_exceeding_lane_limit_use_scalar_coder():
    lv = np.array([1 << 62], dtype=np.int64)
    try:
        cabac_vec.encode_lanes([lv])
        raised = False
    except OverflowError:
        raised = True
    assert raised
    # ... while the scalar coder of the v1/v2 path still round-trips them
    out = decode_state_dict(
        encode_state_dict({"t": QuantizedTensor(lv, 1.0)}),
        dequantize=False, opts=DecodeOptions(backend="scalar"))["t"]
    assert np.array_equal(out.levels, lv)
