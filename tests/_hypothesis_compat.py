"""Optional-hypothesis shim.

Importing this instead of ``hypothesis`` directly lets a module's
deterministic tests keep running when hypothesis isn't installed — only
the ``@given`` property tests skip, instead of a module-level
``importorskip`` taking the whole file down.
"""

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``st``: strategy expressions evaluate to None at
        decoration time; the test never runs."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="needs hypothesis "
                   "(pip install -r requirements-dev.txt)")(f)
