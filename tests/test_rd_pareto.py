"""RD Pareto harness + unified registry entry point.

Covers the `repro.compression.rd_search` sweep (lambda-monotone bytes,
Pareto-front dominance marking, the TensorPolicy artifact's JSON round
trip), the `deepcabac-rd` codec (bit-exact container round trip under a
policy table, policy-aware backend loads matching the container
reconstruction), and the unified `get(name, *, strict=True, **overrides)`
registry API (typo'd overrides raise; `strict=False` records the drop;
the deprecated `make` shim stays behaviorally identical across every
registered codec).
"""

import json
import warnings

import numpy as np
import pytest

from repro import compression
from repro.compression.rd_search import (RDPoint, RDSearchConfig,
                                         TensorPolicy, TensorRule,
                                         pareto_front, rd_assign_levels,
                                         resolve_policy)
from repro.core.rate_model import estimate_level_bits


# ---------------------------------------------------------------------------
# The sweep on a smoke config (shared: it is the expensive part)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep():
    import jax
    from repro import configs
    from repro.compression.rd_search import rd_sweep
    from repro.models.transformer import init_params

    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    search = RDSearchConfig(delta_rels=(1e-3, 6e-3), lambdas=(0.0, 1e-5),
                            prompts=2, prompt_len=8, decode_steps=4,
                            fim_batches=0)
    return cfg, params, rd_sweep(cfg, params, search)


def test_lambda_monotone_bytes(sweep):
    """Higher lambda at a fixed grid step never costs more bytes — the
    rate term only ever pushes levels toward cheaper codes."""
    _, _, res = sweep
    by_dr = {}
    for p in res.points:
        by_dr.setdefault(p.delta_rel, []).append(p)
    assert len(by_dr) > 1
    for dr, pts in by_dr.items():
        pts = sorted(pts, key=lambda p: p.lam)
        sizes = [p.bytes for p in pts]
        assert sizes == sorted(sizes, reverse=True) or all(
            a >= b for a, b in zip(sizes, sizes[1:])), (
            f"bytes not non-increasing in lambda at delta_rel={dr}: {sizes}")


def test_pareto_front_marking(sweep):
    _, _, res = sweep
    front = [p for p in res.points if p.on_front]
    assert front, "empty Pareto front"
    for p in front:
        assert not any(
            q is not p and q.bytes <= p.bytes
            and (q.token_err, q.logit_kl) <= (p.token_err, p.logit_kl)
            and (q.bytes < p.bytes
                 or (q.token_err, q.logit_kl) < (p.token_err, p.logit_kl))
            for q in res.points), "dominated point marked on_front"
    assert res.winner.on_front


def test_pareto_front_function():
    pts = [RDPoint(1e-3, 0.0, 100, 0.0, 1.0),
           RDPoint(1e-3, 1e-4, 80, 0.0, 2.0),
           RDPoint(6e-3, 0.0, 90, 0.0, 3.0),   # dominated by the 80-byte pt
           RDPoint(6e-3, 1e-4, 80, 0.5, 0.5)]  # dominated too: token_err is
    front = pareto_front(pts)                  # the primary distortion key
    assert [p.bytes for p in front] == [80, 100]
    assert not pts[2].on_front and not pts[3].on_front
    assert pts[0].on_front and pts[1].on_front


def test_policy_json_roundtrip(tmp_path, sweep):
    _, _, res = sweep
    path = tmp_path / "policy.json"
    res.policy.save(path)
    loaded = TensorPolicy.load(path)
    assert loaded.rules == res.policy.rules
    assert loaded.meta == res.policy.meta
    # the dict payload round-trips through plain json too
    again = resolve_policy(json.loads(json.dumps(res.policy.to_dict())))
    assert again.rules == res.policy.rules


def test_policy_rejects_foreign_payloads():
    with pytest.raises(ValueError):
        TensorPolicy.from_dict({"rules": {}})          # no format tag
    with pytest.raises(ValueError):
        TensorRule(step=0.1, kind="float4")            # unknown kind
    with pytest.raises(TypeError):
        resolve_policy(42)


def test_rd_container_roundtrip_bit_exact(sweep):
    """Same policy table -> byte-identical containers, and the decoded
    levels match the encoder's quantized entries exactly."""
    cfg, params, res = sweep
    codec = compression.get("deepcabac-rd", policy_table=res.policy)
    art1 = codec.compress(params)
    art2 = compression.get("deepcabac-rd",
                           policy_table=res.policy.to_dict()).compress(params)
    assert art1.blob == art2.blob
    assert len(art1.blob) == res.policy_bytes

    dec = compression.decompress(art1.blob, dequantize=False)
    for name, e in art1.quantized.items():
        if isinstance(e, np.ndarray):
            np.testing.assert_array_equal(np.asarray(dec[name]), e,
                                          err_msg=name)
        else:
            assert dec[name].step == e.step, name
            np.testing.assert_array_equal(dec[name].levels, e.levels,
                                          err_msg=name)


def test_policy_backend_matches_container(sweep):
    """A pytree load through a policy-aware backend equals the
    deepcabac-rd container's reconstruction leaf for leaf."""
    from repro.serve.backends import get_backend

    cfg, params, res = sweep
    art = compression.get("deepcabac-rd",
                          policy_table=res.policy).compress(params)
    from_blob = compression.decompress(art.blob, like=params)
    from_tree = get_backend("bf16", policy_table=res.policy).load(cfg, params)
    flat_blob = compression.flatten_tree(from_blob)
    flat_tree = compression.flatten_tree(from_tree)
    assert set(flat_blob) == set(flat_tree)
    for name in flat_blob:
        np.testing.assert_array_equal(np.asarray(flat_blob[name]),
                                      np.asarray(flat_tree[name]),
                                      err_msg=name)


def test_refinement_respects_budget(sweep):
    _, _, res = sweep
    assert res.policy_token_err <= max(res.winner.token_err, 0.0)
    if res.refined_tensors and not res.reverted:
        assert res.policy_bytes <= res.winner.bytes


# ---------------------------------------------------------------------------
# rd_assign_levels + rate proxy (no sweep needed)
# ---------------------------------------------------------------------------

def test_rd_assign_levels_matches_oracle():
    from repro.core.deepcabac import quantize_tensor_rd
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((64, 32)) * 0.1).astype(np.float32)
    for lam in (0.0, 1e-4):
        got = rd_assign_levels(w, 0.01, lam, assign="host")
        ref = quantize_tensor_rd(w, 0.01, lam)
        np.testing.assert_array_equal(got, ref.levels)


def test_estimate_level_bits_orders_rates():
    rng = np.random.default_rng(1)
    fine = np.rint(rng.standard_normal(4096) * 40).astype(np.int64)
    coarse = np.rint(rng.standard_normal(4096) * 4).astype(np.int64)
    assert estimate_level_bits(fine) > estimate_level_bits(coarse) > 0
    assert estimate_level_bits(np.zeros(0, np.int64)) == 0.0


# ---------------------------------------------------------------------------
# Unified registry entry point (the api_redesign satellite + bugfix)
# ---------------------------------------------------------------------------

def _tiny_tree():
    rng = np.random.default_rng(2)
    return {"w": (rng.standard_normal((16, 16)) * 0.1).astype(np.float32)}


def test_get_rejects_typoed_override():
    """The historical silent-drop bug: `lamda` must raise, not vanish."""
    with pytest.raises(TypeError, match="lamda"):
        compression.get("deepcabac-v3", lamda=0.1)
    with pytest.raises(TypeError, match="strict=False"):
        compression.get("ckpt-nearest", delta_rell=1e-3)


def test_nonstrict_get_records_drop():
    codec = compression.get("deepcabac-v3", strict=False, lamda=0.1,
                            delta_rel=2e-3)
    assert codec.hyperparams["dropped_overrides"] == ["lamda"]
    assert codec.hyperparams["delta_rel"] == 2e-3
    # the drop survives into the artifact a save would write
    art = codec.compress(_tiny_tree())
    assert art.hyperparams["dropped_overrides"] == ["lamda"]


def test_strict_get_keeps_hyperparams_clean():
    codec = compression.get("deepcabac-v3", delta_rel=2e-3)
    assert "dropped_overrides" not in codec.hyperparams


def test_deepcabac_rd_requires_policy_table():
    with pytest.raises(ValueError, match="policy_table"):
        compression.get("deepcabac-rd")


def test_make_shim_parity_every_codec():
    """`make(name, **kw)` stays behaviorally identical to
    `get(name, strict=False, **kw)` for every registered codec — same
    type, same hyperparams (dropped-override log included) — and warns."""
    probe = {"delta_rel": 2e-3, "bogus_override": 1}
    for name in compression.available():
        if name == "deepcabac-rd":
            # requires policy_table; parity is raising the same error
            with pytest.raises(ValueError):
                compression.get(name, strict=False)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                with pytest.raises(ValueError):
                    compression.make(name)
            continue
        via_get = compression.get(name, strict=False, **probe)
        with pytest.warns(DeprecationWarning):
            via_make = compression.make(name, **probe)
        assert type(via_make) is type(via_get), name
        if hasattr(via_get, "hyperparams"):
            assert via_make.hyperparams == via_get.hyperparams, name


def test_checkpoint_manager_records_drop(tmp_path):
    """The manager's generic-config forwarding logs inapplicable knobs
    instead of silently eating them."""
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             codec="serve-q8"))
    codec = mgr._codec()
    assert codec.hyperparams["dropped_overrides"] == ["delta_rel",
                                                      "min_ndim"]
