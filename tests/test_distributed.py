"""Distributed substrate: sharding-rule resolution, 8-bit optimizer,
error-feedback gradient compression, compressed cross-pod collective."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compress import (CompressionConfig,
                                        code_entropy_bits_per_param,
                                        ef_compress_update,
                                        init_error_feedback)
from repro.distributed.sharding import (DEFAULT_RULES, SERVE_RULES,
                                        logical_axes_for_path, spec_for)
from repro.optim.adamw import (AdamWConfig, _q8_decode, _q8_encode,
                               adamw_init, adamw_update)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv dim 8 not divisible by 16 -> replicated
    s = spec_for((4096, 8, 128), ("fsdp", "kv_heads", None), mesh)
    assert s == P("data", None, None)
    # heads 32 divisible -> sharded
    s = spec_for((4096, 32, 128), ("fsdp", "heads", None), mesh)
    assert s == P("data", "model", None)


def test_spec_missing_axis_dropped():
    mesh = FakeMesh({"data": 4, "model": 2})   # no 'pod'
    s = spec_for((64, 128), ("batch", None), mesh)
    assert s == P("data", None)


def test_moment_suffix_inherits_param_rule():
    axes_p = logical_axes_for_path("moments/layers/attn/wq", 3)
    axes_m = logical_axes_for_path("moments/layers/attn/wq/m", 3)
    axes_q = logical_axes_for_path("moments/layers/attn/wq/m_q", 3)
    assert axes_p == axes_m == axes_q == (None, "fsdp", "tp")


def test_serve_rules_disable_fsdp():
    mesh = FakeMesh({"data": 16, "model": 16})
    s_train = spec_for((4096, 14336), ("fsdp", "tp"), mesh, DEFAULT_RULES)
    s_serve = spec_for((4096, 14336), ("fsdp", "tp"), mesh, SERVE_RULES)
    assert s_train == P("data", "model")
    assert s_serve == P(None, "model")


# -- 8-bit moments -------------------------------------------------------------

def test_q8_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 256)) * 0.1, jnp.float32)
    codes, scale = _q8_encode(x)
    back = _q8_decode(codes, scale)
    blockmax = np.abs(np.asarray(x)).reshape(64, 2, 128).max(-1)
    tol = (blockmax / 127.0).max()
    assert float(jnp.max(jnp.abs(back - x))) <= tol + 1e-7


@pytest.mark.parametrize("quant", [False, True])
def test_adamw_converges_quadratic(quant):
    target = jnp.asarray(np.random.default_rng(1).standard_normal((4, 128)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 128), jnp.float32)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, quantized_moments=quant)
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.mean(jnp.square(p["w"] - target))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


# -- error-feedback gradient compression ---------------------------------------

def test_ef_compression_unbiased_accumulation():
    """EF-quantized GD converges on a quadratic despite int8 grads, and
    beats the same quantization without error feedback."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)

    def run(use_ef: bool):
        params = {"w": jnp.zeros((8, 128), jnp.float32)}
        cfg = CompressionConfig(enabled=True, ef_decay=1.0 if use_ef else 0.0)
        ef = init_error_feedback(params)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            gq, ef = ef_compress_update(g, ef, cfg)
            params = {"w": params["w"] - 0.2 * gq["w"]}
        return float(jnp.mean(jnp.square(params["w"] - target)))

    err_ef = run(True)
    err_no = run(False)
    assert err_ef < 1e-4, err_ef
    assert err_ef <= err_no


def test_ef_disabled_passthrough():
    g = {"w": jnp.ones((4, 128))}
    ef = init_error_feedback(g)
    out, ef2 = ef_compress_update(g, ef, CompressionConfig(enabled=False))
    assert out is g


def test_cross_pod_psum_compressed():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (see test_dryrun_mini subprocess)")


def test_cross_pod_shape_contract_validated():
    """The collective's contract is explicit: x must lead with the pod axis
    (one partial sum per pod); anything else is rejected up front instead
    of silently mis-summing via the old ndim-based keepdims branch."""
    from repro.distributed.compress import cross_pod_psum_compressed
    mesh = FakeMesh({"pod": 2, "data": 2})
    with pytest.raises(ValueError, match="pod axis"):
        cross_pod_psum_compressed(jnp.ones((3, 4, 128)), mesh)
    with pytest.raises(ValueError, match="pod axis"):
        cross_pod_psum_compressed(jnp.ones(()), mesh)


def test_code_entropy_reporting():
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(-10, 10, 10000), jnp.int8)
    bits = code_entropy_bits_per_param(codes)
    assert 0 < bits <= 8
