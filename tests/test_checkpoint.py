"""Checkpoint manager: round-trip fidelity, compression, retention,
atomicity, elastic (resharded) restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      flatten_tree, unflatten_like)
from repro.configs import get_smoke_config
from repro.distributed.sharding import build_param_specs, named_shardings
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state


def _state(seed=0, quant=False):
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_train_state(cfg, AdamWConfig(quantized_moments=quant),
                                 seed=seed)


def test_roundtrip_raw(tmp_path):
    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             params_mode="raw"))
    mgr.save(state, 7)
    restored, meta = mgr.restore(state)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_cabac_bounded_error(tmp_path):
    cfg, state = _state()
    delta_rel = 1e-3
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             params_mode="cabac",
                                             delta_rel=delta_rel))
    mgr.save(state, 1)
    restored, meta = mgr.restore(state)
    assert meta["params_compressed_bytes"] < meta["params_raw_bytes"]
    for (pa, a), (pb, b) in zip(
            flatten_tree(state["params"]).items(),
            flatten_tree(restored["params"]).items()):
        if a.ndim >= 2:
            step = delta_rel * a.astype(np.float64).std()
            # step/2 from rounding + f32 dequantization rounding slack
            assert np.max(np.abs(a.astype(np.float64)
                                 - b.astype(np.float64))) <= \
                step / 2 * (1 + 1e-3) + 1e-7
        else:
            np.testing.assert_array_equal(a, b)
    # optimizer state is exact
    np.testing.assert_array_equal(
        np.asarray(state["step"]), np.asarray(restored["step"]))


def test_roundtrip_v3_codec_batched_restore(tmp_path):
    """codec="deepcabac-v3" saves a version-3 container and restore's
    batched lane decode must agree bit-for-bit with decoding the same blob
    through the serial scalar path."""
    from repro.compression.codec import DecodeOptions, decompress
    from repro.core.container import VERSION_V3, ContainerReader

    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             codec="deepcabac-v3",
                                             delta_rel=1e-3))
    mgr.save(state, 3)
    with open(os.path.join(str(tmp_path), "step_00000003",
                           "params.dcbc"), "rb") as f:
        blob = f.read()
    assert ContainerReader(blob).version == VERSION_V3
    restored, meta = mgr.restore(state)
    assert meta["codec"] == "deepcabac-v3"
    serial = decompress(blob, like=state["params"],
                        opts=DecodeOptions(backend="scalar"))
    for a, b in zip(jax.tree.leaves(serial),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=2,
                                             params_mode="raw"))
    for s in [1, 2, 3, 4]:
        mgr.save(state, s)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_no_tmp_dirs_left(tmp_path):
    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             params_mode="raw"))
    mgr.save(state, 5)
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]


def test_async_save(tmp_path):
    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), params_mode="raw",
                                             async_save=True))
    mgr.save(state, 9, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 9


def test_elastic_resharded_restore(tmp_path):
    """Save unsharded, restore onto an explicit 2-device mesh sharding."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    cfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             params_mode="raw"))
    mgr.save(state, 3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {
        "params": named_shardings(
            build_param_specs(state["params"], mesh), mesh),
        "opt": {"count": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                "moments": named_shardings(build_param_specs(
                    state["opt"]["moments"], mesh), mesh)},
        "ef": None,
        "step": jax.sharding.NamedSharding(mesh,
                                           jax.sharding.PartitionSpec()),
    }
    restored, _ = mgr.restore(state, shardings=shardings)
    chex_leaf = jax.tree.leaves(restored["params"])[0]
    assert chex_leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_flatten_unflatten_identity():
    cfg, state = _state()
    flat = flatten_tree(state["params"])
    back = unflatten_like(flat, state["params"])
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
