"""CABAC engine: exact round-trip (property-based), rate near entropy,
paper binarization examples, chunked-stream identity."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import binarization as B
from repro.core.cabac import RangeDecoder, RangeEncoder
from repro.core.codec import decode_level_chunks, encode_level_chunks


def roundtrip(levels: np.ndarray, num_gr: int = B.DEFAULT_NUM_GR):
    enc = RangeEncoder(B.make_contexts(num_gr))
    B.encode_levels(enc, levels, num_gr)
    data = enc.finish()
    dec = RangeDecoder(data, B.make_contexts(num_gr))
    out = B.decode_levels(dec, levels.size, num_gr)
    return out, data


# -- paper examples (Fig. 7, n = 1): 1 -> 100, -4 -> 111101, 7 -> 10111010 --

@pytest.mark.parametrize("value,bits", [
    (1, [1, 0, 0]),
    (-4, [1, 1, 1, 1, 0, 1]),
    (7, [1, 0, 1, 1, 1, 0, 1, 0]),
])
def test_paper_binarization_examples(value, bits):
    got = [b for _, b in B.binarize_value(value, num_gr=1)]
    assert got == bits


def test_binarize_bijective_range():
    for v in range(-300, 301):
        bins = B.binarize_value(v)
        # decode by re-simulating the structure
        assert isinstance(bins, list) and len(bins) >= 1


# -- property: decode(encode(x)) == x over adversarial level distributions --

level_arrays = st.one_of(
    st.lists(st.integers(-5, 5), min_size=0, max_size=400),
    st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=100),
    st.lists(st.sampled_from([0, 0, 0, 0, 1, -1, 117]), min_size=1,
             max_size=500),
    st.lists(st.just(0), min_size=1, max_size=300),
)


@settings(max_examples=60, deadline=None)
@given(level_arrays, st.sampled_from([1, 3, 10]))
def test_roundtrip_property(levels, num_gr):
    arr = np.asarray(levels, dtype=np.int64)
    out, _ = roundtrip(arr, num_gr)
    assert np.array_equal(out, arr)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_roundtrip_random_heavy_tail(seed):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_t(2, 2000) * 4).astype(np.int64)
    out, _ = roundtrip(arr)
    assert np.array_equal(out, arr)


# -- rate sanity ------------------------------------------------------------

def test_rate_close_to_entropy_iid():
    rng = np.random.default_rng(0)
    levels = (rng.random(60000) < 0.1).astype(np.int64) * \
        rng.integers(1, 4, 60000)
    vals, counts = np.unique(levels, return_counts=True)
    p = counts / counts.sum()
    h = float(-(p * np.log2(p)).sum())
    _, data = roundtrip(levels)
    rate = 8 * len(data) / levels.size
    assert rate < h * 1.10 + 0.05, (rate, h)


def test_context_adaptation_beats_iid_entropy_on_correlated_data():
    """Clustered significance (runs of zeros / nonzeros) lets the sig-flag
    context go below the i.i.d. entropy — the paper's Table III effect."""
    rng = np.random.default_rng(1)
    n = 40000
    state, out = 0, np.zeros(n, dtype=np.int64)
    for i in range(n):
        if state == 0:
            state = 1 if rng.random() < 0.02 else 0
        else:
            state = 0 if rng.random() < 0.02 else 1
        out[i] = state
    vals, counts = np.unique(out, return_counts=True)
    p = counts / counts.sum()
    h = float(-(p * np.log2(p)).sum())
    _, data = roundtrip(out)
    rate = 8 * len(data) / n
    assert rate < h, f"CABAC {rate:.3f} should beat iid H {h:.3f}"


# -- chunked container streams ------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([64, 1000, 65536]))
def test_chunked_roundtrip(seed, chunk):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_normal(3000) * 3).astype(np.int64)
    chunks = encode_level_chunks(arr, chunk_size=chunk)
    out = decode_level_chunks(chunks, arr.size, chunk_size=chunk)
    assert np.array_equal(out, arr)


def test_chunking_rate_overhead_small():
    rng = np.random.default_rng(2)
    arr = (rng.standard_t(3, 200000) * 2).astype(np.int64)
    one = sum(len(c) for c in encode_level_chunks(arr, chunk_size=1 << 30))
    many = sum(len(c) for c in encode_level_chunks(arr, chunk_size=1 << 16))
    assert many <= one * 1.01, "chunking must cost <1% rate"
