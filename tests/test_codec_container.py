"""Container/codec: pytree round-trips, dtype fidelity, size accounting."""

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.codec import (QuantizedTensor, decode_state_dict,
                              encode_state_dict, resolve_dtype)
from repro.core.deepcabac import compress_dc_v1, compress_dc_v2


def test_state_dict_roundtrip_mixed():
    rng = np.random.default_rng(0)
    entries = {
        "w1": QuantizedTensor((rng.standard_t(3, (32, 64)) * 4).astype(
            np.int64), 0.01, "float32"),
        "bias": rng.standard_normal(64).astype(np.float32),
        "w_bf16": QuantizedTensor((rng.standard_normal((16, 16)) * 9).astype(
            np.int64), 0.5, "bfloat16"),
        "scalar_like": np.asarray([3], dtype=np.int32),
    }
    blob = encode_state_dict(entries)
    out = decode_state_dict(blob, dequantize=False)
    for k, v in entries.items():
        if isinstance(v, QuantizedTensor):
            assert isinstance(out[k], QuantizedTensor)
            assert np.array_equal(out[k].levels, v.levels)
            assert out[k].step == v.step
            assert out[k].dtype == v.dtype
        else:
            assert np.array_equal(out[k], v)
    deq = decode_state_dict(blob, dequantize=True)
    assert deq["w_bf16"].dtype == resolve_dtype("bfloat16")


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31))
def test_roundtrip_property_statedict(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 40, size=rng.integers(1, 4)))
    levels = (rng.standard_t(2, shape) * 3).astype(np.int64)
    entries = {"t": QuantizedTensor(levels, float(rng.random() + 1e-3))}
    out = decode_state_dict(encode_state_dict(entries), dequantize=False)
    assert np.array_equal(out["t"].levels, levels)


def test_dc_v2_reconstruction_error_bounded():
    rng = np.random.default_rng(1)
    params = {"w": (rng.standard_normal((64, 64)) * 0.05).astype(np.float32)}
    delta = 0.004
    res = compress_dc_v2(params, delta=delta, lam=0.0)
    rec = res.reconstructed()["w"]
    assert np.max(np.abs(rec - params["w"])) <= delta / 2 + 1e-6


def test_dc_v1_per_layer_step_sizes():
    rng = np.random.default_rng(2)
    params = {
        "sensitive": (rng.standard_normal((32, 32)) * 0.02).astype(np.float32),
        "robust": (rng.standard_normal((32, 32)) * 0.02).astype(np.float32),
    }
    sigma = {"sensitive": np.full((32, 32), 1e-4),
             "robust": np.full((32, 32), 1e-1)}
    res = compress_dc_v1(params, sigma, s=64.0, lam=0.0)
    q = res.quantized
    # eq. 12: smaller sigma_min -> smaller step -> finer quantization
    assert q["sensitive"].step < q["robust"].step
    err_s = np.max(np.abs(res.reconstructed()["sensitive"]
                          - params["sensitive"]))
    assert err_s <= q["sensitive"].step / 2 + 1e-7


def test_compression_report_fields():
    rng = np.random.default_rng(3)
    params = {"w": (rng.standard_normal((128, 128)) * 0.03).astype(
        np.float32)}
    res = compress_dc_v2(params, delta=0.01, lam=1e-4)
    r = res.report
    assert r["params"] == 128 * 128
    assert 0 < r["bits_per_param"] < 32
    assert r["compressed_mb"] < r["orig_mb"]
