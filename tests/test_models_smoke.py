"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape checks, no NaNs; prefill/decode consistency against full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill, train_loss)

B, S = 2, 32


def _batch(cfg, key):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.m_rope:
        batch["pos3d"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, _ = forward(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           pos3d=batch.get("pos3d"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(train_loss)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)  # avoid capacity drops
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    kw = ({"tokens": batch["tokens"]} if cfg.embed_input
          else {"embeds": batch["embeds"]})
    logits_full, _, _ = forward(params, cfg, pos3d=batch.get("pos3d"), **kw)
    lg_pre, caches = prefill(params, cfg, max_len=S + 8,
                             pos3d=batch.get("pos3d"), **kw)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_full[:, -1, :]),
                               rtol=2e-4, atol=2e-4)
    # one decode step must equal full forward over the extended sequence
    if cfg.embed_input:
        dt = {"tokens": batch["tokens"][:, 0]}
        ext = jnp.concatenate([batch["tokens"], batch["tokens"][:, :1]], 1)
        logits2, _, _ = forward(params, cfg, tokens=ext)
    else:
        dt = {"embeds": batch["embeds"][:, :1, :]}
        ext = jnp.concatenate([batch["embeds"], batch["embeds"][:, :1, :]], 1)
        p3 = None
        if cfg.m_rope:
            p3 = jnp.broadcast_to(jnp.arange(S + 1)[None, None],
                                  (3, B, S + 1)).astype(jnp.int32)
        logits2, _, _ = forward(params, cfg, embeds=ext, pos3d=p3)
    p3d = None
    if cfg.m_rope:
        p3d = jnp.full((3, B, 1), S, dtype=jnp.int32)
    lg_dec, _ = decode_step(params, cfg, caches, S, pos3d=p3d, **dt)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits2[:, -1, :]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    spec = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    if arch == "deepseek-v3-671b":
        assert (cfg.num_experts, cfg.top_k, cfg.moe_d_ff,
                cfg.num_shared_experts) == (256, 8, 2048, 1)
        assert cfg.attention == "mla"
    if arch == "deepseek-moe-16b":
        assert (cfg.num_experts, cfg.top_k, cfg.moe_d_ff,
                cfg.num_shared_experts) == (64, 6, 1408, 2)
    if arch == "mamba2-2.7b":
        assert cfg.ssm_state == 128
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every == 6
    if arch == "qwen2-vl-7b":
        assert cfg.m_rope
    if arch == "qwen1.5-4b":
        assert cfg.qkv_bias
    if arch == "qwen3-8b":
        assert cfg.qk_norm


def test_shape_skip_policy():
    for arch in ARCH_IDS:
        shapes = shapes_for(arch)
        if arch in ("mamba2-2.7b", "zamba2-2.7b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
