"""Kernel registry: platform dispatch, policy overrides, tuning-cache
consultation, constraint fallbacks, and the promoted embed_lookup_q8 op."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.kernels import KernelPolicy, tune
from repro.kernels.dequant_matmul.ops import _pad_to, default_tiles


def _dm_inputs(m=4, k=256, n=256, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.01 + 1e-4, jnp.float32)
    return x, wq, sc


def test_all_ops_registered():
    assert set(kernels.available_ops()) >= {
        "rd_quant", "dequant_matmul", "flash_attention", "embed_lookup_q8"}


def test_platform_dispatch_defaults():
    op = kernels.get("dequant_matmul")
    x, wq, sc = _dm_inputs()
    assert op.plan(x, wq, sc, policy=KernelPolicy(platform="tpu")).impl \
        == "pallas"
    assert op.plan(x, wq, sc, policy=KernelPolicy(platform="cpu")).impl \
        == "ref"
    fa = kernels.get("flash_attention")
    q = jnp.zeros((1, 64, 2, 32)); kv = jnp.zeros((1, 64, 2, 32))
    qpos = jnp.broadcast_to(jnp.arange(64), (1, 64))
    assert fa.plan(q, kv, kv, qpos,
                   policy=KernelPolicy(platform="tpu")).impl == "pallas"
    assert fa.plan(q, kv, kv, qpos,
                   policy=KernelPolicy(platform="cpu")).impl == "scan"


def test_policy_impl_override_and_equivalence():
    op = kernels.get("dequant_matmul")
    x, wq, sc = _dm_inputs(m=5, k=200, n=130)   # non-multiple-of-block
    ref = np.asarray(op(x, wq, sc, policy=KernelPolicy().override(
        "dequant_matmul", "ref")))
    interp = np.asarray(op(x, wq, sc, policy=KernelPolicy().override(
        "dequant_matmul", "interpret")))
    np.testing.assert_allclose(interp, ref, rtol=2e-4,
                               atol=2e-4 * np.abs(ref).max())


def test_unknown_impl_raises():
    op = kernels.get("dequant_matmul")
    x, wq, sc = _dm_inputs()
    with pytest.raises(KeyError, match="unknown impl"):
        op.plan(x, wq, sc, policy=KernelPolicy().override(
            "dequant_matmul", "nope"))


def test_decode_tiles_clamp_no_pad():
    """Satellite: a 1-8 row decode matmul must not pad rows to 256."""
    t = default_tiles(4, 512, 512)
    assert t["bm"] == 8
    assert default_tiles(1, 512, 512)["bm"] == 8
    assert default_tiles(300, 512, 512)["bm"] == 256
    # no-pad fast path: m == bm -> the padded operand IS the operand
    x = jnp.ones((8, 512))
    assert _pad_to(x, (t["bm"], t["bk"])).shape == (8, 512)
    assert _pad_to(x, (t["bm"], t["bk"])) is x
    # dispatch plan reflects the clamped tile
    plan = kernels.get("dequant_matmul").plan(
        *_dm_inputs(m=8, k=512, n=512),
        policy=KernelPolicy(platform="tpu", use_tuning_cache=False))
    assert dict(plan.tiles)["bm"] == 8


def test_decode_shape_numerics_small_bm():
    op = kernels.get("dequant_matmul")
    for m in (1, 3, 8):
        x, wq, sc = _dm_inputs(m=m, seed=m)
        got = np.asarray(op(x, wq, sc, policy=KernelPolicy().override(
            "dequant_matmul", "interpret")))
        want = np.asarray(kernels.spec("dequant_matmul").oracle(x, wq, sc))
        np.testing.assert_allclose(got, want, rtol=2e-4,
                                   atol=2e-4 * np.abs(want).max())


def test_tuning_cache_hit_vs_default_tiles(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "tune.json"))
    tune.invalidate_cache()
    op = kernels.get("dequant_matmul")
    x, wq, sc = _dm_inputs(m=4)
    pol = KernelPolicy(platform="cpu").override("dequant_matmul", "interpret")

    cold = op.plan(x, wq, sc, policy=pol)
    assert not cold.cache_hit
    assert dict(cold.tiles) == default_tiles(4, 256, 256)

    res = tune.autotune("dequant_matmul", [(4, 256, 256)], impl="interpret",
                        repeats=1, warmup=1, force=True)
    assert (tmp_path / "tune.json").exists()
    (entry,) = res.values()
    warm = op.plan(x, wq, sc, policy=pol)
    assert warm.cache_hit
    assert dict(warm.tiles) == entry["tiles"]
    # same pow2 bucket (m=4 -> bucket m4? no: pow2_bucket(3)=4) serves m=3
    assert op.plan(*_dm_inputs(m=3), policy=pol).cache_hit
    # ...and can be ignored by policy
    off = KernelPolicy(platform="cpu", use_tuning_cache=False).override(
        "dequant_matmul", "interpret")
    assert not op.plan(x, wq, sc, policy=off).cache_hit
    # tile pins beat the cache
    pinned = pol.with_tiles("dequant_matmul", bm=16)
    assert dict(op.plan(x, wq, sc, policy=pinned).tiles)["bm"] == 16


def test_flash_non_multiple_shape_falls_back():
    """sq=100 has no power-of-two tile >= 8: pallas constraint fails and
    dispatch downgrades to scan, visibly."""
    fa = kernels.get("flash_attention")
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 100, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 100, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 100, 2, 32)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(100), (1, 100))
    plan = fa.plan(q, k, v, qpos, policy=KernelPolicy(platform="tpu"))
    assert plan.impl == "scan"
    assert "power-of-two" in plan.fallback_reason
    # the fallback still computes correctly (scan == naive oracle)
    got = np.asarray(fa(q, k, v, qpos))
    want = np.asarray(fa(q, k, v, qpos, policy=KernelPolicy().override(
        "flash_attention", "ref")))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_embed_lookup_q8_matches_previous_behavior():
    """The promoted op must reproduce serve/quantized.py's gather exactly."""
    rng = np.random.default_rng(7)
    leaf = {"q8": jnp.asarray(rng.integers(-127, 127, (512, 64)), jnp.int8),
            "q8s": jnp.asarray(rng.random(64) * 0.02 + 1e-4, jnp.float32)}
    toks = jnp.asarray(rng.integers(0, 512, (2, 9)), jnp.int32)
    op = kernels.get("embed_lookup_q8")
    got = np.asarray(op(leaf, toks, jnp.float32))
    # the exact formula embed_lookup_q8 used in serve/quantized.py
    want = np.asarray((jnp.take(leaf["q8"], toks, axis=0).astype(jnp.float32)
                       * leaf["q8s"]).astype(jnp.float32))
    np.testing.assert_array_equal(got, want)
    # ref impl (dequant-then-gather) is bit-identical
    ref = np.asarray(op(leaf, toks, jnp.float32,
                        policy=KernelPolicy().override(
                            "embed_lookup_q8", "ref")))
    np.testing.assert_array_equal(got, ref)
    # non-q8 leaf passes through
    table = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    t2 = jnp.asarray([[0, 3]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(op(table, t2, jnp.float32)),
                                  np.asarray(jnp.take(table, t2, axis=0)))


def test_legacy_config_fields_removed():
    """The PR-3 deprecation shims are gone: per-op pins go through
    KernelPolicy only, and the serve.quantized re-export is dropped."""
    from repro.configs import get_smoke_config
    import repro.serve.quantized as sq
    cfg = get_smoke_config("llama3-8b")
    with pytest.raises(TypeError):
        cfg.replace(attn_impl="naive")
    with pytest.raises(TypeError):
        cfg.replace(q8_matmul_impl="interpret")
    assert not hasattr(sq, "embed_lookup_q8")
    cfg2 = cfg.replace(kernels=KernelPolicy().override(
        "flash_attention", "ref"))
    assert cfg2.kernels.impl_for("flash_attention") == "ref"


def test_dispatch_report_records_default_fallback():
    kernels.clear_dispatch_report()
    fa = kernels.get("flash_attention")
    q = jnp.zeros((1, 8, 2, 16))
    kv = jnp.zeros((1, 8, 2, 16))
    v8 = jnp.zeros((1, 8, 2, 8))     # dv != d
    qpos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    out = fa(q, kv, v8, qpos, policy=KernelPolicy(platform="tpu"))
    assert out.shape == (1, 8, 2, 8)
    (rec,) = [r for r in kernels.dispatch_report()
              if r["op"] == "flash_attention"]
    assert rec["requested"] is None and rec["impl"] == "scan"
    assert "d != dv" in rec["reason"]
    kernels.clear_dispatch_report()
    assert kernels.dispatch_report() == []


def test_noncanonical_qpos_blocks_pallas():
    """The pallas kernel hard-codes right-aligned causal positions; a
    concrete shifted qpos must not silently reach it (review regression)."""
    fa = kernels.get("flash_attention")
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, 2, 32)), jnp.float32)
    shifted = jnp.maximum(jnp.arange(16) - 4, 0)[None, :]
    pol = KernelPolicy(platform="tpu")
    plan = fa.plan(q, k, v, shifted, policy=pol)
    assert plan.impl == "scan" and "qpos" in plan.fallback_reason
    # the fallback honors the shifted positions (scan == ref oracle)
    got = np.asarray(fa(q, k, v, shifted))
    want = np.asarray(fa(q, k, v, shifted, policy=KernelPolicy().override(
        "flash_attention", "ref")))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # canonical positions keep the kernel eligible
    canon = jnp.broadcast_to(jnp.arange(16), (1, 16))
    assert fa.plan(q, k, v, canon, policy=pol).impl == "pallas"
    # strict + pinned pallas refuses the shifted positions
    with pytest.raises(kernels.KernelDispatchError, match="qpos"):
        fa(q, k, v, shifted, policy=KernelPolicy(
            platform="tpu", strict=True).override(
                "flash_attention", "pallas"))


def test_decode_routes_to_scan_without_fallback_record():
    """Sq==1 is designed routing, not a constraint fallback — it must not
    pollute dispatch_report() on TPU-default policies."""
    fa = kernels.get("flash_attention")
    kernels.clear_dispatch_report()
    q = jnp.zeros((2, 1, 2, 16))
    kv = jnp.zeros((2, 8, 2, 16))
    qpos = jnp.full((2, 1), 7)
    plan = fa.plan(q, kv, kv, qpos, policy=KernelPolicy(platform="tpu"))
    assert plan.impl == "scan" and plan.fallback_reason is None
    fa(q, kv, kv, qpos, policy=KernelPolicy(platform="tpu"),
       kv_len=jnp.asarray([5, 8]))
    assert [r for r in kernels.dispatch_report()
            if r["op"] == "flash_attention"] == []


def test_attend_impl_aliases_map_to_registry():
    """attend(impl=...) keeps its historical vocabulary, mapped onto
    registry impl names (the ModelConfig string fields are gone)."""
    from repro.models.attention import attend
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    naive = np.asarray(attend(q, k, v, qpos, impl="naive"))
    scan = np.asarray(attend(q, k, v, qpos, impl="scan"))
    np.testing.assert_allclose(naive, scan, atol=2e-5, rtol=2e-5)
    with pytest.raises(ValueError, match="unknown attention impl"):
        attend(q, k, v, qpos, impl="bogus")
