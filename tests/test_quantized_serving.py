"""Fixed-point serving: int8 weights + int8 KV cache keep decode faithful."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill)
from repro.serve.quantized import (dequant_leaf, is_q8, quantize_leaf,
                                   quantize_params_for_serving)

B, S = 2, 24


def test_quantize_leaf_roundtrip_error():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 128)) * 0.1, jnp.float32)
    q = quantize_leaf(w)
    back = dequant_leaf(q, jnp.float32)
    tol = float(jnp.max(jnp.abs(w), axis=0).max()) / 127.0
    assert float(jnp.max(jnp.abs(back - w))) <= tol + 1e-7


def test_quantize_stacked_keeps_layer_dim():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32)
    q = quantize_leaf(w)
    assert q["q8"].shape == (4, 32, 64)
    assert q["q8s"].shape == (4, 64)


def test_norms_stay_full_precision():
    cfg = get_smoke_config("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params_for_serving(params)
    assert is_q8(qp["layers"]["attn"]["wq"])
    assert not is_q8(qp["layers"]["attn_norm"])   # stacked 1-D vector
    assert is_q8(qp["embed"]) and is_q8(qp["head"])


def test_int8_serving_close_to_fp():
    """Quantized weights + int8 cache: logits near the fp path and the
    prefill->decode handoff stays consistent under quantization."""
    cfg = get_smoke_config("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_fp, _, _ = forward(params, cfg, tokens=tokens)

    qcfg = cfg.replace(q8_cache=True)
    qp = quantize_params_for_serving(params)
    lg_pre, caches = prefill(qp, qcfg, tokens=tokens, max_len=S + 4)
    # per-channel int8 PTQ on a random-init smoke model: rank agreement
    # of the top prediction is the meaningful check
    top_fp = np.asarray(jnp.argmax(logits_fp[:, -1, :], -1))
    top_q = np.asarray(jnp.argmax(lg_pre, -1))
    corr = np.corrcoef(np.asarray(logits_fp[:, -1, :]).ravel(),
                       np.asarray(lg_pre).ravel())[0, 1]
    assert corr > 0.98, corr
    lg_dec, _ = decode_step(qp, qcfg, caches, S, tokens=tokens[:, 0])
    assert np.all(np.isfinite(np.asarray(lg_dec)))
    assert np.mean(top_fp == top_q) >= 0.5


def test_int8_cache_stores_int8():
    cfg = get_smoke_config("qwen3-8b").replace(q8_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    _, caches = prefill(params, cfg, tokens=tokens, max_len=S + 4)
    assert caches["k"].dtype == jnp.int8
    assert caches["v"].dtype == jnp.int8


def test_int8_serving_mla():
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        q8_cache=True, capacity_factor=8.0)
    params = quantize_params_for_serving(
        init_params(cfg, jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    lg, caches = prefill(params, cfg, tokens=tokens, max_len=S + 4)
    assert caches["main"]["ckv"].dtype == jnp.int8
    lg2, _ = decode_step(params, cfg, caches, S, tokens=tokens[:, 0])
    assert np.all(np.isfinite(np.asarray(lg2)))
