"""String registry of codec factories: ``get("deepcabac-v2", delta=...)``.

Factories take keyword overrides so call sites tune the hyperparameters
without re-plumbing quantizer/coder objects.  New coders/backends plug in
here via :func:`register` without touching any call site.
"""

from __future__ import annotations

import inspect
from typing import Callable

from ..core import binarization as B
from ..core.codec import DEFAULT_CHUNK
from .coders import (CabacCoder, CabacDeltaCoder, CabacV3Coder, HuffmanCoder,
                     RawLevelCoder)
from .codec import Codec, DeltaCodec
from .quantizers import (NearestStdQuantizer, PerChannelInt8Quantizer,
                         RDGridQuantizer, ndim_float_policy, relative_step,
                         serve_q8_policy)

_REGISTRY: dict[str, Callable[..., Codec]] = {}


def register(name: str, factory: Callable[..., Codec]) -> None:
    _REGISTRY[name] = factory


def available() -> list[str]:
    return sorted(_REGISTRY)


def get(name: str, **overrides) -> Codec:
    """Build a registered codec, applying keyword overrides to its factory."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown codec {name!r}; available: {available()}")
    return _REGISTRY[name](**overrides)


def make(name: str, **overrides) -> Codec:
    """Like :func:`get`, but drops overrides the factory doesn't accept —
    for callers forwarding one generic config at a user-chosen codec
    (e.g. CheckpointConfig.delta_rel is meaningful for ckpt-nearest and
    huffman but not for serve-q8/raw)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown codec {name!r}; available: {available()}")
    factory = _REGISTRY[name]
    params = inspect.signature(factory).parameters
    return factory(**{k: v for k, v in overrides.items() if k in params})


# ---------------------------------------------------------------------------
# Built-in codecs
# ---------------------------------------------------------------------------

def _deepcabac_v2(delta: float = 0.01, lam: float = 0.0,
                  num_gr: int = B.DEFAULT_NUM_GR, min_ndim: int = 2,
                  chunk_size: int = DEFAULT_CHUNK,
                  delta_rel: float | None = None) -> Codec:
    """Paper DC-v2: global-Delta RD grid (eq. 11) + chunk-parallel CABAC.

    ``delta_rel`` switches the grid to the per-tensor relative step
    Delta = delta_rel * std(w) (overriding ``delta``) so callers with a
    relative-step config — e.g. CheckpointConfig — keep their semantics."""
    if delta_rel is not None:
        quantizer = RDGridQuantizer(
            lam=lam, num_gr=num_gr,
            step_for=lambda name, w: relative_step(w, delta_rel))
        hyperparams = {"delta_rel": delta_rel, "lam": lam, "num_gr": num_gr}
    else:
        quantizer = RDGridQuantizer(delta=delta, lam=lam, num_gr=num_gr)
        hyperparams = {"delta": delta, "lam": lam, "num_gr": num_gr}
    return Codec("deepcabac-v2",
                 coder=CabacCoder(num_gr=num_gr, chunk_size=chunk_size),
                 quantizer=quantizer,
                 policy=ndim_float_policy(min_ndim),
                 hyperparams=hyperparams)


def _deepcabac_v3(delta: float = 0.01, lam: float = 0.0,
                  num_gr: int = B.DEFAULT_NUM_GR, min_ndim: int = 2,
                  chunk_size: int = DEFAULT_CHUNK,
                  delta_rel: float | None = None,
                  backend: str = "auto") -> Codec:
    """DC-v2 quantization + lane-scheduled CABAC (container v3): the same
    RD grid and bitstream chunks as ``deepcabac-v2``, but records carry
    per-chunk lane metadata so cold-start decode runs the vectorized
    engine over every chunk at once.  Use this for serving artifacts;
    ``deepcabac-v2`` remains for blobs older readers must accept."""
    if delta_rel is not None:
        quantizer = RDGridQuantizer(
            lam=lam, num_gr=num_gr,
            step_for=lambda name, w: relative_step(w, delta_rel))
        hyperparams = {"delta_rel": delta_rel, "lam": lam, "num_gr": num_gr}
    else:
        quantizer = RDGridQuantizer(delta=delta, lam=lam, num_gr=num_gr)
        hyperparams = {"delta": delta, "lam": lam, "num_gr": num_gr}
    return Codec("deepcabac-v3",
                 coder=CabacV3Coder(num_gr=num_gr, chunk_size=chunk_size,
                                    backend=backend),
                 quantizer=quantizer,
                 policy=ndim_float_policy(min_ndim),
                 hyperparams=hyperparams)


def _ckpt_nearest(delta_rel: float = 1e-3, min_ndim: int = 2,
                  num_gr: int = B.DEFAULT_NUM_GR,
                  chunk_size: int = DEFAULT_CHUNK) -> Codec:
    """Checkpoint codec: deterministic nearest-level on Delta =
    delta_rel * std(w) + CABAC (bit-reproducible resumes)."""
    return Codec("ckpt-nearest",
                 coder=CabacCoder(num_gr=num_gr, chunk_size=chunk_size),
                 quantizer=NearestStdQuantizer(delta_rel=delta_rel),
                 policy=ndim_float_policy(min_ndim),
                 hyperparams={"delta_rel": delta_rel})


def _serve_q8() -> Codec:
    """Fixed-point serving artifact: per-out-channel symmetric int8 levels
    + scales, stored raw (mmap-friendly, decode-free load)."""
    return Codec("serve-q8",
                 coder=RawLevelCoder(),
                 quantizer=PerChannelInt8Quantizer(),
                 policy=serve_q8_policy)


def _huffman(delta_rel: float = 1e-3, min_ndim: int = 2) -> Codec:
    """Scalar Huffman baseline (paper §IV-B-2): same nearest-level grid as
    the checkpoint codec, coded with an explicit two-part Huffman code."""
    return Codec("huffman",
                 coder=HuffmanCoder(),
                 quantizer=NearestStdQuantizer(delta_rel=delta_rel),
                 policy=ndim_float_policy(min_ndim),
                 hyperparams={"delta_rel": delta_rel})


def _deepcabac_delta(delta_rel: float = 1e-3, min_ndim: int = 2,
                     num_gr: int = B.DEFAULT_NUM_GR,
                     chunk_size: int = DEFAULT_CHUNK,
                     backend: str = "auto") -> DeltaCodec:
    """Temporal delta ("P-frame") codec.  ``compress`` behaves like a
    deterministic nearest-level keyframe codec with lane-scheduled v3
    records; ``compress_delta`` quantizes a new frame on the base frame's
    grids and temporal-context CABAC-codes the integer-level residuals
    (container v4, ``ENC_CABAC_DELTA``).  The chain linkage — which base a
    delta applies to — lives in the delta manifest
    (``repro.checkpoint.delta``)."""
    return DeltaCodec(
        "deepcabac-delta",
        coder=CabacV3Coder(num_gr=num_gr, chunk_size=chunk_size,
                           backend=backend),
        quantizer=NearestStdQuantizer(delta_rel=delta_rel),
        policy=ndim_float_policy(min_ndim),
        hyperparams={"delta_rel": delta_rel, "num_gr": num_gr,
                     "chunk_size": chunk_size},
        delta_coder=CabacDeltaCoder(num_gr=num_gr, chunk_size=chunk_size,
                                    backend=backend))


def _raw() -> Codec:
    """Lossless passthrough — every leaf stored verbatim."""
    return Codec("raw")


def _kv_q8_cabac(step: float = 1.0, num_gr: int = B.DEFAULT_NUM_GR,
                 chunk_size: int | None = None, backend: str = "auto"):
    """KV-cache page codec (the paged serving cache's eviction format):
    int8 cache pages CABAC-coded losslessly, float pages q8
    block-quantized first (``compression.q8``) with raw f32 scale
    records.  Restores batch every chunk through the lane-parallel
    decoder.  Not a tree-policy :class:`Codec` — pages are dense
    activation tiles, so the quantizer x policy machinery for weight
    trees doesn't apply; the object exposes the same
    ``compress``/``decompress`` surface.  See
    :mod:`repro.compression.kv_pages`."""
    from .kv_pages import KV_PAGE_CHUNK, KVPageCodec
    return KVPageCodec(step=step, num_gr=num_gr,
                       chunk_size=KV_PAGE_CHUNK if chunk_size is None
                       else chunk_size, backend=backend)


register("deepcabac-v2", _deepcabac_v2)
register("deepcabac-delta", _deepcabac_delta)
register("deepcabac-v3", _deepcabac_v3)
register("ckpt-nearest", _ckpt_nearest)
register("serve-q8", _serve_q8)
register("huffman", _huffman)
register("raw", _raw)
register("kv-q8-cabac", _kv_q8_cabac)
