"""String registry of codec factories: ``get("deepcabac-v2", delta=...)``.

Factories take keyword overrides so call sites tune the hyperparameters
without re-plumbing quantizer/coder objects.  New coders/backends plug in
here via :func:`register` without touching any call site.

``get`` is the single entry point.  By default it is *strict*: an
override the factory does not accept raises ``TypeError`` naming the
accepted parameters (so ``lamda=0.1`` can never be silently ignored).
Callers forwarding one generic config at a user-chosen codec — e.g.
``CheckpointConfig.delta_rel`` is meaningful for ``ckpt-nearest`` and
``huffman`` but not for ``serve-q8``/``raw`` — pass ``strict=False``:
unknown overrides are dropped, and the drop is recorded in the codec's
``hyperparams["dropped_overrides"]`` so it shows up in checkpoint
metadata instead of vanishing.  The old ``make`` (which dropped
silently) survives as a deprecated shim for one release.
"""

from __future__ import annotations

import inspect
import warnings
from typing import Callable

from ..core import binarization as B
from ..core.codec import DEFAULT_CHUNK
from .coders import (CabacCoder, CabacDeltaCoder, CabacV3Coder, HuffmanCoder,
                     RawLevelCoder)
from .codec import Codec, DeltaCodec
from .quantizers import (NearestStdQuantizer, PerChannelInt8Quantizer,
                         PolicyFn, RDGridQuantizer, ndim_float_policy,
                         relative_step, serve_q8_policy)

_REGISTRY: dict[str, Callable[..., Codec]] = {}


def register(name: str, factory: Callable[..., Codec]) -> None:
    _REGISTRY[name] = factory


def available() -> list[str]:
    return sorted(_REGISTRY)


def get(name: str, *, strict: bool = True, **overrides) -> Codec:
    """Build a registered codec, applying keyword overrides to its factory.

    ``strict=True`` (default): an override the factory does not accept
    raises ``TypeError``.  ``strict=False``: unknown overrides are
    dropped and recorded in the built codec's
    ``hyperparams["dropped_overrides"]`` — the forwarding mode for
    callers pushing one generic config at a user-chosen codec.
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown codec {name!r}; available: {available()}")
    factory = _REGISTRY[name]
    params = inspect.signature(factory).parameters
    takes_var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in params.values())
    dropped: list[str] = []
    if not takes_var_kw:
        unknown = sorted(set(overrides) - set(params))
        if unknown:
            if strict:
                raise TypeError(
                    f"codec {name!r} does not accept override(s) "
                    f"{unknown}; accepted: {sorted(params)} "
                    f"(pass strict=False to forward a generic config and "
                    f"record the drop)")
            dropped = unknown
            overrides = {k: v for k, v in overrides.items()
                         if k not in unknown}
    codec = factory(**overrides)
    if dropped and hasattr(codec, "hyperparams"):
        codec.hyperparams = {**codec.hyperparams,
                             "dropped_overrides": dropped}
    return codec


def make(name: str, **overrides) -> Codec:
    """Deprecated: use ``get(name, strict=False, **overrides)``.

    The historical forwarding entry point — it dropped unknown overrides
    *silently*, so a typo'd hyperparameter was indistinguishable from an
    inapplicable one.  The unified :func:`get` keeps the forwarding
    semantics behind an explicit ``strict=False`` and records every drop
    in the codec's ``hyperparams``."""
    warnings.warn(
        "compression.registry.make is deprecated; use "
        "get(name, strict=False, **overrides)", DeprecationWarning,
        stacklevel=2)
    return get(name, strict=False, **overrides)


# ---------------------------------------------------------------------------
# Built-in codecs
# ---------------------------------------------------------------------------

def _rd_grid_quantizer(delta: float, delta_rel: float | None, lam: float,
                       num_gr: int) -> tuple[RDGridQuantizer, dict]:
    """The shared RD-grid builder behind every ``deepcabac-*`` intra
    codec: a global ``delta``, or — when ``delta_rel`` is set — the
    per-tensor relative step Delta = delta_rel * std(w), so callers with
    a relative-step config (e.g. CheckpointConfig) keep their semantics.
    Returns (quantizer, hyperparams)."""
    if delta_rel is not None:
        quantizer = RDGridQuantizer(
            lam=lam, num_gr=num_gr,
            step_for=lambda name, w: relative_step(w, delta_rel))
        return quantizer, {"delta_rel": delta_rel, "lam": lam,
                           "num_gr": num_gr}
    return (RDGridQuantizer(delta=delta, lam=lam, num_gr=num_gr),
            {"delta": delta, "lam": lam, "num_gr": num_gr})


def _deepcabac_v2(delta: float = 0.01, lam: float = 0.0,
                  num_gr: int = B.DEFAULT_NUM_GR, min_ndim: int = 2,
                  chunk_size: int = DEFAULT_CHUNK,
                  delta_rel: float | None = None) -> Codec:
    """Paper DC-v2: global-Delta RD grid (eq. 11) + chunk-parallel CABAC."""
    quantizer, hyperparams = _rd_grid_quantizer(delta, delta_rel, lam, num_gr)
    return Codec("deepcabac-v2",
                 coder=CabacCoder(num_gr=num_gr, chunk_size=chunk_size),
                 quantizer=quantizer,
                 policy=ndim_float_policy(min_ndim),
                 hyperparams=hyperparams)


def _deepcabac_v3(delta: float = 0.01, lam: float = 0.0,
                  num_gr: int = B.DEFAULT_NUM_GR, min_ndim: int = 2,
                  chunk_size: int = DEFAULT_CHUNK,
                  delta_rel: float | None = None,
                  backend: str = "auto") -> Codec:
    """DC-v2 quantization + lane-scheduled CABAC (container v3): the same
    RD grid and bitstream chunks as ``deepcabac-v2``, but records carry
    per-chunk lane metadata so cold-start decode runs the vectorized
    engine over every chunk at once.  Use this for serving artifacts;
    ``deepcabac-v2`` remains for blobs older readers must accept."""
    quantizer, hyperparams = _rd_grid_quantizer(delta, delta_rel, lam, num_gr)
    return Codec("deepcabac-v3",
                 coder=CabacV3Coder(num_gr=num_gr, chunk_size=chunk_size,
                                    backend=backend),
                 quantizer=quantizer,
                 policy=ndim_float_policy(min_ndim),
                 hyperparams=hyperparams)


def _deepcabac_rd(policy_table=None, num_gr: int = B.DEFAULT_NUM_GR,
                  min_ndim: int = 2, chunk_size: int = DEFAULT_CHUNK,
                  backend: str = "auto", assign: str = "auto") -> Codec:
    """Per-tensor mixed-precision codec driven by a swept
    :class:`~repro.compression.rd_search.TensorPolicy` table.

    ``policy_table`` (required) is a ``TensorPolicy``, its ``to_dict()``
    payload, or a path to its JSON file — the output of the
    rate-distortion Pareto harness (``repro.compression.rd_search`` /
    ``benchmarks/rd_sweep_bench.py``).  Each covered tensor is
    RD-assigned on its own (step, lambda) operating point through the
    ``rd_quant`` kernel dispatch (``assign``: ``auto`` routes to the
    Pallas kernel on TPU and the numpy oracle elsewhere); tensors the
    table does not cover stay raw.  Containers are lane-scheduled v3 —
    byte-compatible with every existing reader."""
    from .rd_search import PolicyQuantizer, resolve_policy
    if policy_table is None:
        raise ValueError(
            "deepcabac-rd needs policy_table= (a TensorPolicy, its dict "
            "form, or a JSON path) — sweep one with "
            "repro.compression.rd_search.rd_sweep or "
            "benchmarks/rd_sweep_bench.py")
    table = resolve_policy(policy_table)
    base_policy = ndim_float_policy(min_ndim)

    def policy(name, w):
        return table.rule_for(name) is not None and base_policy(name, w)

    return Codec("deepcabac-rd",
                 coder=CabacV3Coder(num_gr=num_gr, chunk_size=chunk_size,
                                    backend=backend),
                 quantizer=PolicyQuantizer(table=table, num_gr=num_gr,
                                           assign=assign),
                 policy=policy,
                 hyperparams={"num_gr": num_gr,
                              "policy_tensors": len(table.rules),
                              **({"policy_meta": dict(table.meta)}
                                 if table.meta else {})})


def _ckpt_nearest(delta_rel: float = 1e-3, min_ndim: int = 2,
                  num_gr: int = B.DEFAULT_NUM_GR,
                  chunk_size: int = DEFAULT_CHUNK) -> Codec:
    """Checkpoint codec: deterministic nearest-level on Delta =
    delta_rel * std(w) + CABAC (bit-reproducible resumes)."""
    return Codec("ckpt-nearest",
                 coder=CabacCoder(num_gr=num_gr, chunk_size=chunk_size),
                 quantizer=NearestStdQuantizer(delta_rel=delta_rel),
                 policy=ndim_float_policy(min_ndim),
                 hyperparams={"delta_rel": delta_rel})


def _serve_q8() -> Codec:
    """Fixed-point serving artifact: per-out-channel symmetric int8 levels
    + scales, stored raw (mmap-friendly, decode-free load)."""
    return Codec("serve-q8",
                 coder=RawLevelCoder(),
                 quantizer=PerChannelInt8Quantizer(),
                 policy=serve_q8_policy)


def _huffman(delta_rel: float = 1e-3, min_ndim: int = 2) -> Codec:
    """Scalar Huffman baseline (paper §IV-B-2): same nearest-level grid as
    the checkpoint codec, coded with an explicit two-part Huffman code."""
    return Codec("huffman",
                 coder=HuffmanCoder(),
                 quantizer=NearestStdQuantizer(delta_rel=delta_rel),
                 policy=ndim_float_policy(min_ndim),
                 hyperparams={"delta_rel": delta_rel})


def _deepcabac_delta(delta_rel: float = 1e-3, min_ndim: int = 2,
                     num_gr: int = B.DEFAULT_NUM_GR,
                     chunk_size: int = DEFAULT_CHUNK,
                     backend: str = "auto") -> DeltaCodec:
    """Temporal delta ("P-frame") codec.  ``compress`` behaves like a
    deterministic nearest-level keyframe codec with lane-scheduled v3
    records; ``compress_delta`` quantizes a new frame on the base frame's
    grids and temporal-context CABAC-codes the integer-level residuals
    (container v4, ``ENC_CABAC_DELTA``).  The chain linkage — which base a
    delta applies to — lives in the delta manifest
    (``repro.checkpoint.delta``)."""
    return DeltaCodec(
        "deepcabac-delta",
        coder=CabacV3Coder(num_gr=num_gr, chunk_size=chunk_size,
                           backend=backend),
        quantizer=NearestStdQuantizer(delta_rel=delta_rel),
        policy=ndim_float_policy(min_ndim),
        hyperparams={"delta_rel": delta_rel, "num_gr": num_gr,
                     "chunk_size": chunk_size},
        delta_coder=CabacDeltaCoder(num_gr=num_gr, chunk_size=chunk_size,
                                    backend=backend))


def _raw() -> Codec:
    """Lossless passthrough — every leaf stored verbatim."""
    return Codec("raw")


def _kv_q8_cabac(step: float = 1.0, num_gr: int = B.DEFAULT_NUM_GR,
                 chunk_size: int | None = None, backend: str = "auto"):
    """KV-cache page codec (the paged serving cache's eviction format):
    int8 cache pages CABAC-coded losslessly, float pages q8
    block-quantized first (``compression.q8``) with raw f32 scale
    records.  Restores batch every chunk through the lane-parallel
    decoder.  Not a tree-policy :class:`Codec` — pages are dense
    activation tiles, so the quantizer x policy machinery for weight
    trees doesn't apply; the object exposes the same
    ``compress``/``decompress`` surface.  See
    :mod:`repro.compression.kv_pages`."""
    from .kv_pages import KV_PAGE_CHUNK, KVPageCodec
    return KVPageCodec(step=step, num_gr=num_gr,
                       chunk_size=KV_PAGE_CHUNK if chunk_size is None
                       else chunk_size, backend=backend)


register("deepcabac-v2", _deepcabac_v2)
register("deepcabac-delta", _deepcabac_delta)
register("deepcabac-v3", _deepcabac_v3)
register("deepcabac-rd", _deepcabac_rd)
register("ckpt-nearest", _ckpt_nearest)
register("serve-q8", _serve_q8)
register("huffman", _huffman)
register("raw", _raw)
register("kv-q8-cabac", _kv_q8_cabac)
