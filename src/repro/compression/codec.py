"""The Codec: Quantizer x EntropyCoder x per-tensor policy over pytrees.

``compress`` accepts any jax pytree (or an already-flat name->array dict),
flattens it to "a/b/c" names, applies the policy per tensor, quantizes
what the policy selects, entropy-codes into one DCBC container and
returns an :class:`Artifact`.  ``decompress`` is codec-independent — the
container is self-describing — and optionally rebuilds the original tree
structure (with dtype restore, incl. bfloat16) from a template.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..core.codec import (DecodeOptions, compressed_size_report,
                          decode_state_dict, decode_state_dict_batched,
                          iter_decode_state_dict)
from ..core.container import ContainerWriter
from .artifact import Artifact
from .coders import EntropyCoder
from .quantizers import Quantizer
from .tree import flatten_tree, unflatten_like


def iter_decompress(blob: bytes, dequantize: bool = True,
                    opts: DecodeOptions | None = None):
    """Streaming decode of any codec's container: yields ``(name, tensor)``
    one record at a time.  A consumer that converts each tensor to its
    destination representation before advancing keeps peak decoded host
    memory bounded by the largest tensor (layer-bound, not model-bound) —
    the contract the ``container`` serving weight backend relies on.
    ``opts`` tunes the lane-parallel entropy decode of v3 cabac records
    (per-tensor batches, so the streaming bound still holds)."""
    yield from iter_decode_state_dict(blob, dequantize=dequantize, opts=opts)


def decompress(blob: bytes, like=None, dequantize: bool = True,
               batched: bool = False, opts: DecodeOptions | None = None):
    """Decode any codec's container.

    Returns the flat ``{"a/b/c": ndarray}`` dict, or — given ``like``, a
    template pytree — the rebuilt tree with each leaf cast to the
    template's dtype.  ``dequantize=False`` yields the quantized
    representations instead of reconstructed arrays.  ``batched=True``
    schedules every CABAC chunk in the container into one lane-parallel
    decode batch (cold-start path: fastest wall-clock, model-bound
    memory); the default decodes record by record.
    """
    if batched:
        flat = decode_state_dict_batched(blob, dequantize=dequantize,
                                         opts=opts)
    else:
        flat = decode_state_dict(blob, dequantize=dequantize, opts=opts)
    if like is None:
        return flat
    return unflatten_like(flat, like)


@dataclass
class Codec:
    name: str
    coder: EntropyCoder | None = None       # None => raw-only codec
    quantizer: Quantizer | None = None      # None => everything raw
    policy: Callable[[str, np.ndarray], bool] | None = None
    hyperparams: dict = field(default_factory=dict)

    def quantize_entries(self, tree) -> dict:
        """Flatten + per-tensor policy + quantize; raw leaves pass through."""
        entries: dict = {}
        for name, w in flatten_tree(tree).items():
            if (self.quantizer is not None and w.size > 0
                    and (self.policy is None or self.policy(name, w))):
                entries[name] = self.quantizer.quantize(name, w)
            else:
                entries[name] = w
        return entries

    def compress(self, tree) -> Artifact:
        entries = self.quantize_entries(tree)
        writer = ContainerWriter()
        for name, e in entries.items():
            if isinstance(e, np.ndarray):
                writer.add_raw(name, e)
            elif self.coder is None:
                raise ValueError(
                    f"codec {self.name!r} quantized {name} but has no "
                    f"entropy coder")
            else:
                self.coder.add_record(writer, name, e)
        blob = writer.tobytes()
        return Artifact(blob=blob,
                        report=compressed_size_report(entries, blob),
                        hyperparams={"codec": self.name, **self.hyperparams},
                        quantized=entries)

    def decompress(self, blob: bytes, like=None, dequantize: bool = True):
        return decompress(blob, like=like, dequantize=dequantize)
