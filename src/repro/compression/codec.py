"""The Codec: Quantizer x EntropyCoder x per-tensor policy over pytrees.

``compress`` accepts any jax pytree (or an already-flat name->array dict),
flattens it to "a/b/c" names, applies the policy per tensor, quantizes
what the policy selects, entropy-codes into one DCBC container and
returns an :class:`Artifact`.  ``decompress`` is codec-independent — the
container is self-describing — and optionally rebuilds the original tree
structure (with dtype restore, incl. bfloat16) from a template.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.codec import (DecodeOptions, DeltaTensor, QuantizedTensor,
                          compressed_size_report, decode_state_dict,
                          decode_state_dict_batched, iter_decode_state_dict)
from ..core.container import ContainerWriter
from ..core.quant import nearest_level
from .artifact import Artifact
from .coders import EntropyCoder
from .quantizers import PolicyFn, Quantizer
from .tree import flatten_tree, unflatten_like


def iter_decompress(blob: bytes, dequantize: bool = True,
                    opts: DecodeOptions | None = None):
    """Streaming decode of any codec's container: yields ``(name, tensor)``
    one record at a time.  A consumer that converts each tensor to its
    destination representation before advancing keeps peak decoded host
    memory bounded by the largest tensor (layer-bound, not model-bound) —
    the contract the ``container`` serving weight backend relies on.
    ``opts`` tunes the lane-parallel entropy decode of v3 cabac records
    (per-tensor batches, so the streaming bound still holds)."""
    yield from iter_decode_state_dict(blob, dequantize=dequantize, opts=opts)


def decompress(blob: bytes, like=None, dequantize: bool = True,
               batched: bool = False, opts: DecodeOptions | None = None):
    """Decode any codec's container.

    Returns the flat ``{"a/b/c": ndarray}`` dict, or — given ``like``, a
    template pytree — the rebuilt tree with each leaf cast to the
    template's dtype.  ``dequantize=False`` yields the quantized
    representations instead of reconstructed arrays.  ``batched=True``
    schedules every CABAC chunk in the container into one lane-parallel
    decode batch (cold-start path: fastest wall-clock, model-bound
    memory); the default decodes record by record.
    """
    if batched:
        flat = decode_state_dict_batched(blob, dequantize=dequantize,
                                         opts=opts)
    else:
        flat = decode_state_dict(blob, dequantize=dequantize, opts=opts)
    if like is None:
        return flat
    return unflatten_like(flat, like)


@dataclass
class Codec:
    name: str
    coder: EntropyCoder | None = None       # None => raw-only codec
    quantizer: Quantizer | None = None      # None => everything raw
    policy: PolicyFn | None = None
    hyperparams: dict = field(default_factory=dict)

    def quantize_entries(self, tree) -> dict:
        """Flatten + per-tensor policy + quantize; raw leaves pass through."""
        entries: dict = {}
        for name, w in flatten_tree(tree).items():
            if (self.quantizer is not None and w.size > 0
                    and (self.policy is None or self.policy(name, w))):
                entries[name] = self.quantizer.quantize(name, w)
            else:
                entries[name] = w
        return entries

    def compress_entries(self, entries: dict) -> Artifact:
        """Entropy-code an already-quantized flat entry dict (the output
        of :meth:`quantize_entries` — or of ``DeltaCodec.quantize_like``
        for a step-locked frame) without re-quantizing."""
        writer = ContainerWriter()
        for name, e in entries.items():
            if isinstance(e, np.ndarray):
                writer.add_raw(name, e)
            elif self.coder is None:
                raise ValueError(
                    f"codec {self.name!r} quantized {name} but has no "
                    f"entropy coder")
            else:
                self.coder.add_record(writer, name, e)
        blob = writer.tobytes()
        return Artifact(blob=blob,
                        report=compressed_size_report(entries, blob),
                        hyperparams={"codec": self.name, **self.hyperparams},
                        quantized=entries)

    def compress(self, tree) -> Artifact:
        return self.compress_entries(self.quantize_entries(tree))

    def decompress(self, blob: bytes, like=None, dequantize: bool = True):
        return decompress(blob, like=like, dequantize=dequantize)


@dataclass
class DeltaCodec(Codec):
    """Temporal delta ("P-frame") codec.

    Keyframes (I-frames) go through the inherited :meth:`Codec.compress` —
    a plain lane-scheduled container.  :meth:`compress_delta` codes a new
    frame against a base frame's quantized entries: the new frame is
    quantized on the *base tensor's grid* (step locking — no per-frame
    std recomputation), the integer-level residual is temporal-context
    CABAC coded, and reconstruction is therefore bit-identical to the
    direct encoding of the same step-locked frame, with zero drift across
    chains of any depth.  Tensors with no compatible base (new name,
    shape change, raw-in-base) fall back to full intra records inside the
    same container.
    """

    delta_coder: EntropyCoder | None = None

    def _lockable(self, name, w, base) -> bool:
        quantizable = (self.quantizer is not None and w.size > 0
                       and (self.policy is None or self.policy(name, w)))
        return (quantizable and isinstance(base, QuantizedTensor)
                and base.shape == tuple(np.asarray(w).shape)
                and base.step > 0)

    def delta_entries(self, tree, base_entries: dict) -> dict:
        """Flatten the new frame; every tensor with a compatible base
        entry is quantized on the *base's* grid (step locking) and becomes
        a :class:`DeltaTensor` residual against the base levels; the rest
        follow the codec's own quantizer/policy as full intra entries."""
        out: dict = {}
        for name, w in flatten_tree(tree).items():
            base = base_entries.get(name)
            if self._lockable(name, w, base):
                w_arr = np.asarray(w)
                levels = nearest_level(
                    w_arr.astype(np.float64).ravel(),
                    base.step).reshape(w_arr.shape)
                resid = levels - base.levels.astype(np.int64)
                out[name] = DeltaTensor(resid=resid, base=base.levels,
                                        step=base.step,
                                        dtype=str(w_arr.dtype))
            elif (self.quantizer is not None and w.size > 0
                    and (self.policy is None or self.policy(name, w))):
                out[name] = self.quantizer.quantize(name, w)
            else:
                out[name] = w
        return out

    def quantize_like(self, tree, base_entries: dict) -> dict:
        """The step-locked quantization of the new frame — the frame a
        base + delta chain reconstructs bit-identically.  Encoding these
        entries directly (``Codec.compress`` path) is the monolithic
        reference the delta tests pin against."""
        return self.reconstruct_entries(
            self.delta_entries(tree, base_entries))

    @staticmethod
    def reconstruct_entries(dentries: dict) -> dict:
        """New-frame entries (QuantizedTensor / Q8Tensor / ndarray) from a
        :meth:`delta_entries` dict — what a decoder of the chain yields,
        and what the next link's ``base_entries`` should be."""
        out: dict = {}
        for name, e in dentries.items():
            if isinstance(e, DeltaTensor):
                out[name] = QuantizedTensor(
                    e.new_levels().reshape(e.shape), e.step, e.dtype)
            else:
                out[name] = e
        return out

    def compress_delta(self, tree, base_entries: dict) -> Artifact:
        """Encode ``tree`` as a P-frame against ``base_entries`` (the flat
        quantized entries of the base frame, e.g. ``Artifact.quantized``
        of the previous save).  ``Artifact.quantized`` holds the
        *reconstructed new frame* so callers can chain the next delta
        without re-decoding."""
        if self.delta_coder is None:
            raise ValueError(
                f"codec {self.name!r} has no delta coder; use compress()")
        dentries = self.delta_entries(tree, base_entries)
        writer = ContainerWriter()
        n_delta = 0
        for name, e in dentries.items():
            if isinstance(e, DeltaTensor):
                self.delta_coder.add_record(writer, name, e)
                n_delta += 1
            elif isinstance(e, np.ndarray):
                writer.add_raw(name, e)
            elif self.coder is None:
                raise ValueError(
                    f"codec {self.name!r} quantized {name} but has no "
                    f"entropy coder")
            else:
                self.coder.add_record(writer, name, e)
        blob = writer.tobytes()
        new_entries = self.reconstruct_entries(dentries)
        return Artifact(
            blob=blob,
            report={**compressed_size_report(new_entries, blob),
                    "delta_records": n_delta},
            hyperparams={"codec": self.name, "delta": True,
                         **self.hyperparams},
            quantized=new_entries)
