"""EntropyCoder strategies: quantized tensor -> DCBC container record.

Decoding needs no strategy object — container records are self-describing
and ``repro.core.codec.decode_state_dict`` handles every encoding.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import binarization as B
from ..core.codec import (DEFAULT_CHUNK, DeltaTensor, Q8Tensor,
                          QuantizedTensor, encode_delta_chunks_batched,
                          encode_level_chunks, encode_level_chunks_batched)
from ..core.container import ContainerWriter
from ..core.huffman import build_huffman, pack_payload


class EntropyCoder:
    """Strategy interface: append one quantized tensor to a container."""

    def add_record(self, writer: ContainerWriter, name: str,
                   qt: QuantizedTensor | Q8Tensor) -> None:
        raise NotImplementedError


@dataclass
class CabacCoder(EntropyCoder):
    """Chunk-parallel context-adaptive binary arithmetic coding — the
    paper's coder; chunks decode independently for multi-host restores."""

    num_gr: int = B.DEFAULT_NUM_GR
    chunk_size: int = DEFAULT_CHUNK

    def add_record(self, writer, name, qt):
        if not isinstance(qt, QuantizedTensor):
            raise TypeError(
                f"CabacCoder codes scalar-step levels, got {type(qt).__name__}")
        chunks = encode_level_chunks(qt.levels, self.num_gr, self.chunk_size)
        writer.add_cabac(name, qt.dtype, qt.shape, qt.step,
                         self.num_gr, self.chunk_size, chunks)


@dataclass
class CabacV3Coder(EntropyCoder):
    """Lane-scheduled CABAC: chunks are encoded as a vectorized lane batch
    (bit-identical streams to :class:`CabacCoder`) and the container
    record carries per-chunk value counts, so readers batch every chunk
    of a tensor — or a whole state dict — into one lane-parallel decode
    (``repro.core.cabac_vec``).  Containers carrying these records are
    version 3; v1/v2-era readers reject them with a versioned error."""

    num_gr: int = B.DEFAULT_NUM_GR
    chunk_size: int = DEFAULT_CHUNK
    backend: str = "auto"          # lane engine for encode: auto | c | numpy

    def add_record(self, writer, name, qt):
        if not isinstance(qt, QuantizedTensor):
            raise TypeError(
                f"CabacV3Coder codes scalar-step levels, "
                f"got {type(qt).__name__}")
        chunks, counts = encode_level_chunks_batched(
            qt.levels, self.num_gr, self.chunk_size, backend=self.backend)
        writer.add_cabac_v3(name, qt.dtype, qt.shape, qt.step,
                            self.num_gr, self.chunk_size, chunks, counts)


@dataclass
class CabacDeltaCoder(EntropyCoder):
    """Temporal-context CABAC over integer-level *residuals* ("P-frame"
    records): each residual's context bank is selected by the class of
    its co-located base-frame level, and the chunk layout mirrors the v3
    lane schedule.  Containers carrying these records are version 4 and
    undecodable without the base frame the delta manifest names."""

    num_gr: int = B.DEFAULT_NUM_GR
    chunk_size: int = DEFAULT_CHUNK
    backend: str = "auto"          # lane engine for encode: auto | c | numpy

    def add_record(self, writer, name, dt):
        if not isinstance(dt, DeltaTensor):
            raise TypeError(
                f"CabacDeltaCoder codes level residuals, "
                f"got {type(dt).__name__}")
        chunks, counts = encode_delta_chunks_batched(
            dt.resid, dt.base, self.num_gr, self.chunk_size,
            backend=self.backend)
        writer.add_cabac_delta(name, dt.dtype, dt.shape, dt.step,
                               self.num_gr, self.chunk_size, chunks, counts)


@dataclass
class HuffmanCoder(EntropyCoder):
    """Canonical scalar Huffman baseline (paper §IV-B-2) with the two-part
    code table transmitted in-band ahead of the bitstream.

    This is the *benchmark baseline* coder: the per-symbol Python
    encode/decode loops are fine for the paper-table fixtures but orders
    of magnitude slower than CABAC's chunked path on real model sizes —
    don't point CheckpointManager at it for large states.
    """

    def add_record(self, writer, name, qt):
        if not isinstance(qt, QuantizedTensor):
            raise TypeError(
                f"HuffmanCoder codes scalar-step levels, got {type(qt).__name__}")
        flat = np.asarray(qt.levels).ravel()
        payload = pack_payload(flat, build_huffman(flat))
        writer.add_huffman(name, qt.dtype, qt.shape, qt.step, payload)


@dataclass
class RawLevelCoder(EntropyCoder):
    """Raw passthrough of int8 levels + per-channel scales — no entropy
    coding; the serving artifact wants mmap-friendly fixed-point payloads."""

    def add_record(self, writer, name, qt):
        if not isinstance(qt, Q8Tensor):
            raise TypeError(
                f"RawLevelCoder stores int8 per-channel tensors, "
                f"got {type(qt).__name__}")
        writer.add_q8(name, qt.dtype, qt.levels, qt.scale)
