"""Quantizer strategies + per-tensor policies for the Codec API.

A quantizer maps one full-precision tensor to a quantized representation
(``QuantizedTensor`` for scalar-step equidistant grids, ``Q8Tensor`` for
per-channel int8); a policy decides per flat-named leaf whether to
quantize at all (1-D biases/norms and integer leaves stay raw, as in the
paper's protocol).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import binarization as B
from ..core.codec import Q8Tensor, QuantizedTensor
from ..core.deepcabac import quantize_tensor_rd
from ..core.quant import nearest_level

# ---------------------------------------------------------------------------
# Per-tensor policies
# ---------------------------------------------------------------------------

STACKED_TOP_KEYS = ("layers", "dense_layers")


@runtime_checkable
class PolicyFn(Protocol):
    """The one per-tensor policy signature every codec shares.

    Called with the flat leaf name (``layers/attn/wq``-style, as produced
    by ``compression.tree.flatten_tree``) and the leaf array; returns
    True when the leaf should be quantized, False to store it raw.
    ``serve_q8_policy``, the :func:`ndim_float_policy` family, and the
    ``deepcabac-rd`` table-membership policy all implement it — custom
    policies passed to :class:`~repro.compression.codec.Codec` should
    too (any plain ``(name, w) -> bool`` callable qualifies).
    """

    def __call__(self, name: str, w: np.ndarray) -> bool: ...


def is_float_dtype(dt) -> bool:
    """True for any float dtype incl. ml_dtypes extensions (bfloat16...)."""
    return bool(jnp.issubdtype(np.dtype(dt), jnp.floating))


def ndim_float_policy(min_ndim: int = 2) -> PolicyFn:
    """Quantize float tensors of rank >= min_ndim; everything else raw."""
    def policy(name: str, w: np.ndarray) -> bool:
        return w.ndim >= min_ndim and is_float_dtype(w.dtype)
    return policy


def serve_q8_policy(name: str, w: np.ndarray) -> bool:
    """The serving rule: stacked layer tensors (ndim >= 3 — per-layer
    vectors stack to 2-D and stay full precision) and the unstacked 2-D
    embed/head matrices."""
    top = name.split("/", 1)[0]
    stacked = top in STACKED_TOP_KEYS
    return is_float_dtype(w.dtype) and (
        (stacked and w.ndim >= 3) or (not stacked and w.ndim == 2))


# ---------------------------------------------------------------------------
# Quantizer strategies
# ---------------------------------------------------------------------------

class Quantizer:
    """Strategy interface: one tensor -> quantized representation."""

    def quantize(self, name: str,
                 w: np.ndarray) -> QuantizedTensor | Q8Tensor:
        raise NotImplementedError


@dataclass
class RDGridQuantizer(Quantizer):
    """Rate-distortion assignment on the equidistant grid (paper eq. 11).

    DC-v2 shape: a global ``delta``.  DC-v1 shape: pass ``step_for`` (the
    per-layer eq. 12 step) and an ``importance`` dict (F_i = 1/sigma^2)
    keyed by flat tensor name.
    """

    delta: float = 0.01
    lam: float = 0.0
    num_gr: int = B.DEFAULT_NUM_GR
    step_for: Callable[[str, np.ndarray], float] | None = None
    importance: dict | None = None

    def quantize(self, name: str, w: np.ndarray) -> QuantizedTensor:
        w = np.asarray(w)
        step = (self.delta if self.step_for is None
                else float(self.step_for(name, w)))
        fim = (None if self.importance is None
               else np.asarray(self.importance[name]))
        return quantize_tensor_rd(w, step, self.lam, fim, num_gr=self.num_gr)


def relative_step(w: np.ndarray, delta_rel: float,
                  min_step: float = 1e-12) -> float:
    """Per-tensor grid step Delta = delta_rel * std(w).

    (Near-)constant tensors fall back to Delta = delta_rel * max|w|: a
    vanishing std would put a constant-0.5 tensor at level ~5e11,
    overflowing the Huffman symbol range and ballooning the CABAC stream
    for zero accuracy gain.  The floor is relative (std vs 1e-6 * max|w|)
    so constant-up-to-noise tensors are caught too, not just exact ties.
    """
    wf = np.asarray(w, dtype=np.float64)   # no copy when already float64
    if wf.size == 0:
        return min_step
    std = float(wf.std())
    amax = float(np.abs(wf).max())
    scale = std if std > 1e-6 * amax else amax
    return max(delta_rel * scale, min_step)


@dataclass
class NearestStdQuantizer(Quantizer):
    """Nearest-level on the per-tensor :func:`relative_step` grid — the
    deterministic checkpoint quantizer (bit-reproducible resumes)."""

    delta_rel: float = 1e-3
    min_step: float = 1e-12

    def quantize(self, name: str, w: np.ndarray) -> QuantizedTensor:
        w = np.asarray(w)
        wf = w.astype(np.float64)        # one conversion, shared below
        step = relative_step(wf, self.delta_rel, self.min_step)
        levels = nearest_level(wf.ravel(), step).reshape(w.shape)
        return QuantizedTensor(levels, step, str(w.dtype))


def quantize_leaf(w: jnp.ndarray) -> dict:
    """Per-output-channel (last dim) symmetric int8 on the DeepCABAC grid.

    Stacked (L, ..., out) tensors keep a per-layer leading dim on the scale
    so the layer scan can slice codes and scales together."""
    wf = w.astype(jnp.float32)
    if w.ndim >= 3:
        axes = tuple(range(1, w.ndim - 1))
        scale = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)  # (L,1..,out)
        q = jnp.clip(jnp.round(wf / jnp.maximum(scale / 127.0, 1e-12)),
                     -127, 127).astype(jnp.int8)
        scale_out = jnp.maximum(scale.reshape(w.shape[0], w.shape[-1])
                                / 127.0, 1e-12)
        return {"q8": q, "q8s": scale_out.astype(jnp.float32)}
    scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=tuple(
        range(w.ndim - 1))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q8": q, "q8s": scale.astype(jnp.float32)}


def quantize_tree_q8(params):
    """The serving tree pass: int8-quantize the matmul weights in place,
    leaving every other leaf untouched ({"q8","q8s"} leaf dicts).  Leaf
    selection delegates to :func:`serve_q8_policy` so this path and the
    "serve-q8" container codec can never drift apart."""
    from .tree import _path_key

    def visit(path, leaf):
        if not hasattr(leaf, "ndim") or not hasattr(leaf, "dtype"):
            return leaf
        if serve_q8_policy(_path_key(path), leaf):
            return quantize_leaf(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, params)


@dataclass
class PerChannelInt8Quantizer(Quantizer):
    """Per-output-channel symmetric int8 (the serving representation),
    sharing :func:`quantize_leaf` with the in-memory tree pass so the
    container path and the serving path agree bit-for-bit."""

    def quantize(self, name: str, w: np.ndarray) -> Q8Tensor:
        arr = np.asarray(w)
        # host-side container path: keep the shared jnp math on CPU so an
        # (async) checkpoint save never bounces weights off the accelerator
        with jax.default_device(jax.devices("cpu")[0]):
            q = quantize_leaf(jnp.asarray(arr))
            levels, scale = np.asarray(q["q8"]), np.asarray(q["q8s"])
        return Q8Tensor(levels=levels, scale=scale, dtype=str(arr.dtype))
