"""Pytree <-> flat ``{"a/b/c": ndarray}`` mapping used by every codec.

Flat names join the jax key path with "/"; a dict that is already flat
maps through unchanged (its keys contain no nested structure).
"""

from __future__ import annotations

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for k in path:
        parts.append(str(k.key) if hasattr(k, "key") else str(k.idx))
    return "/".join(parts)


def flatten_tree(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_key(path)] = np.asarray(leaf)
    return out


def unflatten_like(flat: dict[str, np.ndarray], template):
    """Rebuild ``template``'s structure from a flat dict, restoring each
    leaf's dtype (incl. bfloat16) and checking shapes.  Quantized
    representations (anything with ``dequantize``, from a
    ``dequantize=False`` decode) are placed as-is — their ``dtype`` field
    already records the reconstruction dtype."""
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_t:
        key = _path_key(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != state "
                f"{np.shape(leaf)}")
        if hasattr(arr, "dequantize"):
            leaves.append(arr)
        else:
            leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
