"""Shared int8 block-quantization primitives.

The quantize-where-you-store recipe used by the 8-bit AdamW moments, the
error-feedback gradient stream and the cross-pod collectives: int8 codes
with per-block (``Q8_BLOCK`` along the last axis) absmax scales.  Lives
here so ``optim``, ``distributed`` and ``serve`` all pull one
implementation instead of reaching into each other's privates.

All ops are elementwise/jit-friendly and shard trivially under pjit
(scales inherit the blocking of the last axis).
"""

from __future__ import annotations

import jax.numpy as jnp

Q8_BLOCK = 128


def q8_blockable(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 1 and shape[-1] % Q8_BLOCK == 0


def q8_encode(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (int8 codes, float32 blockwise scales)."""
    if q8_blockable(x.shape):
        b = x.reshape(*x.shape[:-1], x.shape[-1] // Q8_BLOCK, Q8_BLOCK)
        scale = jnp.max(jnp.abs(b), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        codes = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
        return codes.reshape(x.shape), scale.squeeze(-1).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def q8_decode(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    if codes.ndim >= 1 and codes.shape[-1] % Q8_BLOCK == 0 and \
            scale.ndim == codes.ndim:
        b = codes.reshape(*codes.shape[:-1],
                          codes.shape[-1] // Q8_BLOCK, Q8_BLOCK)
        return (b.astype(jnp.float32) * scale[..., None]).reshape(codes.shape)
    return codes.astype(jnp.float32) * scale


def q8_encode_sqrt(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Second moment in sqrt-space: v spans many orders of magnitude, so
    linear absmax codes flush small entries to zero and destabilize
    1/sqrt(v).  Quantizing sqrt(v) halves the dynamic range in log terms —
    the same trick 8-bit optimizers use via nonlinear quantization maps."""
    return q8_encode(jnp.sqrt(jnp.maximum(v, 0.0)))


def q8_decode_sqrt(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    r = q8_decode(codes, scale)
    return jnp.square(r)


def q8_scale_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    if q8_blockable(shape):
        return (*shape[:-1], shape[-1] // Q8_BLOCK)
    return ()
