"""Unified pytree-native compression API (quantizer x entropy coder).

Every compression path in the repo — DC-v1/v2 research pipelines,
checkpointing, fixed-point serving artifacts, baselines — composes the
same three strategies behind one :class:`Codec`:

    from repro import compression
    codec = compression.get("deepcabac-v2", delta=0.01, lam=1e-4)
    artifact = codec.compress(params)              # any jax pytree
    tree = compression.decompress(artifact.blob, like=params)

Registered codecs: ``deepcabac-v2``, ``deepcabac-v3`` (lane-scheduled
CABAC, container v3), ``deepcabac-rd`` (per-tensor mixed precision from
a swept ``TensorPolicy`` table — see ``rd_search``), ``deepcabac-delta``
(temporal "P-frame" residual coding, container v4), ``ckpt-nearest``,
``serve-q8``, ``huffman``, ``raw`` (see docs/compression_api.md).

Import discipline: only the leaf modules (``artifact``, ``q8``, ``tree``)
load eagerly — they import nothing from ``repro.core``.  The strategy /
registry modules import ``repro.core``, whose ``deepcabac`` imports
``.artifact`` back from this package, so they resolve lazily (PEP 562) to
keep both import orders cycle-free.
"""

from .artifact import Artifact  # noqa: F401
from .q8 import (Q8_BLOCK, q8_blockable, q8_decode,  # noqa: F401
                 q8_decode_sqrt, q8_encode, q8_encode_sqrt, q8_scale_shape)
from .tree import flatten_tree, unflatten_like  # noqa: F401

_LAZY = {
    "Codec": "codec",
    "DeltaCodec": "codec",
    "decompress": "codec",
    "iter_decompress": "codec",
    "DecodeOptions": "codec",
    "EntropyCoder": "coders",
    "CabacCoder": "coders",
    "CabacDeltaCoder": "coders",
    "CabacV3Coder": "coders",
    "HuffmanCoder": "coders",
    "RawLevelCoder": "coders",
    "KVPageCodec": "kv_pages",
    "Quantizer": "quantizers",
    "RDGridQuantizer": "quantizers",
    "NearestStdQuantizer": "quantizers",
    "PerChannelInt8Quantizer": "quantizers",
    "PolicyFn": "quantizers",
    "quantize_leaf": "quantizers",
    "quantize_tree_q8": "quantizers",
    "ndim_float_policy": "quantizers",
    "serve_q8_policy": "quantizers",
    "is_float_dtype": "quantizers",
    "relative_step": "quantizers",
    "get": "registry",
    "make": "registry",
    "register": "registry",
    "available": "registry",
    "TensorRule": "rd_search",
    "TensorPolicy": "rd_search",
    "PolicyQuantizer": "rd_search",
    "resolve_policy": "rd_search",
    "RDSearchConfig": "rd_search",
    "RDPoint": "rd_search",
    "rd_sweep": "rd_search",
    "pareto_front": "rd_search",
    "fisher_for": "rd_search",
    "TaskProxy": "rd_search",
}

__all__ = sorted({"Artifact", "Q8_BLOCK", "q8_blockable", "q8_decode",
                  "q8_decode_sqrt", "q8_encode", "q8_encode_sqrt",
                  "q8_scale_shape", "flatten_tree", "unflatten_like",
                  *_LAZY})


def __getattr__(name: str):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{submodule}", __name__), name)


def __dir__():
    return __all__
