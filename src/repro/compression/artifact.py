"""Shared artifact type every compression path returns.

Deliberately a leaf module (numpy only): ``core.deepcabac`` imports it to
build ``CompressionResult`` on top, so it must not import back into
``repro.core`` or the rest of this package.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Artifact:
    """Result of compressing a pytree: serialized blob + bookkeeping.

    ``quantized`` maps flat tensor names to either the quantized
    representation (anything with a ``dequantize()`` method, e.g.
    ``QuantizedTensor`` / ``Q8Tensor``) or the raw ndarray that passed
    through uncoded.
    """

    blob: bytes
    report: dict
    hyperparams: dict
    quantized: dict = field(repr=False, default_factory=dict)

    def reconstructed(self) -> dict[str, np.ndarray]:
        """Dequantized view of every entry (what a decoder will produce)."""
        out = {}
        for k, v in self.quantized.items():
            out[k] = v.dequantize() if hasattr(v, "dequantize") else v
        return out
