"""KV-page codec: the paper's quantize -> CABAC stack pointed at KV pages.

``KVPageCodec`` (registered as ``kv-q8-cabac``) turns a pytree of gathered
KV-cache pages into one v3-chunked DCBC container and back.  It is the
eviction format of the paged serving cache (``repro.serve.kv``): cold
pages are entropy-coded to host, and re-admission decodes every chunk of
every record through ``decode_level_chunks_batched`` — the lane-parallel
engine — so restores are scheduled exactly like container cold starts.

Two leaf encodings, chosen by the page's storage dtype:

* int8 pages (``cfg.q8_cache=True`` — levels on the ``kv_cache_delta``
  grid) are coded **losslessly**: the int8 levels go straight through
  CABAC, so an evict/restore round trip is bit-exact and a paged session
  stays token-identical to an unpaged one.
* float pages (bf16/f32 caches) are q8 block-quantized first
  (``compression.q8``, per-128-block absmax scales): the codes are
  CABAC-coded and the f32 scales ride along as a raw ``<name>#scale``
  record.  This path is lossy (the restore is the q8 reconstruction), and
  the q8 *levels* themselves round-trip bit-exactly.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core import binarization as B
from ..core.codec import (DecodeOptions, decode_record,
                          encode_level_chunks_batched, resolve_dtype)
from ..core.container import ContainerReader, ContainerWriter
from .artifact import Artifact
from .q8 import q8_decode, q8_encode
from .tree import flatten_tree, unflatten_like

# Flat page names join key paths with "/", so "#" cannot collide.
SCALE_SUFFIX = "#scale"

# Pages are small (a few hundred KiB); smaller chunks than the weight
# codecs' DEFAULT_CHUNK keep enough lanes in flight per record.
KV_PAGE_CHUNK = 1 << 14


@dataclass
class KVPageCodec:
    """Compress/decompress a pytree of KV pages (see module docstring).

    ``step`` records the int8 cache's ``kv_cache_delta`` in each header —
    informational for int8 pages (decode returns the levels; the model
    dequantizes in-kernel), unused for float pages.
    """

    step: float = 1.0
    num_gr: int = B.DEFAULT_NUM_GR
    chunk_size: int = KV_PAGE_CHUNK
    backend: str = "auto"
    name: str = "kv-q8-cabac"

    def compress(self, pages) -> Artifact:
        flat = flatten_tree(pages)
        writer = ContainerWriter()
        raw_bytes = 0
        for tname, arr in flat.items():
            arr = np.asarray(arr)
            raw_bytes += int(arr.nbytes)
            if arr.dtype == np.int8:
                codes = arr
            else:
                codes, scale = q8_encode(jnp.asarray(arr))
                codes = np.asarray(codes)
                writer.add_raw(tname + SCALE_SUFFIX,
                               np.asarray(scale, np.float32))
            chunks, counts = encode_level_chunks_batched(
                codes.astype(np.int64), self.num_gr, self.chunk_size,
                self.backend)
            writer.add_cabac_v3(tname, str(arr.dtype), arr.shape, self.step,
                                self.num_gr, self.chunk_size, chunks, counts)
        blob = writer.tobytes()
        report = {"tensors": len(flat), "raw_bytes": raw_bytes,
                  "compressed_bytes": len(blob),
                  "ratio": len(blob) / max(raw_bytes, 1)}
        return Artifact(blob=blob, report=report,
                        hyperparams={"codec": self.name, "step": self.step,
                                     "num_gr": self.num_gr,
                                     "chunk_size": self.chunk_size})

    def decompress(self, blob: bytes, like=None,
                   opts: DecodeOptions | None = None):
        """blob -> flat ``{name: ndarray}`` (or ``like``'s structure).

        int8 records come back as the stored int8 levels; float records as
        the q8 reconstruction in their original dtype.  All CABAC chunks
        decode through the lane engine selected by ``opts``.
        """
        opts = opts or DecodeOptions()
        tensors: dict[str, object] = {}
        scales: dict[str, np.ndarray] = {}
        for hdr, payload in ContainerReader(blob):
            rec = decode_record(hdr, payload, dequantize=False, opts=opts)
            if hdr.name.endswith(SCALE_SUFFIX):
                scales[hdr.name[:-len(SCALE_SUFFIX)]] = rec
            else:
                tensors[hdr.name] = rec
        out: dict[str, np.ndarray] = {}
        for tname, qt in tensors.items():
            codes = qt.levels.astype(np.int8)
            if tname in scales:
                dec = q8_decode(jnp.asarray(codes),
                                jnp.asarray(scales[tname]))
                out[tname] = np.asarray(dec).astype(resolve_dtype(qt.dtype))
            else:
                out[tname] = codes
        if like is not None:
            return unflatten_like(out, like)
        return out
