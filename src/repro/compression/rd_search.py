"""Rate-distortion Pareto search across the config zoo (ROADMAP item 5).

The paper's headline claim is RD-*optimal* quantization — eq. 11,
minimize rate + lambda * FIM-weighted distortion — but a single
hand-picked (step, lambda) exercises none of the "optimal".  This module
sweeps the RD grid per model config, measures what actually matters for
deployment (compressed container bytes vs a task-proxy distortion
through the real serving path), and distils the result into a deployable
artifact: a :class:`TensorPolicy` table mapping each flat tensor name to
its own (step, lambda, quantizer-kind) operating point, consumed by the
registered ``deepcabac-rd`` codec and accepted by ``CheckpointManager``,
the serve ``WeightBackend``s, and ``ModelZoo`` admission.

Pipeline (:func:`rd_sweep`):

1. **Global grid** — for each (delta_rel, lambda) point, RD-assign every
   covered tensor (``rd_quant`` kernel dispatch on TPU, the numpy oracle
   elsewhere — see :func:`rd_assign_levels`), entropy-code the full tree
   into a real lane-scheduled v3 container, decode it back, and measure
   greedy-token disagreement + last-position logit KL against the
   uncompressed model through ``ServeSession`` (:class:`TaskProxy`).
2. **Pareto front** — :func:`pareto_front` marks the non-dominated
   (bytes, distortion) points; the winner is the cheapest point within
   the token-error budget.
3. **Per-tensor refinement** — the constrained form of eq. 11: starting
   from the winner's uniform operating point, greedily coarsen the steps
   of the tensors with the best rate-saving per unit FIM-weighted
   distortion (R_hat from ``rate_model.estimate_level_bits``, D_t =
   sum_i F_i (w_i - Delta k_i)^2 with F the empirical Fisher diagonal of
   ``core/fim.py``) until a distortion budget relative to the winner is
   spent.  The FIM decides *which tensors tolerate coarser grids*, while
   level assignment itself stays F=1 so the deployed ``deepcabac-rd``
   encode is bit-identical to what the sweep measured.  (Scoring the
   unconstrained J = R + lam*D at the winner's lambda instead degenerates:
   the small lambdas that win the global grid make the rate term dominate
   any step change, so every tensor coarsens at once.)  The refined table
   is re-validated end to end and reverted wholesale if it leaves the
   token-error budget.

Determinism: everything here is seeded and assignment is the registered
``rd_quant`` oracle, so a saved policy table re-applied through
``get("deepcabac-rd", policy_table=...)`` reproduces the swept container
byte for byte.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

import numpy as np

from ..core import binarization as B
from ..core.codec import QuantizedTensor
from ..core.quant import nearest_level, rd_assign
from ..core.rate_model import (build_rate_table, estimate_bin_probs,
                               estimate_level_bits)
from .quantizers import (PerChannelInt8Quantizer, Quantizer,
                         ndim_float_policy, relative_step)
from .tree import flatten_tree

RULE_KINDS = ("rd-grid", "q8", "raw")
POLICY_FORMAT = "repro-tensor-policy"
POLICY_VERSION = 1


# ---------------------------------------------------------------------------
# TensorPolicy: the deployable artifact
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TensorRule:
    """One tensor's operating point: grid step, RD lambda, quantizer kind
    (``rd-grid`` | ``q8`` | ``raw``)."""

    step: float
    lam: float = 0.0
    kind: str = "rd-grid"

    def __post_init__(self):
        if self.kind not in RULE_KINDS:
            raise ValueError(f"unknown rule kind {self.kind!r}; "
                             f"expected one of {RULE_KINDS}")


@dataclass
class TensorPolicy:
    """Flat-name -> :class:`TensorRule` table + provenance metadata.

    The serialized form (``save``/``load``, plain JSON) is what benches
    commit and configs reference by path; ``meta`` records where the
    table came from (arch, winning grid point, seed) so a policy file is
    auditable on its own.
    """

    rules: dict[str, TensorRule] = field(default_factory=dict)
    meta: dict = field(default_factory=dict)

    def rule_for(self, name: str) -> TensorRule | None:
        return self.rules.get(name)

    def to_dict(self) -> dict:
        return {
            "format": POLICY_FORMAT,
            "version": POLICY_VERSION,
            "meta": dict(self.meta),
            "rules": {name: {"step": r.step, "lam": r.lam, "kind": r.kind}
                      for name, r in sorted(self.rules.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TensorPolicy":
        if d.get("format") != POLICY_FORMAT:
            raise ValueError(
                f"not a tensor-policy payload (format="
                f"{d.get('format')!r}, want {POLICY_FORMAT!r})")
        if int(d.get("version", -1)) > POLICY_VERSION:
            raise ValueError(
                f"tensor-policy version {d['version']} is newer than "
                f"this reader ({POLICY_VERSION})")
        rules = {name: TensorRule(step=float(r["step"]),
                                  lam=float(r.get("lam", 0.0)),
                                  kind=str(r.get("kind", "rd-grid")))
                 for name, r in d.get("rules", {}).items()}
        return cls(rules=rules, meta=dict(d.get("meta", {})))

    def save(self, path: str | os.PathLike) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str | os.PathLike) -> "TensorPolicy":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def resolve_policy(obj) -> TensorPolicy:
    """Coerce the ``policy_table=`` forms the registry accepts — a
    :class:`TensorPolicy`, its ``to_dict`` payload, or a JSON path."""
    if isinstance(obj, TensorPolicy):
        return obj
    if isinstance(obj, dict):
        return TensorPolicy.from_dict(obj)
    if isinstance(obj, (str, os.PathLike)):
        return TensorPolicy.load(obj)
    raise TypeError(
        f"policy_table must be a TensorPolicy, dict payload, or JSON "
        f"path; got {type(obj).__name__}")


# ---------------------------------------------------------------------------
# Level assignment: one entry point over the kernel and the host oracle
# ---------------------------------------------------------------------------

def _use_kernel(assign: str) -> bool:
    if assign == "host":
        return False
    if assign == "kernel":
        return True
    if assign != "auto":
        raise ValueError(f"assign must be auto|kernel|host, got {assign!r}")
    import jax
    return jax.default_backend() == "tpu"


def rd_assign_levels(w: np.ndarray, step: float, lam: float,
                     fim: np.ndarray | None = None, *,
                     num_gr: int = B.DEFAULT_NUM_GR, assign: str = "auto",
                     window: int = 4, passes: int = 2,
                     refinements: int = 1) -> np.ndarray:
    """Eq.-11 level assignment with the standard NN-seed -> statistics ->
    assignment loop, routed through the registered ``rd_quant`` kernel on
    TPU and the numpy oracle (``core.quant.rd_assign``) elsewhere.

    ``assign="auto"`` picks per backend.  The kernel's jit wrapper treats
    (step, lam) as static arguments, so a per-tensor-step sweep on CPU
    would recompile once per tensor per grid point — the host oracle is
    the right default there and is the reference the kernel is
    differentially tested against, so both routes yield the same levels.
    Returns int64 levels with ``w``'s shape.
    """
    arr = np.asarray(w)
    flat = arr.astype(np.float64).ravel()
    nn = nearest_level(flat, step)
    if lam == 0.0:
        return nn.reshape(arr.shape)  # RD reduces to nearest-neighbour
    max_level = int(np.abs(nn).max()) + window + 1
    fl = None if fim is None else np.asarray(fim, dtype=np.float64).ravel()
    use_kernel = _use_kernel(assign)
    levels = nn
    for _ in range(1 + max(refinements, 0)):
        probs = estimate_bin_probs(levels, num_gr)
        if use_kernel:
            from .. import kernels
            levels = np.asarray(kernels.get("rd_quant")(
                flat, fl, probs, step=step, lam=lam, window=window,
                max_level=max_level, passes=passes)).astype(np.int64)
        else:
            table = build_rate_table(probs, max_level)
            levels = rd_assign(flat, fl, step, lam, table, window=window,
                               max_level=max_level, passes=passes)
    return levels.reshape(arr.shape)


@dataclass
class PolicyQuantizer(Quantizer):
    """Per-tensor mixed precision: each leaf is quantized on its
    :class:`TensorRule` from the table — ``rd-grid`` through
    :func:`rd_assign_levels` at the rule's own (step, lambda), ``q8``
    through the per-channel int8 serving quantizer.  The ``deepcabac-rd``
    codec's policy fn keeps uncovered/``raw`` leaves away from here."""

    table: TensorPolicy = field(default_factory=TensorPolicy)
    num_gr: int = B.DEFAULT_NUM_GR
    assign: str = "auto"
    window: int = 4
    passes: int = 2
    refinements: int = 1

    def quantize(self, name: str, w: np.ndarray):
        rule = self.table.rule_for(name)
        if rule is None or rule.kind == "raw":
            raise ValueError(
                f"PolicyQuantizer reached {name!r} without an applicable "
                f"rule — the codec policy fn must exclude it")
        arr = np.asarray(w)
        if rule.kind == "q8":
            return PerChannelInt8Quantizer().quantize(name, arr)
        levels = rd_assign_levels(
            arr, rule.step, rule.lam, num_gr=self.num_gr,
            assign=self.assign, window=self.window, passes=self.passes,
            refinements=self.refinements)
        return QuantizedTensor(levels=levels, step=rule.step,
                               dtype=str(arr.dtype))


# ---------------------------------------------------------------------------
# Task-proxy distortion through the serving path
# ---------------------------------------------------------------------------

class TaskProxy:
    """Distortion oracle: greedy-token disagreement + last-position logit
    KL of a candidate weight tree against the uncompressed reference,
    measured through the real request path (``ServeSession``, greedy
    decode) — not a weight-space MSE.  Token-input archs only (the VLM
    configs take embeds; their text towers are covered by the same
    families elsewhere in the zoo)."""

    def __init__(self, cfg, ref_params, *, prompts: int = 4,
                 prompt_len: int = 8, decode_steps: int = 8, seed: int = 0):
        import jax

        self.cfg = cfg
        self.decode_steps = decode_steps
        rng = np.random.default_rng(seed)
        self.prompts = [
            rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
            for _ in range(prompts)]
        self.ref_tokens = self._greedy_tokens(ref_params)
        self.ref_logp = np.asarray(
            jax.nn.log_softmax(self._last_logits(ref_params), axis=-1),
            dtype=np.float64)

    def _greedy_tokens(self, params) -> list[list[int]]:
        from ..serve.session import ServeConfig, ServeSession
        scfg = ServeConfig(slots=len(self.prompts),
                           max_len=len(self.prompts[0]) + self.decode_steps)
        session = ServeSession(self.cfg, params, backend="bf16",
                               serve_cfg=scfg)
        handles = [session.submit(p, max_new_tokens=self.decode_steps)
                   for p in self.prompts]
        session.run()
        return [[int(t) for t in h.tokens] for h in handles]

    def _last_logits(self, params) -> np.ndarray:
        from ..models.transformer import prefill
        logits, _ = prefill(params, self.cfg,
                            tokens=np.stack(self.prompts))
        return np.asarray(logits, dtype=np.float64)

    def measure(self, cand_params) -> dict:
        """-> {"token_err", "logit_kl"} of the candidate tree."""
        import jax

        cand_tokens = self._greedy_tokens(cand_params)
        total = sum(len(t) for t in self.ref_tokens)
        wrong = sum(a != b for ref, got in zip(self.ref_tokens, cand_tokens)
                    for a, b in zip(ref, got))
        cand_logp = np.asarray(
            jax.nn.log_softmax(self._last_logits(cand_params), axis=-1),
            dtype=np.float64)
        kl = float(np.mean(np.sum(
            np.exp(self.ref_logp) * (self.ref_logp - cand_logp), axis=-1)))
        return {"token_err": wrong / max(total, 1),
                "logit_kl": max(kl, 0.0)}


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------

@dataclass
class RDSearchConfig:
    """Sweep knobs.  The defaults are smoke-scale (CI); the nightly bench
    widens the grids."""

    delta_rels: tuple = (2e-3, 6e-3, 2e-2)   # relative grid steps
    lambdas: tuple = (0.0, 3e-4, 1e-3)       # RD trade-off points
    num_gr: int = B.DEFAULT_NUM_GR
    min_ndim: int = 2                         # tensors below stay raw
    prompts: int = 4
    prompt_len: int = 8
    decode_steps: int = 8
    seed: int = 0
    token_err_budget: float = 0.0             # winner must stay within
    refine: bool = True                       # stage-B per-tensor search
    refine_factors: tuple = (2.0, 4.0)        # coarser steps to try
    refine_dist_growth: float = 1.0           # stage-B FIM-weighted
    # distortion budget, as a fraction of the winner's own distortion
    fim_batches: int = 2                      # 0 => F_i = 1 refinement
    fim_batch: int = 2
    fim_seq: int = 16
    assign: str = "auto"                      # rd_assign_levels routing


@dataclass
class RDPoint:
    """One measured grid point of the bytes-vs-distortion plane."""

    delta_rel: float
    lam: float
    bytes: int
    token_err: float
    logit_kl: float
    on_front: bool = False

    def to_dict(self) -> dict:
        return {"delta_rel": self.delta_rel, "lam": self.lam,
                "bytes": self.bytes, "token_err": round(self.token_err, 6),
                "logit_kl": round(self.logit_kl, 8),
                "on_front": self.on_front}


@dataclass
class RDSweepResult:
    points: list[RDPoint]
    policy: TensorPolicy
    winner: RDPoint
    policy_bytes: int
    policy_token_err: float
    policy_logit_kl: float
    refined_tensors: int        # rules coarsened past the winner's step
    reverted: bool              # stage-B left the budget and was undone


def _distortion_key(p: RDPoint) -> tuple:
    return (p.token_err, p.logit_kl)


def pareto_front(points: list[RDPoint]) -> list[RDPoint]:
    """Mark and return the non-dominated points of the (bytes,
    (token_err, logit_kl)) plane, cheapest first.  q dominates p when it
    is <= on both axes and strictly better on one."""
    for p in points:
        p.on_front = not any(
            q is not p and q.bytes <= p.bytes
            and _distortion_key(q) <= _distortion_key(p)
            and (q.bytes < p.bytes or _distortion_key(q) < _distortion_key(p))
            for q in points)
    return sorted((p for p in points if p.on_front),
                  key=lambda p: (p.bytes, _distortion_key(p)))


def fisher_for(cfg, params, *, batches: int = 2, batch: int = 2,
               seq: int = 16, seed: int = 0):
    """Empirical Fisher diagonal of ``params`` on the synthetic training
    stream (``data.pipeline.make_batch``) — the F_i of eq. 11."""
    from ..core.fim import empirical_fisher_diag
    from ..data.pipeline import make_batch
    from ..models.transformer import train_loss

    bs = [make_batch(cfg, i, batch=batch, seq=seq, seed=seed)
          for i in range(max(batches, 1))]
    return empirical_fisher_diag(lambda p, b: train_loss(p, b, cfg),
                                 params, bs, max_batches=len(bs))


def _sweep_codec(num_gr: int):
    from .coders import CabacV3Coder
    from .codec import Codec
    return Codec("rd-sweep", coder=CabacV3Coder(num_gr=num_gr))


def _measure_entries(codec, entries: dict, like, proxy: TaskProxy):
    """Encode a full entry dict into a real container, decode it back,
    and score it — bytes and distortion both come from the artifact a
    deployment would actually ship."""
    from .codec import decompress
    art = codec.compress_entries(entries)
    cand = decompress(art.blob, like=like)
    d = proxy.measure(cand)
    return len(art.blob), d


def rd_sweep(cfg, params, search: RDSearchConfig | None = None,
             fim=None) -> RDSweepResult:
    """Sweep the RD grid for one model config; see the module docstring
    for the three stages.  ``fim`` (a pytree matching ``params``)
    overrides the empirical-Fisher computation; pass it when the caller
    already has curvature estimates (e.g. from training)."""
    search = search or RDSearchConfig()
    proxy = TaskProxy(cfg, params, prompts=search.prompts,
                      prompt_len=search.prompt_len,
                      decode_steps=search.decode_steps, seed=search.seed)
    flat = {name: np.asarray(w) for name, w in flatten_tree(params).items()}
    covered_by = ndim_float_policy(search.min_ndim)
    covered = {name: w for name, w in flat.items()
               if w.size > 0 and covered_by(name, w)}
    if not covered:
        raise ValueError(f"config {cfg.name!r}: no tensors pass the "
                         f"min_ndim={search.min_ndim} policy")
    codec = _sweep_codec(search.num_gr)

    def entries_for(rules: dict[str, TensorRule]) -> dict:
        out = dict(flat)
        for name, rule in rules.items():
            levels = rd_assign_levels(
                covered[name], rule.step, rule.lam, num_gr=search.num_gr,
                assign=search.assign)
            out[name] = QuantizedTensor(levels=levels, step=rule.step,
                                        dtype=str(covered[name].dtype))
        return out

    # -- stage A: global (delta_rel, lambda) grid ------------------------
    points: list[RDPoint] = []
    rules_at: dict[tuple, dict[str, TensorRule]] = {}
    for dr in search.delta_rels:
        steps = {name: relative_step(w, dr) for name, w in covered.items()}
        for lam in search.lambdas:
            rules = {name: TensorRule(step=steps[name], lam=lam)
                     for name in covered}
            size, d = _measure_entries(codec, entries_for(rules), params,
                                       proxy)
            rules_at[(dr, lam)] = rules
            points.append(RDPoint(delta_rel=dr, lam=lam, bytes=size,
                                  token_err=d["token_err"],
                                  logit_kl=d["logit_kl"]))

    front = pareto_front(points)
    in_budget = [p for p in front if p.token_err <= search.token_err_budget]
    winner = (min(in_budget, key=lambda p: (p.bytes, p.logit_kl))
              if in_budget
              else min(front, key=lambda p: (_distortion_key(p), p.bytes)))

    # -- stage B: distortion-budgeted per-tensor refinement ---------------
    rules = dict(rules_at[(winner.delta_rel, winner.lam)])
    refined, reverted = 0, False
    if search.refine and search.refine_factors:
        fim_flat = (flatten_tree(fim) if fim is not None
                    else flatten_tree(fisher_for(
                        cfg, params, batches=search.fim_batches,
                        batch=search.fim_batch, seq=search.fim_seq,
                        seed=search.seed))
                    if search.fim_batches > 0 else {})

        def wdist(name: str, step: float, levels: np.ndarray) -> float:
            w = covered[name].astype(np.float64)
            f = fim_flat.get(name)
            fw = (np.ones_like(w) if f is None
                  else np.asarray(f, dtype=np.float64))
            return float((fw * (w - step * levels) ** 2).sum())

        # candidate coarsenings: (bits saved) / (FIM-weighted distortion
        # added), at most one step change per tensor
        total_base_dist = 0.0
        cands: list[tuple[float, float, str, TensorRule]] = []
        for name in covered:
            base = rules[name]
            base_levels = rd_assign_levels(
                covered[name], base.step, base.lam, num_gr=search.num_gr,
                assign=search.assign)
            base_bits = estimate_level_bits(base_levels, search.num_gr)
            total_base_dist += wdist(name, base.step, base_levels)
            for fac in search.refine_factors:
                step2 = base.step * fac
                levels2 = rd_assign_levels(
                    covered[name], step2, base.lam, num_gr=search.num_gr,
                    assign=search.assign)
                saved = base_bits - estimate_level_bits(levels2,
                                                        search.num_gr)
                grown = (wdist(name, step2, levels2)
                         - wdist(name, base.step, base_levels))
                if saved > 0:
                    eff = saved / max(grown, 1e-30)
                    cands.append((eff, grown, name,
                                  TensorRule(step=step2, lam=base.lam)))

        budget = search.refine_dist_growth * total_base_dist
        taken: set[str] = set()
        for eff, grown, name, rule in sorted(cands, key=lambda c: -c[0]):
            if name in taken or grown > budget:
                continue
            budget -= grown
            rules[name] = rule
            taken.add(name)
        refined = len(taken)

        if refined:
            size, d = _measure_entries(codec, entries_for(rules), params,
                                       proxy)
            err_budget = max(search.token_err_budget, winner.token_err)
            if d["token_err"] > err_budget:
                rules = dict(rules_at[(winner.delta_rel, winner.lam)])
                refined, reverted = 0, True

    policy = TensorPolicy(
        rules=rules,
        meta={"arch": cfg.name, "delta_rel": winner.delta_rel,
              "lam": winner.lam, "num_gr": search.num_gr,
              "min_ndim": search.min_ndim, "seed": search.seed,
              "refined_tensors": refined,
              "grid": {"delta_rels": list(search.delta_rels),
                       "lambdas": list(search.lambdas)}})

    # -- final validation through the registered codec itself ------------
    from .registry import get as _get
    rd_codec = _get("deepcabac-rd", policy_table=policy,
                    num_gr=search.num_gr, min_ndim=search.min_ndim,
                    assign=search.assign)
    from .codec import decompress
    art = rd_codec.compress(params)
    d = proxy.measure(decompress(art.blob, like=params))
    return RDSweepResult(points=points, policy=policy, winner=winner,
                         policy_bytes=len(art.blob),
                         policy_token_err=d["token_err"],
                         policy_logit_kl=d["logit_kl"],
                         refined_tensors=refined, reverted=reverted)
