# NOTE: ServeEngine is imported lazily (repro.serve.engine) to avoid a
# circular import: models.transformer uses serve.quantized for the
# fixed-point serving path.


def __getattr__(name):
    if name == "ServeEngine":
        from .engine import ServeEngine
        return ServeEngine
    raise AttributeError(name)
