# NOTE: the serving classes are imported lazily (PEP 562) to avoid a
# circular import: models.transformer uses serve.quantized for the
# fixed-point serving path, and session/backends import models back.

_LAZY = {
    "ServeEngine": "engine",
    "ServeSession": "session",
    "ServeConfig": "session",
    "RequestHandle": "session",
    "WeightBackend": "backends",
    "get_backend": "backends",
    "register_backend": "backends",
    "available_backends": "backends",
    "KVColdStore": "backends",
    "get_kv_store": "backends",
    "register_kv_store": "backends",
    "available_kv_stores": "backends",
    "PagedKV": "kv",
    "kv_cache_bytes": "kv",
    "ShardStore": "zoo",
    "ModelZoo": "zoo",
    "ZooConfig": "zoo",
    "ZooRouter": "zoo",
    "ZooHandle": "zoo",
    "ZooError": "zoo",
    "AdmissionStall": "zoo",
    "model_resident_bytes": "zoo",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(name)
    import importlib
    return getattr(importlib.import_module(f".{submodule}", __name__), name)


def __dir__():
    return __all__
