"""Paged KV cache with entropy-coded eviction and prefix sharing.

``PagedKV`` replaces the session's monolithic slot-indexed KV arrays with
three pieces (ROADMAP item 3):

* a **hot page pool** — ``init_cache(cfg, pool_pages, page_size)``, so
  every cache leaf is a pool of fixed-size token pages, (L, P, page, ...)
  with the layer axis leading exactly like the slot caches it replaces.
  Pool page 0 is a scratch page: it is never allocated, and padding rows
  of a compacted decode batch aim all their reads/writes at it.
* a **page table** — per slot, an ordered list of pool page ids covering
  the slot's written positions; decode hands the model a dense
  (B, n_max) int32 ``cache_pages`` map (see
  ``models.attention._paged_update_load``).
* a **compressed cold store** — cold pages (idle shared prefixes, parked
  sessions) are coded by the ``kv-q8-cabac`` codec (int8 cache levels
  CABAC-coded losslessly; float caches q8-block-quantized first) into
  v3-chunked DCBC records and moved to a :class:`~.backends.KVColdStore`.
  Restores decode every chunk through the lane-parallel batched decoder,
  optionally on a worker thread so entropy decode hides behind the
  admission path.

Prefix sharing is copy-on-write by construction: only *full, page-aligned
prompt pages strictly before the last prompt token* are ever published to
the share index, so the page a slot writes into is always private
(asserted per step in :meth:`PagedKV.ensure_writable`).  Two requests
with the same system prompt attach the same page ids and prefill only
their suffixes.

Refcounting: ``page_refs[pid]`` counts holders — each slot whose table
contains the page, plus one for the share index if the page is
published.  A page frees when its count reaches zero; the share index
spills its sole-held (refs == 1) pages to the cold store under LRU
pressure and restores them on the next prefix hit.
"""

from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.registry import get as _get_codec
from ..compression.tree import _path_key
from ..models.transformer import init_cache


class PageError(RuntimeError):
    """The page pool cannot satisfy a request it must (misconfiguration)."""


def kv_cache_bytes(cfg, batch: int, max_len: int) -> int:
    """Device bytes of ``init_cache(cfg, batch, max_len)`` without
    allocating it — the one source of truth for capacity accounting
    (``ServeSession.kv_bytes_per_slot`` and the paging bench both read
    this instead of re-deriving cache shapes)."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return int(sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(shapes)))


@dataclass
class ParkedPages:
    """What :meth:`PagedKV.park` hands back: enough to rebuild the slot's
    page table.  ``prefix_keys`` re-attach through the share index;
    ``cold_key`` names the jointly-coded private pages in the store."""

    cold_key: str
    prefix_keys: tuple
    n_private: int
    _future: object = field(default=None, repr=False)


class _SharedPage:
    __slots__ = ("pid",)

    def __init__(self, pid):
        self.pid: int | None = pid     # None => spilled to the cold store


def _page_keys(prompt: np.ndarray, page: int) -> list[str]:
    """Share-index keys for every *full* prompt page strictly before the
    last prompt token — capping at ``(len-1) // page`` guarantees at
    least one suffix token remains to prefill, and that the published
    pages can never be a slot's write target."""
    n = (prompt.size - 1) // page
    h = hashlib.sha256()
    keys = []
    for i in range(n):
        h.update(np.ascontiguousarray(
            prompt[i * page:(i + 1) * page], np.int32).tobytes())
        keys.append(h.hexdigest())
    return keys


def _gather_pages(pools, ids):
    """Pool leaves (L, P, page, ...) -> gathered (L, n, page, ...)."""
    return jax.tree.map(lambda p: jnp.take(p, ids, axis=1), pools)


def _scatter_pages(pools, vals, ids):
    return jax.tree.map(
        lambda p, v: p.at[:, ids].set(jnp.asarray(v).astype(p.dtype)),
        pools, vals)


class PagedKV:
    """Page table + hot pool + compressed cold store for one session.

    The session owns scheduling (which slot parks, when resumes run);
    this class owns every page: allocation/refcounts, the share index,
    compression to and restoration from the cold store, and the jitted
    pool gather/scatter.  ``slot`` arguments are the session's slot
    indices.
    """

    def __init__(self, cfg, *, slots: int, max_len: int, page_size: int,
                 pool_pages: int | None = None, cold_store="host",
                 codec: str = "kv-q8-cabac", prefix_sharing: bool = True,
                 restore_workers: int = 0, decode_opts=None):
        from ..compression.codec import DecodeOptions
        from .backends import resolve_kv_store

        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                "paged KV serving needs an attention-family cache; the "
                f"{cfg.family!r} state cache has no token axis to page")
        if page_size < 1:
            raise ValueError(f"kv_page_size must be >= 1; got {page_size}")
        self.cfg = cfg
        self.page = int(page_size)
        self.n_max = -(-max_len // self.page)        # page-table width
        if pool_pages is None:
            # enough for every slot at max_len, plus the scratch page —
            # the "no eviction pressure" default; deployments shrink it
            pool_pages = slots * self.n_max + 1
        if pool_pages < self.n_max + 1:
            raise PageError(
                f"kv_pool_pages={pool_pages} cannot hold one full-length "
                f"slot ({self.n_max} pages) + the scratch page")
        self.pool_pages = int(pool_pages)
        self.pools = init_cache(cfg, self.pool_pages, self.page)
        self.prefix_sharing = bool(prefix_sharing)

        self.page_refs = np.zeros(self.pool_pages, np.int32)
        self.page_refs[0] = 1                        # scratch: never freed
        self._free: list[int] = list(range(self.pool_pages - 1, 0, -1))
        self._pages: dict[int, list[int]] = {}       # slot -> page ids
        self._keys: dict[int, list[str]] = {}        # slot -> prompt keys
        self._index: OrderedDict[str, _SharedPage] = OrderedDict()

        # strict=False: ``codec`` is user-chosen (kv_evict_codec) and the
        # grid-step override only applies to step-taking page codecs
        self.codec = _get_codec(codec, strict=False,
                                step=cfg.kv_cache_delta)
        self.store = resolve_kv_store(cold_store)
        # every cold blob (parked private pages, spilled shared pages) is
        # held through the refcounted GC, so a request that goes away
        # while parked drops its blob instead of leaking it in the store
        # (dir-backed stores would otherwise keep the file until close())
        from .backends import BlobGC
        self._gc = BlobGC(self.store.drop)
        self.decode_opts = decode_opts or DecodeOptions()
        self._executor = (ThreadPoolExecutor(max_workers=restore_workers)
                          if restore_workers > 0 else None)
        self._park_seq = 0
        self._treedef = jax.tree_util.tree_structure(self.pools)
        self._leaf_names = [
            _path_key(p) for p, _ in
            jax.tree_util.tree_flatten_with_path(self.pools)[0]]
        self._gather = jax.jit(_gather_pages)
        self._scatter = jax.jit(_scatter_pages)
        self.stats = {
            "pages_evicted": 0, "pages_restored": 0, "restores": 0,
            "restore_s": 0.0, "bytes_to_host": 0, "bytes_from_host": 0,
            "prefix_hits": 0, "prefix_pages_reused": 0, "spills": 0,
        }

    # -- allocation ---------------------------------------------------------

    def free_pages(self) -> int:
        return len(self._free)

    def _alloc(self, n: int) -> list[int] | None:
        if len(self._free) < n:
            return None
        ids = [self._free.pop() for _ in range(n)]
        for pid in ids:
            self.page_refs[pid] = 1
        return ids

    def _deref(self, pid: int) -> None:
        self.page_refs[pid] -= 1
        assert self.page_refs[pid] >= 0, f"page {pid} over-released"
        if self.page_refs[pid] == 0:
            self._free.append(pid)

    def _ensure_free(self, n: int, pin=frozenset(), make_room=None) -> bool:
        """Spill sole-held shared pages (LRU, except ``pin``) — then ask
        the session's ``make_room`` (park a victim slot) — until ``n``
        pages are free.  False when neither can free more."""
        while len(self._free) < n:
            if self._spill_one(pin):
                continue
            if make_room is not None and make_room():
                continue
            return False
        return True

    def _spill_one(self, pin=frozenset()) -> bool:
        for key, entry in self._index.items():
            if (entry.pid is not None and key not in pin
                    and self.page_refs[entry.pid] == 1):
                blob = self._compress([entry.pid])
                self.store.put("share:" + key, blob)
                self._gc.hold("share:" + key)
                self._deref(entry.pid)
                entry.pid = None
                self.stats["spills"] += 1
                return True
        return False

    # -- compression to / from the cold store -------------------------------

    def _compress(self, ids: list[int]) -> bytes:
        vals = self._gather(self.pools, jnp.asarray(ids, jnp.int32))
        art = self.codec.compress(vals)
        self.stats["pages_evicted"] += len(ids)
        self.stats["bytes_to_host"] += len(art.blob)
        return art.blob

    def _decompress(self, blob: bytes) -> list[np.ndarray]:
        """Entropy-decode one page blob to pool-ordered leaves (the slow,
        lane-parallel part — safe to run on a worker thread)."""
        t0 = time.perf_counter()
        flat = self.codec.decompress(blob, opts=self.decode_opts)
        self.stats["restore_s"] += time.perf_counter() - t0
        self.stats["restores"] += 1
        self.stats["bytes_from_host"] += len(blob)
        return [flat[name] for name in self._leaf_names]

    def _restore(self, leaves: list[np.ndarray], ids: list[int]) -> None:
        vals = jax.tree_util.tree_unflatten(self._treedef, leaves)
        self.pools = self._scatter(self.pools, vals,
                                   jnp.asarray(ids, jnp.int32))
        self.stats["pages_restored"] += len(ids)

    # -- admission ----------------------------------------------------------

    def admit(self, slot: int, prompt: np.ndarray, *, min_len: int = 0,
              make_room=None) -> int | None:
        """Build ``slot``'s page table for ``prompt``: attach the longest
        shared prefix present in the index (restoring spilled pages) and
        allocate private pages for the rest (at least ``min_len``
        positions when no prefix hit — bucketed-prefill padding needs its
        pad positions page-backed).  Returns the attached prefix length
        in tokens, or None when the pool cannot provide the pages."""
        assert slot not in self._pages, f"slot {slot} already has pages"
        keys = _page_keys(prompt, self.page) if self.prefix_sharing else []
        chain = 0
        while chain < len(keys) and keys[chain] in self._index:
            chain += 1
        ctx_keys = keys[:chain]
        ctx_len = chain * self.page
        total_len = (max(int(prompt.size), int(min_len)) if chain == 0
                     else int(prompt.size))
        n_suffix = -(-(total_len - ctx_len) // self.page)
        n_cold = sum(1 for k in ctx_keys if self._index[k].pid is None)
        if not self._ensure_free(n_cold + n_suffix, pin=set(ctx_keys),
                                 make_room=make_room):
            return None
        ctx_ids = self._attach(ctx_keys)
        suffix_ids = self._alloc(n_suffix)
        assert suffix_ids is not None   # _ensure_free reserved them
        self._pages[slot] = ctx_ids + suffix_ids
        self._keys[slot] = keys
        if chain:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_pages_reused"] += chain
        return ctx_len

    def _attach(self, keys: list[str]) -> list[int]:
        """Take a slot hold on each shared page, restoring spilled ones
        (allocation already reserved by the caller)."""
        ids = []
        for key in keys:
            entry = self._index[key]
            if entry.pid is None:
                [pid] = self._alloc(1)        # this hold = the index's
                self._restore(self._decompress(self.store.get("share:" + key)),
                              [pid])
                self._gc.release("share:" + key)
                entry.pid = pid
            self.page_refs[entry.pid] += 1    # the slot's hold
            self._index.move_to_end(key)
            ids.append(entry.pid)
        return ids

    def publish(self, slot: int) -> None:
        """After the admission prefill: publish the slot's full prompt
        pages to the share index so later requests attach them."""
        if not self.prefix_sharing:
            return
        ids, keys = self._pages[slot], self._keys[slot]
        for i, key in enumerate(keys):
            if key in self._index:
                continue                      # attached at admission
            self._index[key] = _SharedPage(ids[i])
            self.page_refs[ids[i]] += 1
            self._index.move_to_end(key)

    # -- decode-time paging -------------------------------------------------

    def slot_ids(self, slot: int) -> list[int]:
        return list(self._pages[slot])

    def page_row(self, slot: int) -> np.ndarray:
        """Dense (n_max,) page-table row; unwritten logical pages point at
        the scratch page (their reads are masked by ``kv_len``)."""
        row = np.zeros(self.n_max, np.int32)
        ids = self._pages[slot]
        row[:len(ids)] = ids
        return row

    def ensure_writable(self, slot: int, pos: int, make_room=None) -> bool:
        """Make position ``pos`` writable for ``slot`` (allocate the next
        page at a boundary).  False => pool pressure: the caller parks."""
        ids = self._pages[slot]
        wp = pos // self.page
        if wp == len(ids):
            if not self._ensure_free(1, make_room=make_room):
                return False
            ids.extend(self._alloc(1))
        # copy-on-write invariant: the write target is never shared
        assert self.page_refs[ids[wp]] == 1, \
            f"CoW violation: slot {slot} writing into shared page {ids[wp]}"
        return True

    # -- park / resume / release --------------------------------------------

    def park(self, slot: int) -> ParkedPages:
        """Evict the slot's pages: prefix pages that live in the share
        index just drop this slot's hold; the private tail is jointly
        entropy-coded to the cold store.  The slot's table is cleared."""
        ids = self._pages.pop(slot)
        keys = self._keys.pop(slot)
        n_shared = 0
        while n_shared < len(keys) and keys[n_shared] in self._index:
            n_shared += 1
        private = ids[n_shared:]
        assert private, "a parked slot always has at least its write page"
        self._park_seq += 1
        cold_key = f"park:{self._park_seq}"
        self.store.put(cold_key, self._compress(private))
        self._gc.hold(cold_key)
        for pid in ids[:n_shared]:
            self._deref(pid)
        for pid in private:
            self._deref(pid)
        return ParkedPages(cold_key=cold_key,
                           prefix_keys=tuple(keys[:n_shared]),
                           n_private=len(private))

    def prefetch(self, parked: ParkedPages) -> None:
        """Start entropy-decoding the parked pages on a worker thread so
        the restore latency hides behind admission/decode; no-op without
        ``restore_workers``."""
        if self._executor is not None and parked._future is None:
            blob = self.store.get(parked.cold_key)
            parked._future = self._executor.submit(self._decompress, blob)

    def resume(self, slot: int, parked: ParkedPages, *,
               make_room=None) -> bool:
        """Re-admit parked pages into ``slot``.  False when the pool
        cannot host them yet (caller retries on a later step)."""
        assert slot not in self._pages
        n_cold = sum(1 for k in parked.prefix_keys
                     if self._index[k].pid is None)
        if not self._ensure_free(n_cold + parked.n_private,
                                 pin=set(parked.prefix_keys),
                                 make_room=make_room):
            return False
        ctx_ids = self._attach(list(parked.prefix_keys))
        leaves = (parked._future.result() if parked._future is not None
                  else self._decompress(self.store.get(parked.cold_key)))
        priv_ids = self._alloc(parked.n_private)
        assert priv_ids is not None
        self._restore(leaves, priv_ids)
        self._gc.release(parked.cold_key)
        self._pages[slot] = ctx_ids + priv_ids
        self._keys[slot] = list(parked.prefix_keys)
        return True

    def release(self, slot: int) -> None:
        """The slot's request finished: drop all its page holds (shared
        pages stay alive through the index for future prefix hits)."""
        for pid in self._pages.pop(slot):
            self._deref(pid)
        self._keys.pop(slot, None)

    def discard(self, parked: ParkedPages) -> None:
        """A parked request will never resume (cancelled / finished while
        parked): drop its cold blob now instead of leaking it until the
        store closes.  Any in-flight prefetch result is discarded too."""
        if parked._future is not None:
            parked._future.cancel()
            parked._future = None
        self._gc.release(parked.cold_key)

    # -- accounting ---------------------------------------------------------

    def device_bytes(self) -> int:
        return int(sum(l.nbytes for l in jax.tree.leaves(self.pools)))

    def report(self) -> dict:
        dev = self.device_bytes()
        hot_shared = sum(1 for e in self._index.values()
                         if e.pid is not None)
        return {
            "mode": "paged", "page_size": self.page,
            "page_table_width": self.n_max,
            "pool_pages": self.pool_pages, "free_pages": len(self._free),
            "shared_pages_hot": hot_shared,
            "shared_pages_cold": len(self._index) - hot_shared,
            "device_bytes": dev,
            "page_bytes": dev // self.pool_pages,
            "host_compressed_bytes": int(self.store.nbytes()),
            "stats": dict(self.stats),
        }

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
        self._gc.clear()
        self.store.close()
