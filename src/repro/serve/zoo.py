"""Multi-tenant model-zoo serving: manifest-addressed registry,
cross-model shard dedup, cold-start-aware admission (ROADMAP item 4).

DeepCABAC's pitch is that entropy-coded weights make whole networks
cheap enough to store and ship at fleet scale; this module cashes that
in for serving.  One fleet hosts many models/variants:

* :class:`ShardStore` — a content-addressed object pool.  Checkpoint
  steps are ingested by the per-file SHA-256 their sharded/delta
  manifests already pin (``checkpoint.delta.chain_files``), so N
  finetune variants chained to one base keyframe cost one copy of the
  base shards plus N small delta streams on disk.  Each model gets a
  hardlinked *view* directory that looks exactly like a checkpoint
  root, so every existing chain-resolving restore path works unchanged
  against shared bytes.  Object lifetime is refcounted
  (:class:`~.backends.BlobGC`): evicting one variant never GCs shards
  another still references.
* :class:`ModelZoo` — model-id -> manifest registry plus the resident
  :class:`~.session.ServeSession` set, sized by an HBM budget
  (weights + KV accounted via ``jax.eval_shape`` /
  :func:`~.kv.kv_cache_bytes` — no allocation).  Admission is
  cold-start-aware: victims are the *cheapest to bring back* (measured
  admit seconds, seeded from ``cold_priors``), only idle sessions are
  evicted, and a delta variant whose chain prefix is already resident
  warms by forking the base backend's tracked levels and applying only
  its own delta steps (``WeightBackend.warm_from``) instead of
  decoding the whole chain from disk.
* :class:`ZooRouter` — the request front-end.  ``submit(model_id,
  prompt, ...)`` queues for cold models, admission is triggered by
  demand, and tokens stream through :class:`ZooHandle` with the same
  per-request guarantees ``ServeSession`` gives a single model.

See docs/serving_api.md ("Model zoo & multi-tenant serving").
"""

from __future__ import annotations

import os
import shutil
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint import delta as delta_mod
from ..checkpoint.sharded import MANIFEST_NAME
from .backends import BlobGC, get_backend
from .kv import kv_cache_bytes
from .session import RequestHandle, ServeConfig, ServeSession


class ZooError(RuntimeError):
    """Structural model-zoo misuse (unknown model, impossible budget)."""


class AdmissionStall(ZooError):
    """The budget cannot host the model *right now* — every resident
    session still has work in flight.  Routers retry on a later step."""


# ---------------------------------------------------------------------------
# Content-addressed shard store
# ---------------------------------------------------------------------------

def _copy_verified(src: str, dst: str, sha256: str) -> None:
    """Copy ``src`` to ``dst`` hashing as we go; one pass does both the
    ingest and the integrity check against the manifest-pinned hash."""
    import hashlib
    h = hashlib.sha256()
    tmp = dst + ".tmp"
    with open(src, "rb") as f, open(tmp, "wb") as out:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
            out.write(block)
    if h.hexdigest() != sha256:
        os.remove(tmp)
        raise ValueError(
            f"{src}: content hash {h.hexdigest()[:12]}... does not match "
            f"the manifest-pinned {sha256[:12]}... — refusing to ingest a "
            f"corrupt or substituted shard")
    os.replace(tmp, dst)


class ShardStore:
    """Content-addressed checkpoint storage with per-model views.

    Layout under ``root``::

        objects/<sha256>                     one copy of each unique file
        views/<model_id>/step_NNNNNNNN/...   hardlinks into objects/

    ``add`` ingests a step's whole base chain (keyframe included) keyed
    by the per-file sha256 the manifests pin, so identical files across
    models/variants are stored once; the returned record's ``"tip"`` is
    a view directory any chain-resolving restore accepts verbatim.
    ``remove`` releases the model's object references — an object's
    bytes are deleted only when its last referencing model leaves.
    """

    def __init__(self, root: str):
        self.root = str(root)
        self._objects = os.path.join(self.root, "objects")
        self._views = os.path.join(self.root, "views")
        os.makedirs(self._objects, exist_ok=True)
        os.makedirs(self._views, exist_ok=True)
        self._gc = BlobGC(self._drop_object)
        self._models: dict[str, dict] = {}
        self.stats = {"objects_ingested": 0, "objects_deduped": 0,
                      "bytes_ingested": 0, "bytes_deduped": 0}

    def _obj_path(self, sha: str) -> str:
        return os.path.join(self._objects, sha)

    def _drop_object(self, sha: str) -> None:
        try:
            os.remove(self._obj_path(sha))
        except OSError:
            pass

    def _ingest(self, src: str, sha: str, nbytes: int) -> None:
        obj = self._obj_path(sha)
        if os.path.exists(obj):
            self.stats["objects_deduped"] += 1
            self.stats["bytes_deduped"] += nbytes
            return
        _copy_verified(src, obj, sha)
        self.stats["objects_ingested"] += 1
        self.stats["bytes_ingested"] += nbytes

    def add(self, model_id: str, source: str) -> dict:
        """Ingest ``source`` (a checkpoint step directory — keyframe or
        delta-chain tip) for ``model_id`` and build its view.  Returns
        the model record: ``tip`` (view step dir to load/restore from),
        ``steps`` (base-first view step dirs), ``chain_keys`` (per-link
        pinned payload hashes — the chain's identity, used for warm-
        admission prefix matching) and byte accounting."""
        if model_id in self._models:
            raise ZooError(f"model {model_id!r} already in the store")
        links = delta_mod.chain_files(str(source))
        view_root = os.path.join(self._views, model_id)
        os.makedirs(view_root, exist_ok=True)
        shas: set[str] = set()
        chain_keys: list[str] = []
        steps: list[str] = []
        logical = 0
        for link in links:
            vdir = os.path.join(view_root, os.path.basename(link["dir"]))
            os.makedirs(vdir, exist_ok=True)
            for fname, info in link["files"].items():
                sha = info["sha256"]
                self._ingest(os.path.join(link["dir"], fname), sha,
                             info["bytes"])
                dst = os.path.join(vdir, fname)
                if not os.path.exists(dst):
                    try:
                        os.link(self._obj_path(sha), dst)
                    except OSError:         # cross-device view root
                        shutil.copyfile(self._obj_path(sha), dst)
                shas.add(sha)
                logical += info["bytes"]
            pin = (MANIFEST_NAME if MANIFEST_NAME in link["files"]
                   else delta_mod.PARAMS_FILE)
            chain_keys.append(link["files"][pin]["sha256"])
            steps.append(vdir)
        for sha in shas:
            self._gc.hold(sha)
        rec = {"model_id": model_id, "tip": steps[-1], "steps": steps,
               "chain_keys": chain_keys, "objects": sorted(shas),
               "logical_bytes": int(logical)}
        self._models[model_id] = rec
        return rec

    def remove(self, model_id: str) -> None:
        """Drop a model: its view directory and its object references.
        Objects still referenced by other models keep their bytes."""
        rec = self._models.pop(model_id, None)
        if rec is None:
            return
        for sha in rec["objects"]:
            self._gc.release(sha)
        shutil.rmtree(os.path.join(self._views, model_id),
                      ignore_errors=True)

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._models

    def record(self, model_id: str) -> dict:
        return self._models[model_id]

    def object_count(self) -> int:
        return len(self._gc.live())

    def physical_bytes(self) -> int:
        return sum(os.path.getsize(self._obj_path(sha))
                   for sha in self._gc.live())

    def logical_bytes(self) -> int:
        return sum(r["logical_bytes"] for r in self._models.values())

    def report(self) -> dict:
        logical, physical = self.logical_bytes(), self.physical_bytes()
        return {
            "models": len(self._models),
            "objects": self.object_count(),
            "logical_bytes": int(logical),
            "physical_bytes": int(physical),
            "dedup_ratio": round(logical / physical, 4) if physical else 0.0,
            "stats": dict(self.stats),
        }

    def close(self) -> None:
        for model_id in list(self._models):
            self.remove(model_id)


# ---------------------------------------------------------------------------
# Model zoo: registry + resident set + admission policy
# ---------------------------------------------------------------------------

def model_resident_bytes(cfg, serve_cfg: ServeConfig,
                         backend=None) -> int:
    """HBM a resident model costs: weight bytes plus its session's device
    KV (slot cache, or the paged pool).

    ``backend`` (registry name or instance) picks the weight accounting:
    a q8-resident backend (``WeightBackend.q8_resident``, e.g. ``"q8"``)
    holds serve-quantized leaves as ``{"q8","q8s"}`` — int8 levels plus
    f32 per-channel scales — so eligible tensors are costed at 1 B/param
    + scale width instead of the param-dtype ``jax.eval_shape`` size that
    previously overcounted q8-resident models ~4x (and forfeited the
    compressed-resident admission gains).  ``None`` keeps the
    full-precision accounting (correct for bf16/container residency)."""
    from ..compression.quantizers import serve_q8_policy
    from ..compression.tree import _path_key
    from ..models.transformer import init_params
    from .backends import resolve_backend

    q8_res = (resolve_backend(backend).q8_resident
              if backend is not None else False)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    wb = 0
    for path, s in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(s.shape))
        if q8_res and serve_q8_policy(_path_key(path), s):
            # {"q8","q8s"} leaf: int8 levels + f32 per-out-channel Delta
            # (stacked ndim>=3 tensors carry one scale row per layer)
            scales = (s.shape[0] * s.shape[-1] if s.ndim >= 3
                      else s.shape[-1])
            wb += n + 4 * scales
        else:
            wb += n * s.dtype.itemsize
    if serve_cfg.kv_page_size is not None:
        page = serve_cfg.kv_page_size
        n_max = -(-serve_cfg.max_len // page)
        pool = serve_cfg.kv_pool_pages or serve_cfg.slots * n_max + 1
        kb = kv_cache_bytes(cfg, pool, page)
    else:
        kb = kv_cache_bytes(cfg, serve_cfg.slots, serve_cfg.max_len)
    return int(wb + kb)


@dataclass
class ZooConfig:
    """Admission-policy knobs for a :class:`ModelZoo`."""

    hbm_budget: int                      # bytes for every resident model's
                                         # weights + device KV together
    backend: str = "container"           # WeightBackend registry name
    serve: ServeConfig = field(default_factory=ServeConfig)
    track_levels: bool = True            # keep levels resident: enables
                                         # delta-warm admission + live swap
    cold_priors: dict = field(default_factory=dict)   # model_id -> seconds;
    # seeds the victim scoring before a model's first measured admit
    # (e.g. from BENCH_cold_start-style timings)
    policy_table: object | None = None   # TensorPolicy / dict / JSON path:
    # per-tensor mixed-precision policy applied by the weight backend to
    # pytree admissions (see compression.rd_search); container/manifest
    # admissions carry their quantization in the artifact itself


class ModelZoo:
    """Registry + resident-session fleet under one HBM budget."""

    def __init__(self, store: ShardStore | str, cfg: ZooConfig):
        self.store = ShardStore(store) if isinstance(store, str) else store
        self.cfg = cfg
        self._registry: dict[str, dict] = {}
        self._resident: dict[str, ServeSession] = {}
        self._admit_s: dict[str, float] = {}    # last measured admit cost
        self._last_kind: dict[str, str] = {}
        self._clock = 0
        self._last_used: dict[str, int] = {}
        self.stats = {"admits_cold": 0, "admits_warm": 0, "evictions": 0,
                      "admit_s_cold": 0.0, "admit_s_warm": 0.0}

    # -- registry -----------------------------------------------------------

    def register(self, model_id: str, config, source: str) -> dict:
        """Register ``model_id``: ``config`` is a ``ModelConfig`` (or a
        ``repro.configs`` registry name), ``source`` a checkpoint step
        directory (keyframe or delta-chain tip).  The step's whole chain
        is ingested into the content-addressed store; nothing is decoded
        until admission."""
        if model_id in self._registry:
            raise ZooError(f"model {model_id!r} already registered")
        if isinstance(config, str):
            from .. import configs
            config = configs.get(config)
        rec = self.store.add(model_id, source)
        self._registry[model_id] = {
            "cfg": config,
            "rec": rec,
            "bytes": model_resident_bytes(config, self.cfg.serve,
                                          backend=self.cfg.backend),
        }
        return rec

    def models(self) -> list[str]:
        return sorted(self._registry)

    def resident(self) -> list[str]:
        return sorted(self._resident)

    def resident_bytes(self) -> int:
        return sum(self._registry[m]["bytes"] for m in self._resident)

    def session(self, model_id: str) -> ServeSession | None:
        return self._resident.get(model_id)

    def touch(self, model_id: str) -> None:
        self._clock += 1
        self._last_used[model_id] = self._clock

    # -- admission / eviction -----------------------------------------------

    def admit(self, model_id: str) -> ServeSession:
        """Make ``model_id`` resident (no-op if it already is), evicting
        idle victims as needed to fit the budget.  Raises
        :class:`AdmissionStall` when only busy sessions hold the budget,
        :class:`ZooError` when the model cannot fit an empty zoo."""
        sess = self._resident.get(model_id)
        if sess is not None:
            self.touch(model_id)
            return sess
        ent = self._registry.get(model_id)
        if ent is None:
            raise ZooError(f"model {model_id!r} is not registered; "
                           f"known: {self.models()}")
        if ent["bytes"] > self.cfg.hbm_budget:
            raise ZooError(
                f"model {model_id!r} needs {ent['bytes']} B resident but "
                f"the zoo budget is {self.cfg.hbm_budget} B")
        while self.resident_bytes() + ent["bytes"] > self.cfg.hbm_budget:
            if not self._evict_one():
                raise AdmissionStall(
                    f"cannot admit {model_id!r}: every resident model "
                    f"({self.resident()}) still has requests in flight")
        t0 = time.perf_counter()
        warm = self._warm_base(ent)
        backend = get_backend(self.cfg.backend,
                              track_levels=self.cfg.track_levels,
                              policy_table=self.cfg.policy_table)
        if warm is not None:
            base_id, steps = warm
            base_sess = self._resident[base_id]
            params = backend.warm_from(ent["cfg"], base_sess.backend,
                                       base_sess.params, steps)
            kind = "warm"
        else:
            entries = delta_mod.restore_levels(ent["rec"]["tip"])
            params = backend.load_entries(ent["cfg"], entries)
            kind = "cold"
        sess = ServeSession.from_loaded(ent["cfg"], params, backend=backend,
                                        serve_cfg=self.cfg.serve)
        dt = time.perf_counter() - t0
        self._resident[model_id] = sess
        self._admit_s[model_id] = dt
        self._last_kind[model_id] = kind
        self.stats[f"admits_{kind}"] += 1
        self.stats[f"admit_s_{kind}"] += dt
        self.touch(model_id)
        return sess

    def _warm_base(self, ent: dict) -> tuple[str, list[str]] | None:
        """Find the resident model whose chain is the longest proper
        prefix of ``ent``'s (matched by the manifest-pinned per-link
        hashes): the delta variant can then warm from its levels by
        applying only the suffix steps.  None -> cold start."""
        if not self.cfg.track_levels:
            return None
        keys = ent["rec"]["chain_keys"]
        best: tuple[str, list[str]] | None = None
        best_len = 0
        for mid, sess in self._resident.items():
            other = self._registry[mid]
            if other["cfg"] != ent["cfg"]:
                continue
            okeys = other["rec"]["chain_keys"]
            n = len(okeys)
            if (n < len(keys) and keys[:n] == okeys and n > best_len
                    and sess.backend.track_levels):
                best = (mid, ent["rec"]["steps"][n:])
                best_len = n
        return best

    def _evict_one(self) -> bool:
        """Evict the idle resident model that is cheapest to bring back
        (measured admit seconds, ``cold_priors`` before the first
        measurement; ties fall to least-recently-used)."""
        idle = [m for m, s in self._resident.items()
                if not s.pending and s.num_parked == 0]
        if not idle:
            return False
        victim = min(idle, key=lambda m: (
            self._admit_s.get(m, self.cfg.cold_priors.get(m, 0.0)),
            self._last_used.get(m, 0)))
        self.evict(victim)
        return True

    def evict(self, model_id: str) -> None:
        sess = self._resident.pop(model_id, None)
        if sess is None:
            return
        sess.close()
        self.stats["evictions"] += 1

    # -- reporting ----------------------------------------------------------

    def zoo_report(self) -> dict:
        """One-stop accounting: on-disk dedup (the ShardStore report),
        HBM residency against the budget, and per-model admission
        economics (measured cost + how the last admit ran)."""
        per_model = {}
        for mid, ent in self._registry.items():
            per_model[mid] = {
                "resident": mid in self._resident,
                "resident_bytes": ent["bytes"],
                "chain_len": len(ent["rec"]["chain_keys"]),
                "disk_bytes": ent["rec"]["logical_bytes"],
                "admit_s": round(self._admit_s[mid], 6)
                           if mid in self._admit_s else None,
                "last_admit": self._last_kind.get(mid),
            }
        return {
            "hbm_budget": int(self.cfg.hbm_budget),
            "resident_bytes": int(self.resident_bytes()),
            "resident": self.resident(),
            "store": self.store.report(),
            "models": per_model,
            "stats": dict(self.stats),
        }

    def close(self) -> None:
        for mid in list(self._resident):
            self.evict(mid)


# ---------------------------------------------------------------------------
# Routing front-end
# ---------------------------------------------------------------------------

class ZooHandle:
    """Client-side view of one routed request.  Mirrors
    :class:`~.session.RequestHandle` (``done`` / ``new_tokens`` /
    ``result`` / ``finish_reason``); tokens start flowing once the
    model is admitted and the inner session request exists."""

    def __init__(self, model_id: str, prompt, max_new_tokens: int,
                 temperature: float, seed):
        self.model_id = model_id
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.max_new_tokens = int(max_new_tokens)
        self.temperature = float(temperature)
        self.seed = seed
        self._inner: RequestHandle | None = None

    @property
    def admitted(self) -> bool:
        return self._inner is not None

    @property
    def done(self) -> bool:
        return self._inner is not None and self._inner.done

    @property
    def finish_reason(self) -> str | None:
        return self._inner.finish_reason if self._inner is not None else None

    def new_tokens(self) -> list:
        return self._inner.new_tokens() if self._inner is not None else []

    def result(self) -> np.ndarray:
        assert self.done, (
            f"request to {self.model_id!r} still in flight; run "
            f"router.step()")
        return self._inner.result()


class ZooRouter:
    """Route requests to a :class:`ModelZoo` by model id.

    ``submit`` never blocks: requests for cold models queue here and
    trigger admission on the next :meth:`step` (FIFO per model, so a
    zoo-routed model sees exactly the request order a dedicated session
    would).  Admission stalls (budget full of busy models) retry on
    later steps once residents drain."""

    def __init__(self, zoo: ModelZoo):
        self.zoo = zoo
        self._waiting: deque[ZooHandle] = deque()

    def submit(self, model_id: str, prompt, max_new_tokens: int,
               temperature: float = 0.0, seed=None) -> ZooHandle:
        if model_id not in self.zoo._registry:
            raise ZooError(f"model {model_id!r} is not registered; "
                           f"known: {self.zoo.models()}")
        handle = ZooHandle(model_id, prompt, max_new_tokens, temperature,
                           seed)
        self._waiting.append(handle)
        return handle

    @property
    def pending(self) -> bool:
        return bool(self._waiting) or any(
            s.pending for s in self.zoo._resident.values())

    def step(self) -> None:
        """One routing tick: hand waiting requests to their (admitted-
        on-demand) sessions, then advance every resident session that
        has work.  A request whose admission stalls stays queued; later
        requests for *other* models still flow (no head-of-line block
        across models), while FIFO order within each model holds."""
        still: deque[ZooHandle] = deque()
        stalled: set[str] = set()
        for handle in self._waiting:
            if handle.model_id in stalled:
                still.append(handle)        # keep per-model FIFO order
                continue
            try:
                sess = self.zoo.admit(handle.model_id)
            except AdmissionStall:
                stalled.add(handle.model_id)
                still.append(handle)
                continue
            handle._inner = sess.submit(
                handle.prompt, max_new_tokens=handle.max_new_tokens,
                temperature=handle.temperature, seed=handle.seed)
        self._waiting = still
        for mid, sess in list(self.zoo._resident.items()):
            if sess.pending:
                sess.step()
                self.zoo.touch(mid)

    def run(self, max_steps: int | None = None) -> None:
        """Step until every routed request finished (or ``max_steps``)."""
        steps = 0
        while self.pending:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break

    def close(self) -> None:
        self.zoo.close()
