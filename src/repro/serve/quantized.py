"""Fixed-point serving: DeepCABAC-grid int8 weights (+ int8 KV cache).

The paper's equidistant grid q = Delta * I (§III-C-1) "encourages fixed-
point representations which can be exploited to perform inference with
lower complexity".  On TPU the exploit is bandwidth: decode is HBM-bound on
weight + KV-cache reads, so storing both as int8 levels with per-channel /
per-layer Delta halves the dominant roofline term vs bf16 (quantified in
EXPERIMENTS.md §Perf).  kernels/dequant_matmul is the matching MXU kernel;
under the XLA path the dequantize happens in-core after int8 HBM reads.

A quantized weight leaf is {"q8": int8 levels, "q8s": f32 per-out-channel
Delta}; sharding rules strip the /q8 suffix and reuse the weight's spec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.quantizers import quantize_leaf, quantize_tree_q8  # noqa: F401
# quantize_leaf re-exported: the per-channel int8 quantizer lives in the
# compression package so the "serve-q8" container codec and this in-memory
# path share one implementation.
from .. import kernels as _kernels

# single source of truth for q8-leaf detection lives beside the kernels
# that consume the {"q8","q8s"} layout
is_q8 = _kernels.is_q8_leaf


def quantize_params_for_serving(params):
    """int8-quantize the matmul weights: stacked layer tensors (ndim >= 3 —
    per-layer vectors stack to 2-D and stay full precision, as the paper
    leaves 1-D tensors unquantized) and the unstacked 2-D embed/head.

    This is the in-memory form of ``compression.get("serve-q8")`` — the
    codec's tree pass with {"q8","q8s"} leaf dicts instead of a container.
    """
    return quantize_tree_q8(params)


def dequant_leaf(leaf, dtype):
    if is_q8(leaf):
        q, s = leaf["q8"], leaf["q8s"]
        if s.ndim == 2 and q.ndim > 2:
            # stacked leaf: scales are (L, out) for levels (L, ..., out)
            s = s.reshape((s.shape[0],) + (1,) * (q.ndim - 2)
                          + (s.shape[1],))
        return (q.astype(jnp.float32) * s).astype(dtype)
    return leaf


def dequant_tree(tree, dtype):
    """Dequantize all q8 leaves (applied per-layer inside the scan so HBM
    sees int8 reads, not a materialized bf16 copy of the whole model)."""
    return jax.tree.map(lambda x: dequant_leaf(x, dtype), tree,
                        is_leaf=is_q8)


# -- int8 KV cache -------------------------------------------------------------

# Default per-model Delta (covers |k|,|v| < ~8, the typical post-norm range).
# The served Delta is carried on ModelConfig.kv_cache_delta / ServeConfig —
# the old fixed module constant silently clipped activations outside |x| < 8.
DEFAULT_KV_CACHE_DELTA = 1.0 / 16.0


def quantize_cache_value(x: jnp.ndarray,
                         delta: float = DEFAULT_KV_CACHE_DELTA) -> jnp.ndarray:
    return jnp.clip(jnp.round(x.astype(jnp.float32) / delta),
                    -127, 127).astype(jnp.int8)


def dequant_cache_value(q: jnp.ndarray, dtype,
                        delta: float = DEFAULT_KV_CACHE_DELTA) -> jnp.ndarray:
    return (q.astype(jnp.float32) * delta).astype(dtype)


def calibrate_kv_cache_delta(cfg, params, tokens, margin: float = 1.05
                             ) -> float:
    """Calibrated per-model KV-cache Delta: run a full-precision prefill on
    ``tokens`` (B, S) and map the observed attention-cache absmax to level
    127 (times ``margin`` headroom).  Use the result as
    ``ServeConfig.kv_cache_delta`` / ``ModelConfig.kv_cache_delta`` to avoid
    the silent clipping a fixed grid causes on out-of-range activations."""
    # local imports: models.transformer imports this module at module scope
    from ..models.transformer import init_cache, prefill

    fp_cfg = cfg.replace(q8_cache=False)
    _, caches = prefill(params, fp_cfg, tokens=jnp.asarray(tokens, jnp.int32),
                        max_len=tokens.shape[1])
    template = init_cache(cfg.replace(q8_cache=True), tokens.shape[0],
                          tokens.shape[1])
    amax = 0.0
    for got, want in zip(jax.tree.leaves(caches), jax.tree.leaves(template)):
        if want.dtype == jnp.int8:   # the leaves q8_cache would quantize
            amax = max(amax, float(jnp.max(jnp.abs(got))))
    return max(margin * amax / 127.0, 1e-8)
