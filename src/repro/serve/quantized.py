"""Fixed-point serving: DeepCABAC-grid int8 weights (+ int8 KV cache).

The paper's equidistant grid q = Delta * I (§III-C-1) "encourages fixed-
point representations which can be exploited to perform inference with
lower complexity".  On TPU the exploit is bandwidth: decode is HBM-bound on
weight + KV-cache reads, so storing both as int8 levels with per-channel /
per-layer Delta halves the dominant roofline term vs bf16 (quantified in
EXPERIMENTS.md §Perf).  kernels/dequant_matmul is the matching MXU kernel;
under the XLA path the dequantize happens in-core after int8 HBM reads.

A quantized weight leaf is {"q8": int8 levels, "q8s": f32 per-out-channel
Delta}; sharding rules strip the /q8 suffix and reuse the weight's spec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.quantizers import quantize_leaf, quantize_tree_q8  # noqa: F401
# quantize_leaf re-exported: the per-channel int8 quantizer lives in the
# compression package so the "serve-q8" container codec and this in-memory
# path share one implementation.


def is_q8(leaf) -> bool:
    return isinstance(leaf, dict) and "q8" in leaf and "q8s" in leaf


def quantize_params_for_serving(params):
    """int8-quantize the matmul weights: stacked layer tensors (ndim >= 3 —
    per-layer vectors stack to 2-D and stay full precision, as the paper
    leaves 1-D tensors unquantized) and the unstacked 2-D embed/head.

    This is the in-memory form of ``compression.get("serve-q8")`` — the
    codec's tree pass with {"q8","q8s"} leaf dicts instead of a container.
    """
    return quantize_tree_q8(params)


def dequant_leaf(leaf, dtype):
    if is_q8(leaf):
        return (leaf["q8"].astype(jnp.float32) * leaf["q8s"]).astype(dtype)
    return leaf


def dequant_tree(tree, dtype):
    """Dequantize all q8 leaves (applied per-layer inside the scan so HBM
    sees int8 reads, not a materialized bf16 copy of the whole model)."""
    return jax.tree.map(lambda x: dequant_leaf(x, dtype), tree,
                        is_leaf=is_q8)


def embed_lookup_q8(embed_leaf, tokens, dtype):
    """Gather int8 rows first, dequantize after — the gather reads B*S rows
    of int8 instead of the full-precision table."""
    if is_q8(embed_leaf):
        rows = jnp.take(embed_leaf["q8"], tokens, axis=0)
        return (rows.astype(jnp.float32)
                * embed_leaf["q8s"]).astype(dtype)
    return jnp.take(embed_leaf, tokens, axis=0).astype(dtype)


# -- int8 KV cache -------------------------------------------------------------

CACHE_SCALE = 1.0 / 16.0   # fixed per-install Delta; |k|,|v| <~ 8 post-norm


def quantize_cache_value(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(x.astype(jnp.float32) / CACHE_SCALE),
                    -127, 127).astype(jnp.int8)


def dequant_cache_value(q: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * CACHE_SCALE).astype(dtype)
