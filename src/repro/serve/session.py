"""Request-level serving: ``ServeSession`` with continuous batching.

Clients ``submit(prompt, max_new_tokens, temperature)`` and receive
:class:`RequestHandle`\\ s; the scheduler packs active requests into a
slot-based KV cache (admission on free slot, eviction on EOS/length) and
runs one batched decode step per :meth:`ServeSession.step`, surfacing
per-request token streams via ``handle.new_tokens()``.

Slot model: the session preallocates ``init_cache(cfg, slots, max_len)``
once.  A request is admitted by prefilling its prompt at batch=1 and
scattering the resulting caches into its slot (axis 1 is the slot axis on
every cache leaf).  Decode then advances *all* slots with per-slot ragged
positions (``cache_pos`` as an (S,) int32 vector — see
``models.transformer``); evicted/free slots keep computing at position 0,
which is harmless: their writes are either overwritten by the next
admission's prefill or masked by the per-slot ``kv_len`` until the new
request's own decode rewrites them.

Weights come from a pluggable :mod:`backend <.backends>` (``bf16`` /
``q8`` / ``container``).  ``ServeEngine`` is a thin compatibility wrapper
over this class.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, forward, init_cache, prefill
from .backends import _insert, resolve_backend


@dataclass(frozen=True)
class ServeConfig:
    """Session knobs (model shape/quantization stays on ModelConfig)."""

    slots: int = 4                 # concurrent requests in the KV cache
    max_len: int = 512             # per-slot KV capacity (prompt + new)
    eos_token: int | None = None   # evict a request when it emits this id
    kv_cache_delta: float | None = None   # override the int8 KV grid step
    # (see serve.quantized.calibrate_kv_cache_delta); None keeps the
    # model config's value
    seed: int = 0                  # base seed for temperature sampling
    prefill_buckets: tuple = ()    # sorted prompt-length buckets: pad each
    # admission prefill up to the next bucket so XLA compiles once per
    # bucket instead of once per distinct prompt length.  Dense-family
    # only: padded tail tokens are causally invisible to the prompt and
    # their stale KV is masked/overwritten, but an SSM state or MoE
    # capacity routing would see them.


@dataclass
class RequestHandle:
    """Client-side view of one submitted request."""

    id: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: object = None            # per-request sampling seed (int/tuple);
    # None derives from the session seed + request id
    tokens: list = field(default_factory=list)   # generated ids (incl. EOS)
    done: bool = False
    finish_reason: str | None = None     # "eos" | "length"
    _stream_cursor: int = 0

    def new_tokens(self) -> list:
        """Drain this request's token stream (ids since the last call)."""
        out = self.tokens[self._stream_cursor:]
        self._stream_cursor = len(self.tokens)
        return out

    def result(self) -> np.ndarray:
        assert self.done, "request still in flight; run session.step()"
        return np.asarray(self.tokens, dtype=np.int32)


class _Slot:
    __slots__ = ("req", "pos", "next_token")

    def __init__(self):
        self.req: RequestHandle | None = None
        self.pos = 0               # where next_token's KV will be written
        self.next_token = 0        # token to feed on the next decode step


class ServeSession:
    """Continuous-batching serving session over a slot-based KV cache."""

    def __init__(self, cfg: ModelConfig, weights, *, backend="bf16",
                 serve_cfg: ServeConfig | None = None):
        serve_cfg = serve_cfg or ServeConfig()
        if serve_cfg.slots < 1 or serve_cfg.max_len < 1:
            raise ValueError(
                f"ServeConfig needs slots >= 1 and max_len >= 1; got "
                f"slots={serve_cfg.slots}, max_len={serve_cfg.max_len}")
        if serve_cfg.kv_cache_delta is not None:
            cfg = cfg.replace(kv_cache_delta=serve_cfg.kv_cache_delta)
        if serve_cfg.prefill_buckets and cfg.family != "dense":
            raise ValueError(
                "prefill_buckets pads prompts, which only dense-family "
                "models ignore (SSM state / MoE routing see pad tokens); "
                f"got family {cfg.family!r}")
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.backend = resolve_backend(backend)
        self.params = self.backend.load(cfg, weights)

        self._slots = [_Slot() for _ in range(serve_cfg.slots)]
        self._queue: deque[RequestHandle] = deque()
        self._ids = itertools.count()
        self._caches = init_cache(cfg, serve_cfg.slots, serve_cfg.max_len)
        self._rngs: dict[int, np.random.Generator] = {}

        max_len = serve_cfg.max_len
        if any(b > max_len for b in serve_cfg.prefill_buckets):
            raise ValueError(f"prefill bucket exceeds max_len {max_len}")
        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks, max_len=max_len))

        def prefill_padded(p, toks, last_idx):
            # padded admission: gather the last *real* prompt position per
            # row before the head projection (pad tail is causally
            # invisible, and the head only ever sees one position)
            caches = init_cache(cfg, toks.shape[0], max_len)
            logits, new_caches, _ = forward(p, cfg, tokens=toks,
                                            caches=caches,
                                            last_index=last_idx)
            return logits[:, 0, :], new_caches
        self._prefill_padded = jax.jit(prefill_padded)
        self._decode = jax.jit(
            lambda p, caches, tok, pos: decode_step(p, cfg, caches, pos,
                                                    tokens=tok))
        self._scatter = jax.jit(self._scatter_impl)

    @classmethod
    def from_container(cls, cfg: ModelConfig, blob: bytes, *,
                       backend="container",
                       serve_cfg: ServeConfig | None = None
                       ) -> "ServeSession":
        """Build a session straight from a DCBC deployment artifact."""
        return cls(cfg, blob, backend=backend, serve_cfg=serve_cfg)

    # -- client API ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               temperature: float = 0.0, seed=None) -> RequestHandle:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("prompt must contain at least one token")
        if prompt.size + max_new_tokens > self.serve_cfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({max_new_tokens}) exceeds slot capacity "
                f"{self.serve_cfg.max_len}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = RequestHandle(id=next(self._ids), prompt=prompt,
                            max_new_tokens=max_new_tokens,
                            temperature=temperature, seed=seed)
        self._queue.append(req)
        return req

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return sum(s.req is not None for s in self._slots)

    @property
    def pending(self) -> bool:
        return bool(self._queue) or self.num_active > 0

    def swap_weights(self, source) -> int:
        """Swap in a delta ("P-frame") checkpoint step at a batch
        boundary: the backend decodes the step's residual records against
        its tracked base levels (``WeightBackend.apply_delta``) and the
        updated leaves replace their counterparts in ``self.params``.

        In-flight requests keep their slots and KV caches — the next
        :meth:`step` simply decodes with the new weights.  Leaf shapes,
        dtypes and the tree structure are unchanged by construction (a
        delta step is coded on the base frame's grid), so the jitted
        prefill/decode functions don't recompile.  The backend must have
        been built with ``track_levels=True`` and loaded from the chain's
        base frame.  Returns the number of updated tensors."""
        updates = self.backend.apply_delta(self.cfg, source)
        for name, leaf in updates.items():
            _insert(self.params, name, leaf)
        return len(updates)

    def run(self, max_steps: int | None = None) -> None:
        """Step until every submitted request finished (or max_steps)."""
        steps = 0
        while self.pending:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break

    # -- scheduler -----------------------------------------------------------

    def step(self) -> None:
        """One scheduler tick: admit onto free slots, then one batched
        decode step over all slots, then evict finished requests."""
        self._admit()
        if self.num_active == 0:
            return
        tok = np.zeros(len(self._slots), np.int32)
        pos = np.zeros(len(self._slots), np.int32)
        for i, slot in enumerate(self._slots):
            if slot.req is not None:
                tok[i] = slot.next_token
                pos[i] = slot.pos
        logits, self._caches = self._decode(
            self.params, self._caches, jnp.asarray(tok), jnp.asarray(pos))
        logits = np.asarray(logits)
        for i, slot in enumerate(self._slots):
            if slot.req is None:
                continue
            slot.pos += 1
            nxt = self._sample(logits[i], slot.req)
            slot.req.tokens.append(nxt)
            slot.next_token = nxt
            self._maybe_evict(slot)

    def _admit(self) -> None:
        """Admit queued requests onto free slots.  The FIFO prefix sharing
        one (bucketed) prefill length is admitted as a single batched
        prefill — so a same-length burst (the ServeEngine wrapper's whole
        batch) costs one forward pass, not one per request."""
        while self._queue:
            free = [i for i, s in enumerate(self._slots) if s.req is None]
            if not free:
                return
            length = self._bucket_len(self._queue[0].prompt.size)
            group = []
            for req in itertools.islice(self._queue, len(free)):
                if self._bucket_len(req.prompt.size) != length:
                    break
                group.append(req)
            for _ in group:
                self._queue.popleft()
            slots_idx = free[:len(group)]

            toks = np.zeros((len(group), length), np.int32)
            for j, req in enumerate(group):
                toks[j, :req.prompt.size] = req.prompt
            if any(req.prompt.size < length for req in group):
                logits, caches_g = self._prefill_padded(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([r.prompt.size - 1 for r in group],
                                jnp.int32))
            else:
                logits, caches_g = self._prefill(self.params,
                                                 jnp.asarray(toks))
            self._place(caches_g, slots_idx)
            logits = np.asarray(logits)
            for j, req in enumerate(group):
                slot = self._slots[slots_idx[j]]
                first = self._sample(logits[j], req)
                req.tokens.append(first)
                slot.req = req
                slot.pos = req.prompt.size
                slot.next_token = first
                self._maybe_evict(slot)

    def _place(self, caches_g, slots_idx: list) -> None:
        """Scatter a batch-k prefill's caches into slots ``slots_idx``:
        one contiguous write when the slots are adjacent (the common case
        on an idle session), per-row writes otherwise."""
        if slots_idx == list(range(slots_idx[0],
                                   slots_idx[0] + len(slots_idx))):
            self._caches = self._scatter(
                self._caches, caches_g,
                jnp.asarray(slots_idx[0], jnp.int32))
            return
        for j, slot_i in enumerate(slots_idx):
            row = jax.tree.map(lambda a: a[:, j:j + 1], caches_g)
            self._caches = self._scatter(self._caches, row,
                                         jnp.asarray(slot_i, jnp.int32))

    def _maybe_evict(self, slot: _Slot) -> None:
        req = slot.req
        eos = self.serve_cfg.eos_token
        if eos is not None and req.tokens[-1] == eos:
            req.finish_reason = "eos"
        elif len(req.tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
        elif slot.pos >= self.serve_cfg.max_len:
            req.finish_reason = "length"
        else:
            return
        req.done = True
        self._rngs.pop(req.id, None)
        slot.req = None
        slot.pos = 0
        slot.next_token = 0

    # -- helpers -------------------------------------------------------------

    def _bucket_len(self, n: int) -> int:
        """Smallest configured prefill bucket >= n (n itself if none)."""
        fits = [b for b in self.serve_cfg.prefill_buckets if b >= n]
        return min(fits) if fits else n

    @staticmethod
    def _scatter_impl(caches, caches1, slot_idx):
        """Write a batch=1 prefill's caches into slot ``slot_idx`` (every
        cache leaf carries the slot axis at position 1)."""
        return jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot_idx, axis=1),
            caches, caches1)

    def _sample(self, logits_row: np.ndarray, req: RequestHandle) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = self._rngs.get(req.id)
        if rng is None:
            # per-request seed (reproducible across sessions) or a
            # session-seed + request-id derivation
            key = (req.seed if req.seed is not None
                   else (self.serve_cfg.seed, req.id))
            rng = np.random.default_rng(key)
            self._rngs[req.id] = rng
        z = logits_row.astype(np.float64) / req.temperature
        return int(np.argmax(z + rng.gumbel(size=z.shape)))
