"""Request-level serving: ``ServeSession`` with continuous batching.

Clients ``submit(prompt, max_new_tokens, temperature)`` and receive
:class:`RequestHandle`\\ s; the scheduler packs active requests into a
KV cache (admission on free slot, eviction on EOS/length) and runs one
batched decode step per :meth:`ServeSession.step`, surfacing per-request
token streams via ``handle.new_tokens()``.

Two cache layouts:

* **Slot mode** (default): the session preallocates
  ``init_cache(cfg, slots, max_len)`` once.  A request is admitted by
  prefilling its prompt at batch=1 and scattering the resulting caches
  into its slot (axis 1 is the slot axis on every cache leaf).  Decode
  advances the *active* slots with per-slot ragged positions
  (``cache_pos`` as an (S,) int32 vector — see ``models.transformer``);
  free slots still occupy decode rows (their rows compute at position 0
  and are dead by construction), counted in ``stats["free_slot_rows"]``,
  and an all-free tick skips the decode call entirely.
* **Paged mode** (``ServeConfig.kv_page_size``): the cache is a page
  pool + per-slot page table (:mod:`repro.serve.kv`).  Decode batches
  are *compacted* — only active slots are gathered (padded to a
  power-of-two batch over the scratch page), so free slots never burn
  decode FLOPs.  Cold pages are entropy-coded (``kv-q8-cabac``) and
  evicted to a host cold store under pool pressure; parked requests
  restore through the lane-parallel batched decoder on re-admission, and
  page-aligned shared prompt prefixes prefill once
  (copy-on-write prefix sharing).

Weights come from a pluggable :mod:`backend <.backends>` (``bf16`` /
``q8`` / ``container``).  ``ServeEngine`` is a thin compatibility wrapper
over this class.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, forward, init_cache, prefill
from .backends import _insert, resolve_backend
from .kv import PagedKV, kv_cache_bytes


@dataclass(frozen=True)
class ServeConfig:
    """Session knobs (model shape/quantization stays on ModelConfig)."""

    slots: int = 4                 # concurrent requests in the KV cache
    max_len: int = 512             # per-slot KV capacity (prompt + new)
    eos_token: int | None = None   # evict a request when it emits this id
    kv_cache_delta: float | None = None   # override the int8 KV grid step
    # (see serve.quantized.calibrate_kv_cache_delta); None keeps the
    # model config's value
    seed: int = 0                  # base seed for temperature sampling
    prefill_buckets: tuple = ()    # sorted prompt-length buckets: pad each
    # admission prefill up to the next bucket so XLA compiles once per
    # bucket instead of once per distinct prompt length.  Dense-family
    # only: padded tail tokens are causally invisible to the prompt and
    # their stale KV is masked/overwritten, but an SSM state or MoE
    # capacity routing would see them.

    # -- paged KV cache (docs/serving_api.md "Paged KV cache") ------------
    kv_page_size: int | None = None   # tokens per page; None = slot mode
    kv_pool_pages: int | None = None  # hot pool size; None sizes it for
    # every slot at max_len (no eviction pressure)
    kv_cold_store: str = "host"       # KVColdStore registry name/instance
    kv_evict_codec: str = "kv-q8-cabac"   # compression codec for cold pages
    kv_prefix_sharing: bool = True    # share page-aligned prompt prefixes
    kv_restore_workers: int = 0       # >0: entropy-decode restores on a
    # worker pool so decode latency hides behind the admission path


@dataclass
class RequestHandle:
    """Client-side view of one submitted request."""

    id: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: object = None            # per-request sampling seed (int/tuple);
    # None derives from the session seed + request id
    tokens: list = field(default_factory=list)   # generated ids (incl. EOS)
    done: bool = False
    finish_reason: str | None = None     # "eos" | "length"
    _stream_cursor: int = 0

    def new_tokens(self) -> list:
        """Drain this request's token stream (ids since the last call)."""
        out = self.tokens[self._stream_cursor:]
        self._stream_cursor = len(self.tokens)
        return out

    def result(self) -> np.ndarray:
        assert self.done, "request still in flight; run session.step()"
        return np.asarray(self.tokens, dtype=np.int32)


class _Slot:
    __slots__ = ("req", "pos", "next_token")

    def __init__(self):
        self.req: RequestHandle | None = None
        self.pos = 0               # where next_token's KV will be written
        self.next_token = 0        # token to feed on the next decode step

    def clear(self):
        self.req, self.pos, self.next_token = None, 0, 0


class ServeSession:
    """Continuous-batching serving session over a slot or paged KV cache."""

    def __init__(self, cfg: ModelConfig, weights, *, backend="bf16",
                 serve_cfg: ServeConfig | None = None,
                 preloaded: bool = False):
        serve_cfg = serve_cfg or ServeConfig()
        if serve_cfg.slots < 1 or serve_cfg.max_len < 1:
            raise ValueError(
                f"ServeConfig needs slots >= 1 and max_len >= 1; got "
                f"slots={serve_cfg.slots}, max_len={serve_cfg.max_len}")
        if serve_cfg.kv_cache_delta is not None:
            cfg = cfg.replace(kv_cache_delta=serve_cfg.kv_cache_delta)
        if serve_cfg.prefill_buckets and cfg.family != "dense":
            raise ValueError(
                "prefill_buckets pads prompts, which only dense-family "
                "models ignore (SSM state / MoE routing see pad tokens); "
                f"got family {cfg.family!r}")
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.backend = resolve_backend(backend)
        # preloaded: ``weights`` is already this backend's serving tree
        # (a ModelZoo admission that decoded or warm-forked it) — loading
        # again would double the cold-start cost and clobber the
        # backend's tracked delta levels
        self.params = weights if preloaded else self.backend.load(cfg,
                                                                  weights)

        self._slots = [_Slot() for _ in range(serve_cfg.slots)]
        self._queue: deque[RequestHandle] = deque()
        self._ids = itertools.count()
        self._rngs: dict[int, np.random.Generator] = {}
        self.stats = {
            "decode_steps": 0, "decode_rows": 0, "free_slot_rows": 0,
            "padded_rows": 0, "skipped_all_free_steps": 0,
            "prefill_tokens": 0, "prefix_reused_tokens": 0,
            "parks": 0, "resumes": 0, "admit_stalls": 0,
        }

        max_len = serve_cfg.max_len
        if any(b > max_len for b in serve_cfg.prefill_buckets):
            raise ValueError(f"prefill bucket exceeds max_len {max_len}")

        self._paged = serve_cfg.kv_page_size is not None
        if self._paged:
            self._caches = None        # no monolithic slot cache allocated
            self._kv = PagedKV(
                cfg, slots=serve_cfg.slots, max_len=max_len,
                page_size=serve_cfg.kv_page_size,
                pool_pages=serve_cfg.kv_pool_pages,
                cold_store=serve_cfg.kv_cold_store,
                codec=serve_cfg.kv_evict_codec,
                prefix_sharing=serve_cfg.kv_prefix_sharing,
                restore_workers=serve_cfg.kv_restore_workers)
            self._resume_q: deque = deque()     # (req, parked, pos, next)
            self._parked: dict = {}             # manual parks, by req id
            self._decode_paged = jax.jit(
                lambda p, pools, pages, tok, pos: decode_step(
                    p, cfg, pools, pos, tokens=tok, cache_pages=pages))
            self._prefill_fns: dict = {}        # cache_len -> jit
            self._prefill_pad_fns: dict = {}
            self._partial_fns: dict = {}        # n_ctx -> jit
            self._scatter_paged = jax.jit(self._scatter_paged_impl)
        else:
            self._kv = None
            self._caches = init_cache(cfg, serve_cfg.slots, max_len)
            self._prefill = jax.jit(
                lambda p, toks: prefill(p, cfg, tokens=toks,
                                        max_len=max_len))

            def prefill_padded(p, toks, last_idx):
                # padded admission: gather the last *real* prompt position
                # per row before the head projection (pad tail is causally
                # invisible, and the head only ever sees one position)
                caches = init_cache(cfg, toks.shape[0], max_len)
                logits, new_caches, _ = forward(p, cfg, tokens=toks,
                                                caches=caches,
                                                last_index=last_idx)
                return logits[:, 0, :], new_caches
            self._prefill_padded = jax.jit(prefill_padded)
            self._decode = jax.jit(
                lambda p, caches, tok, pos: decode_step(p, cfg, caches, pos,
                                                        tokens=tok))
            self._scatter = jax.jit(self._scatter_impl)

    @classmethod
    def from_container(cls, cfg: ModelConfig, blob: bytes, *,
                       backend="container",
                       serve_cfg: ServeConfig | None = None
                       ) -> "ServeSession":
        """Build a session straight from a DCBC deployment artifact."""
        return cls(cfg, blob, backend=backend, serve_cfg=serve_cfg)

    @classmethod
    def from_loaded(cls, cfg: ModelConfig, params, *, backend,
                    serve_cfg: ServeConfig | None = None) -> "ServeSession":
        """Wrap an already-built serving tree.  ``backend`` must be the
        instance that produced ``params`` (its tracked levels, if any,
        describe exactly this tree), so delta swaps keep working."""
        return cls(cfg, params, backend=backend, serve_cfg=serve_cfg,
                   preloaded=True)

    # -- client API ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               temperature: float = 0.0, seed=None) -> RequestHandle:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("prompt must contain at least one token")
        if prompt.size + max_new_tokens > self.serve_cfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({max_new_tokens}) exceeds slot capacity "
                f"{self.serve_cfg.max_len}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = RequestHandle(id=next(self._ids), prompt=prompt,
                            max_new_tokens=max_new_tokens,
                            temperature=temperature, seed=seed)
        self._queue.append(req)
        return req

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return sum(s.req is not None for s in self._slots)

    @property
    def num_parked(self) -> int:
        """Requests evicted to the compressed cold store (paged mode):
        auto-parked ones waiting to resume, plus manual :meth:`park`\\ s."""
        if not self._paged:
            return 0
        return len(self._resume_q) + len(self._parked)

    @property
    def pending(self) -> bool:
        active = bool(self._queue) or self.num_active > 0
        if self._paged:
            # manual parks (self._parked) wait for an explicit resume();
            # auto-parked requests re-admit themselves, so they count
            return active or bool(self._resume_q)
        return active

    def park(self, handle: RequestHandle) -> None:
        """Evict ``handle``'s slot to the compressed cold store.  The
        request keeps its sampling state and resumes **token-identically**
        (int8 caches round-trip bit-exactly) after :meth:`resume`."""
        if not self._paged:
            raise ValueError("park() needs the paged KV cache "
                             "(ServeConfig.kv_page_size)")
        idx = self._slot_of(handle)
        slot = self._slots[idx]
        parked = self._kv.park(idx)
        self._parked[handle.id] = (handle, parked, slot.pos,
                                   slot.next_token)
        slot.clear()
        self.stats["parks"] += 1

    def resume(self, handle: RequestHandle) -> None:
        """Queue a manually parked request for re-admission; its pages
        restore through the lane-parallel decoder on the next steps."""
        rec = self._parked.pop(handle.id, None)
        if rec is None:
            raise ValueError(f"request {handle.id} is not parked")
        self._kv.prefetch(rec[1])
        self._resume_q.append(rec)

    def cancel(self, handle: RequestHandle) -> bool:
        """Abort a request wherever it lives — queued, active, manually
        parked, or waiting to resume — releasing its slot/pages and, for
        parked requests, dropping the cold-store blob (a dir-backed
        store would otherwise keep the file until ``close()``).  Already
        finished requests are left alone (returns False)."""
        if handle.done:
            return False
        try:
            self._queue.remove(handle)
            return self._finish_cancelled(handle)
        except ValueError:
            pass
        if self._paged:
            rec = self._parked.pop(handle.id, None)
            if rec is not None:
                self._kv.discard(rec[1])
                return self._finish_cancelled(handle)
            for i, rec in enumerate(self._resume_q):
                if rec[0] is handle:
                    del self._resume_q[i]
                    self._kv.discard(rec[1])
                    return self._finish_cancelled(handle)
        for i, s in enumerate(self._slots):
            if s.req is handle:
                if self._paged:
                    self._kv.release(i)
                s.clear()
                return self._finish_cancelled(handle)
        raise ValueError(f"request {handle.id} is not known to this session")

    def _finish_cancelled(self, handle: RequestHandle) -> bool:
        handle.done = True
        handle.finish_reason = "cancelled"
        self._rngs.pop(handle.id, None)
        return True

    def _slot_of(self, handle: RequestHandle) -> int:
        for i, s in enumerate(self._slots):
            if s.req is handle:
                return i
        raise ValueError(f"request {handle.id} holds no slot")

    def swap_weights(self, source) -> int:
        """Swap in a delta ("P-frame") checkpoint step at a batch
        boundary: the backend decodes the step's residual records against
        its tracked base levels (``WeightBackend.apply_delta``) and the
        updated leaves replace their counterparts in ``self.params``.

        In-flight requests keep their slots and KV caches — the next
        :meth:`step` simply decodes with the new weights.  Leaf shapes,
        dtypes and the tree structure are unchanged by construction (a
        delta step is coded on the base frame's grid), so the jitted
        prefill/decode functions don't recompile.  The backend must have
        been built with ``track_levels=True`` and loaded from the chain's
        base frame.  Returns the number of updated tensors."""
        updates = self.backend.apply_delta(self.cfg, source)
        for name, leaf in updates.items():
            _insert(self.params, name, leaf)
        return len(updates)

    def run(self, max_steps: int | None = None) -> None:
        """Step until every submitted request finished (or max_steps)."""
        steps = 0
        while self.pending:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break

    def close(self) -> None:
        """Release the paged cache's cold store (no-op in slot mode)."""
        if self._paged:
            self._kv.close()

    # -- capacity accounting (one source of truth for bench + admission) ----

    def kv_bytes_per_slot(self) -> int:
        """Device KV bytes one request at full ``max_len`` context costs —
        derived from the real cache shapes via ``jax.eval_shape``, never
        recomputed by hand (``serve.kv.kv_cache_bytes``)."""
        return kv_cache_bytes(self.cfg, 1, self.serve_cfg.max_len)

    def kv_report(self) -> dict:
        """Total-KV accounting: device-resident bytes plus compressed
        host bytes, the per-slot cost, and the scheduler counters."""
        if self._paged:
            r = self._kv.report()
        else:
            r = {"mode": "slots",
                 "device_bytes": int(sum(
                     l.nbytes for l in jax.tree.leaves(self._caches))),
                 "host_compressed_bytes": 0}
        r["slots"] = len(self._slots)
        r["max_len"] = self.serve_cfg.max_len
        r["bytes_per_slot"] = self.kv_bytes_per_slot()
        r["scheduler"] = dict(self.stats)
        return r

    # -- scheduler -----------------------------------------------------------

    def step(self) -> None:
        """One scheduler tick: admit onto free slots, then one batched
        decode step, then evict finished requests.  In slot mode the
        decode batch spans every slot; in paged mode it is compacted to
        the active ones."""
        if self._paged:
            return self._step_paged()
        self._admit()
        if self.num_active == 0:
            self.stats["skipped_all_free_steps"] += 1
            return
        tok = np.zeros(len(self._slots), np.int32)
        pos = np.zeros(len(self._slots), np.int32)
        for i, slot in enumerate(self._slots):
            if slot.req is not None:
                tok[i] = slot.next_token
                pos[i] = slot.pos
        self.stats["decode_steps"] += 1
        self.stats["decode_rows"] += len(self._slots)
        self.stats["free_slot_rows"] += len(self._slots) - self.num_active
        logits, self._caches = self._decode(
            self.params, self._caches, jnp.asarray(tok), jnp.asarray(pos))
        logits = np.asarray(logits)
        for i, slot in enumerate(self._slots):
            if slot.req is None:
                continue
            slot.pos += 1
            nxt = self._sample(logits[i], slot.req)
            slot.req.tokens.append(nxt)
            slot.next_token = nxt
            self._maybe_evict(slot, i)

    def _admit(self) -> None:
        """Admit queued requests onto free slots.  The FIFO prefix sharing
        one (bucketed) prefill length is admitted as a single batched
        prefill — so a same-length burst (the ServeEngine wrapper's whole
        batch) costs one forward pass, not one per request."""
        while self._queue:
            free = [i for i, s in enumerate(self._slots) if s.req is None]
            if not free:
                return
            length = self._bucket_len(self._queue[0].prompt.size)
            group = []
            for req in itertools.islice(self._queue, len(free)):
                if self._bucket_len(req.prompt.size) != length:
                    break
                group.append(req)
            for _ in group:
                self._queue.popleft()
            slots_idx = free[:len(group)]

            toks = np.zeros((len(group), length), np.int32)
            for j, req in enumerate(group):
                toks[j, :req.prompt.size] = req.prompt
            if any(req.prompt.size < length for req in group):
                logits, caches_g = self._prefill_padded(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([r.prompt.size - 1 for r in group],
                                jnp.int32))
            else:
                logits, caches_g = self._prefill(self.params,
                                                 jnp.asarray(toks))
            self._place(caches_g, slots_idx)
            logits = np.asarray(logits)
            for j, req in enumerate(group):
                i = slots_idx[j]
                slot = self._slots[i]
                first = self._sample(logits[j], req)
                req.tokens.append(first)
                slot.req = req
                slot.pos = req.prompt.size
                slot.next_token = first
                self.stats["prefill_tokens"] += length
                self._maybe_evict(slot, i)

    def _place(self, caches_g, slots_idx: list) -> None:
        """Scatter a batch-k prefill's caches into slots ``slots_idx``:
        one contiguous write when the slots are adjacent (the common case
        on an idle session), per-row writes otherwise."""
        if slots_idx == list(range(slots_idx[0],
                                   slots_idx[0] + len(slots_idx))):
            self._caches = self._scatter(
                self._caches, caches_g,
                jnp.asarray(slots_idx[0], jnp.int32))
            return
        for j, slot_i in enumerate(slots_idx):
            row = jax.tree.map(lambda a: a[:, j:j + 1], caches_g)
            self._caches = self._scatter(self._caches, row,
                                         jnp.asarray(slot_i, jnp.int32))

    def _maybe_evict(self, slot: _Slot, idx: int) -> None:
        req = slot.req
        eos = self.serve_cfg.eos_token
        if eos is not None and req.tokens[-1] == eos:
            req.finish_reason = "eos"
        elif len(req.tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
        elif slot.pos >= self.serve_cfg.max_len:
            req.finish_reason = "length"
        else:
            return
        req.done = True
        self._rngs.pop(req.id, None)
        if self._paged:
            self._kv.release(idx)
        slot.clear()

    # -- paged scheduler -----------------------------------------------------

    def _step_paged(self) -> None:
        self._admit_paged()
        active = [i for i, s in enumerate(self._slots) if s.req is not None]
        if not active:
            self.stats["skipped_all_free_steps"] += 1
            return
        # page-boundary allocation; a slot the pool can't grow parks
        # itself (compressed to host) and re-admits when pressure clears
        still = []
        for i in active:
            if self._kv.ensure_writable(i, self._slots[i].pos):
                still.append(i)
            else:
                self._auto_park(i)
        active = still
        if not active:
            return
        bs = min(1 << (len(active) - 1).bit_length(), len(self._slots))
        tok = np.zeros(bs, np.int32)
        pos = np.zeros(bs, np.int32)
        pages = np.zeros((bs, self._kv.n_max), np.int32)   # pads -> scratch
        for j, i in enumerate(active):
            tok[j] = self._slots[i].next_token
            pos[j] = self._slots[i].pos
            pages[j] = self._kv.page_row(i)
        self.stats["decode_steps"] += 1
        self.stats["decode_rows"] += bs
        self.stats["padded_rows"] += bs - len(active)
        logits, self._kv.pools = self._decode_paged(
            self.params, self._kv.pools, jnp.asarray(pages),
            jnp.asarray(tok), jnp.asarray(pos))
        logits = np.asarray(logits)
        for j, i in enumerate(active):
            slot = self._slots[i]
            slot.pos += 1
            nxt = self._sample(logits[j], slot.req)
            slot.req.tokens.append(nxt)
            slot.next_token = nxt
            self._maybe_evict(slot, i)

    def _admit_paged(self) -> None:
        """Resumes first (FIFO), then fresh admissions — one batch=1
        prefill each, since page tables are per-request."""
        while self._resume_q:
            free = [i for i, s in enumerate(self._slots) if s.req is None]
            if not free:
                return
            req, parked, pos, next_token = self._resume_q[0]
            if not self._kv.resume(free[0], parked):
                self.stats["admit_stalls"] += 1
                break                      # pool pressure; retry next step
            self._resume_q.popleft()
            slot = self._slots[free[0]]
            slot.req, slot.pos, slot.next_token = req, pos, next_token
            self.stats["resumes"] += 1
        while self._queue:
            free = [i for i, s in enumerate(self._slots) if s.req is None]
            if not free:
                return
            req = self._queue[0]
            # fresh admissions may park a victim slot to make room, but
            # never while resumes are waiting (no priority inversion)
            make_room = self._park_victim if not self._resume_q else None
            min_len = self._bucket_len(req.prompt.size)
            ctx_len = self._kv.admit(free[0], req.prompt, min_len=min_len,
                                     make_room=make_room)
            if ctx_len is None:
                self.stats["admit_stalls"] += 1
                return
            self._queue.popleft()
            logits_row = self._prefill_paged(free[0], req, ctx_len)
            self._kv.publish(free[0])
            slot = self._slots[free[0]]
            first = self._sample(logits_row, req)
            req.tokens.append(first)
            slot.req = req
            slot.pos = req.prompt.size
            slot.next_token = first
            self._maybe_evict(slot, free[0])

    def _prefill_paged(self, idx: int, req: RequestHandle,
                       ctx_len: int) -> np.ndarray:
        """Prefill into the slot's freshly built page table.  With a
        shared-prefix hit only the suffix runs (partial prefill over the
        gathered context pages); otherwise the whole (bucketed) prompt
        prefills into a contiguous cache that is scattered to the pages."""
        prompt = req.prompt
        page = self._kv.page
        ids = self._kv.slot_ids(idx)
        if ctx_len > 0:
            n_ctx = ctx_len // page
            fn = self._partial_prefill_fn(n_ctx)
            logits, self._kv.pools = fn(
                self.params, self._kv.pools, jnp.asarray(ids, jnp.int32),
                jnp.asarray(prompt[None, ctx_len:]))
            self.stats["prefix_reused_tokens"] += ctx_len
            self.stats["prefill_tokens"] += prompt.size - ctx_len
            return np.asarray(logits)[0]
        length = self._bucket_len(prompt.size)
        cache_len = len(ids) * page
        toks = np.zeros((1, length), np.int32)
        toks[0, :prompt.size] = prompt
        if prompt.size < length:
            logits, caches = self._prefill_pad_fn(cache_len)(
                self.params, jnp.asarray(toks),
                jnp.asarray([prompt.size - 1], jnp.int32))
        else:
            logits, caches = self._prefill_fn(cache_len)(
                self.params, jnp.asarray(toks))
        self._kv.pools = self._scatter_paged(
            self._kv.pools, caches, jnp.asarray(ids, jnp.int32))
        self.stats["prefill_tokens"] += length
        return np.asarray(logits)[0]

    def _auto_park(self, idx: int) -> None:
        slot = self._slots[idx]
        parked = self._kv.park(idx)
        rec = (slot.req, parked, slot.pos, slot.next_token)
        self._kv.prefetch(parked)
        self._resume_q.append(rec)
        slot.clear()
        self.stats["parks"] += 1

    def _park_victim(self) -> bool:
        """Pool-pressure callback: auto-park the active slot holding the
        most pages (ties to the youngest request, keeping older requests
        running).  False when no slot can be parked."""
        cands = [(len(self._kv.slot_ids(i)), self._slots[i].req.id, i)
                 for i, s in enumerate(self._slots) if s.req is not None]
        if not cands:
            return False
        _, _, idx = max(cands)
        self._auto_park(idx)
        return True

    # -- jit caches (paged mode compiles per cache length / ctx pages) ------

    def _prefill_fn(self, cache_len: int):
        fn = self._prefill_fns.get(cache_len)
        if fn is None:
            cfg = self.cfg
            fn = jax.jit(lambda p, toks: prefill(p, cfg, tokens=toks,
                                                 max_len=cache_len))
            self._prefill_fns[cache_len] = fn
        return fn

    def _prefill_pad_fn(self, cache_len: int):
        fn = self._prefill_pad_fns.get(cache_len)
        if fn is None:
            cfg = self.cfg

            def pad_fn(p, toks, last_idx):
                caches = init_cache(cfg, toks.shape[0], cache_len)
                logits, new_caches, _ = forward(p, cfg, tokens=toks,
                                                caches=caches,
                                                last_index=last_idx)
                return logits[:, 0, :], new_caches
            fn = jax.jit(pad_fn)
            self._prefill_pad_fns[cache_len] = fn
        return fn

    def _partial_prefill_fn(self, n_ctx: int):
        """Suffix prefill over a shared prefix: gather the slot's pages to
        a contiguous view, run the suffix at ``cache_pos = n_ctx * page``
        (scalar — the S>1 cache write / causal-mask path), scatter back
        only the suffix pages.  The shared context pages are read-only."""
        fn = self._partial_fns.get(n_ctx)
        if fn is None:
            cfg, page = self.cfg, self._kv.page

            def partial_fn(p, pools, ids, toks):
                def gather(pool):
                    g = jnp.take(pool, ids, axis=1)
                    return g.reshape(g.shape[0], 1, g.shape[1] * page,
                                     *g.shape[3:])
                contig = jax.tree.map(gather, pools)
                logits, newc, _ = forward(p, cfg, tokens=toks,
                                          caches=contig,
                                          cache_pos=n_ctx * page,
                                          last_only=True)

                def put(pool, c):
                    c = c.reshape(c.shape[0], ids.shape[0], page,
                                  *c.shape[3:])
                    return pool.at[:, ids[n_ctx:]].set(
                        c[:, n_ctx:].astype(pool.dtype))
                return logits[:, 0], jax.tree.map(put, pools, newc)
            fn = jax.jit(partial_fn)
            self._partial_fns[n_ctx] = fn
        return fn

    @staticmethod
    def _scatter_paged_impl(pools, caches, ids):
        """Scatter a batch-1 prefill's contiguous caches (L, 1, n*page,
        ...) into pool pages ``ids``."""
        def put(pool, c):
            page = pool.shape[2]
            c = c.reshape(c.shape[0], ids.shape[0], page, *c.shape[3:])
            return pool.at[:, ids].set(c.astype(pool.dtype))
        return jax.tree.map(put, pools, caches)

    # -- helpers -------------------------------------------------------------

    def _bucket_len(self, n: int) -> int:
        """Smallest configured prefill bucket >= n (n itself if none)."""
        fits = [b for b in self.serve_cfg.prefill_buckets if b >= n]
        return min(fits) if fits else n

    @staticmethod
    def _scatter_impl(caches, caches1, slot_idx):
        """Write a batch=1 prefill's caches into slot ``slot_idx`` (every
        cache leaf carries the slot axis at position 1)."""
        return jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot_idx, axis=1),
            caches, caches1)

    def _sample(self, logits_row: np.ndarray, req: RequestHandle) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = self._rngs.get(req.id)
        if rng is None:
            # per-request seed (reproducible across sessions) or a
            # session-seed + request-id derivation
            key = (req.seed if req.seed is not None
                   else (self.serve_cfg.seed, req.id))
            rng = np.random.default_rng(key)
            self._rngs[req.id] = rng
        z = logits_row.astype(np.float64) / req.temperature
        return int(np.argmax(z + rng.gumbel(size=z.shape)))
