"""Batched serving engine: prefill + greedy/temperature decode over a
preallocated KV/state cache, loading weights from DeepCABAC containers.

The from-compressed path is the paper's deployment story: an 8.7 MB
container instead of a 553 MB fp32 blob, decoded chunk-parallel at load
time.  The fixed-point serving path (dequant_matmul kernel) consumes the
quantized levels directly — see kernels/dequant_matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..compression import decompress
from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_params, prefill


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks, max_len=max_len))
        self._decode = jax.jit(
            lambda p, caches, tok, pos: decode_step(p, cfg, caches, pos,
                                                    tokens=tok))

    # -- loading -------------------------------------------------------------
    @classmethod
    def from_compressed(cls, cfg: ModelConfig, blob: bytes,
                        max_len: int = 512) -> "ServeEngine":
        template = init_params(cfg, jax.random.PRNGKey(0))
        params = decompress(blob, like=template)
        return cls(cfg, params, max_len)

    # -- generation ------------------------------------------------------------
    def generate(self, prompts: np.ndarray, steps: int,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts (B, S) int32 -> (B, S + steps) including generated ids."""
        toks = jnp.asarray(prompts, jnp.int32)
        b, s = toks.shape
        assert s + steps <= self.max_len, "exceeds cache length"
        logits, caches = self._prefill(self.params, toks)
        out = [np.asarray(toks)]
        key = jax.random.PRNGKey(seed)
        cur = self._sample(logits, temperature, key)
        for i in range(steps):
            out.append(np.asarray(cur)[:, None])
            if i == steps - 1:
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, cur, s + i)
            cur = self._sample(logits, temperature, sub)
        return np.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
