"""Batch-call compatibility wrapper over the request-level ServeSession.

``ServeEngine`` keeps the original one-shot API — ``generate(prompts,
steps)`` over same-length prompts — but delegates scheduling, KV slot
management and sampling to :class:`~repro.serve.session.ServeSession`.
New code should use ``ServeSession`` directly (per-request lengths,
streaming, admission/eviction); see docs/serving_api.md.
"""

from __future__ import annotations

import numpy as np

from ..models.config import ModelConfig
from .backends import get_backend
from .session import ServeConfig, ServeSession


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 backend: str = "bf16"):
        self.cfg = cfg
        self.params = get_backend(backend).load(cfg, params)
        self.max_len = max_len
        self._sessions: dict[int, ServeSession] = {}

    # -- loading -------------------------------------------------------------
    @classmethod
    def from_compressed(cls, cfg: ModelConfig, blob: bytes,
                        max_len: int = 512,
                        backend: str = "container") -> "ServeEngine":
        """Load from a DCBC container via the streaming container backend
        (per-tensor decode; serve-q8 records stay int8 in memory).
        ``__init__`` accepts blobs directly; this name is kept for the
        original API."""
        return cls(cfg, blob, max_len=max_len, backend=backend)

    def _session(self, slots: int) -> ServeSession:
        # one session per batch size, kept for the engine's lifetime so
        # jit caches persist across generate calls (matching the old
        # engine's per-shape jit cache).  Sampling streams are seeded per
        # request in generate(), so reuse stays reproducible.
        if slots not in self._sessions:
            # params are already loaded — "bf16" passes pytrees through
            self._sessions[slots] = ServeSession(
                self.cfg, self.params, backend="bf16",
                serve_cfg=ServeConfig(slots=slots, max_len=self.max_len))
        return self._sessions[slots]

    # -- generation ------------------------------------------------------------
    def generate(self, prompts: np.ndarray, steps: int,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts (B, S) int32 -> (B, S + steps) including generated ids."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        assert s + steps <= self.max_len, "exceeds cache length"
        session = self._session(b)
        handles = [session.submit(prompts[i], max_new_tokens=steps,
                                  temperature=temperature, seed=(seed, i))
                   for i in range(b)]
        session.run()
        gen = np.stack([h.result() for h in handles])
        return np.concatenate([prompts, gen], axis=1)
