"""Pluggable serving weight backends: how a ServeSession gets its params.

A ``WeightBackend`` turns a weight *source* (an in-memory pytree or a DCBC
container blob) into the parameter tree the model consumes.  The string
registry mirrors ``repro.compression``'s codec registry — new backends
plug in via :func:`register_backend` without touching any call site:

    ``bf16``       dequantize-on-load: full-precision leaves in memory
                   (blobs are decoded record-by-record, then dropped).
    ``q8``         fixed-point serving: eligible matmul weights become
                   in-memory ``{"q8","q8s"}`` leaves that drive the
                   ``dequant_matmul`` and ``embed_lookup_q8`` registry ops
                   (kernels.get(...); impl/tiles picked by the model's
                   KernelPolicy) through the model (int8 HBM reads,
                   in-core dequant).
    ``container``  the paper's deployment artifact: stream-decode a DCBC
                   blob via the per-tensor iterator
                   (``compression.iter_decompress``), so peak decoded host
                   memory is bounded by the largest tensor — layer-bound,
                   not model-bound.  ``serve-q8`` records stay int8.

Blob loads never materialize the full fp32 tree: the template comes from
``jax.eval_shape`` (shapes/dtypes only) and each decoded tensor is
converted to its destination representation before the next record is
pulled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import DecodeOptions, iter_decompress
from ..compression.quantizers import serve_q8_policy
from ..compression.tree import _path_key
from ..core.codec import Q8Tensor
from .quantized import quantize_leaf, quantize_tree_q8


class WeightBackend:
    """Strategy interface: one weight source -> serving parameter tree.

    ``decode`` tunes the entropy-decode of container blobs at cold start:
    v3 cabac records route every chunk of a tensor through the
    lane-parallel engine (``repro.core.cabac_vec``) as one batch, so the
    backend keeps the layer-bound streaming contract *and* vectorized
    decode.  Defaults come from ``DecodeOptions()`` (env-tunable lanes /
    engine).
    """

    name = "?"

    def __init__(self, decode: DecodeOptions | None = None):
        self.decode = decode or DecodeOptions()

    def load(self, cfg, source):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry (mirrors compression.registry)
# ---------------------------------------------------------------------------

_BACKENDS: dict = {}


def register_backend(name: str, factory) -> None:
    _BACKENDS[name] = factory


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **overrides) -> WeightBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown weight backend {name!r}; "
                       f"available: {available_backends()}")
    return _BACKENDS[name](**overrides)


def resolve_backend(backend) -> WeightBackend:
    """Accept a registry name or an already-built backend instance."""
    if isinstance(backend, WeightBackend):
        return backend
    return get_backend(backend)


# ---------------------------------------------------------------------------
# Streaming container fold
# ---------------------------------------------------------------------------

def _template_specs(cfg) -> dict:
    """Flat name -> ShapeDtypeStruct map from the abstract init (shapes
    and dtypes only — no weight memory is materialized)."""
    from ..models.transformer import init_params
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        # _path_key is the same join container record names were written
        # with (compression.tree.flatten_tree), so lookups can't drift
        out[_path_key(path)] = leaf
    return out


def _insert(tree: dict, name: str, leaf) -> None:
    parts = name.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = leaf


def _stream_tree(cfg, blob: bytes, convert,
                 decode: DecodeOptions | None = None) -> dict:
    """Fold the per-tensor decode iterator into a nested params dict.

    ``convert(name, record, dtype)`` maps one decoded record to its final
    (device) leaf; the host-side decoded array is dropped before the next
    record is decoded, so decoded-host peak stays one-tensor-bounded.

    Validated against the model template (same contract the old
    ``decompress(blob, like=template)`` path enforced): records the model
    doesn't expect are skipped, shape mismatches raise at load time, and
    a container missing template tensors raises instead of failing deep
    inside ``forward``.
    """
    specs = _template_specs(cfg)
    tree: dict = {}
    seen: set = set()
    for name, record in iter_decompress(blob, dequantize=False, opts=decode):
        spec = specs.get(name)
        if spec is None:
            continue                       # not part of this model
        shape = tuple(record.shape)
        if shape != tuple(spec.shape):
            raise ValueError(
                f"{name}: container shape {shape} != model "
                f"{tuple(spec.shape)}")
        seen.add(name)
        _insert(tree, name, convert(name, record, spec.dtype))
    missing = sorted(set(specs) - seen)
    if missing:
        raise KeyError(
            f"container missing {len(missing)} model tensor(s), e.g. "
            f"{missing[:3]}")
    return tree


def _to_array(record, dtype):
    """Decoded record -> device array in the template dtype.

    ``copy=True`` forces a real device buffer (host->HBM on accelerators;
    on the CPU backend jax would otherwise alias the decoded numpy buffer,
    silently pinning every decoded tensor on the host heap and defeating
    the layer-bound streaming contract)."""
    arr = np.asarray(record.dequantize()
                     if hasattr(record, "dequantize") else record)
    return jnp.array(arr, dtype=dtype or arr.dtype, copy=True)


def _q8_leaf(record: Q8Tensor) -> dict:
    return {"q8": jnp.array(record.levels, copy=True),
            "q8s": jnp.array(record.scale, dtype=jnp.float32, copy=True)}


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

class Bf16Backend(WeightBackend):
    """Dequantize-on-load (the classic ServeEngine path): pytrees pass
    through untouched; blobs decode to full-precision leaves in the
    model's param dtype."""

    name = "bf16"

    def load(self, cfg, source):
        if isinstance(source, (bytes, bytearray, memoryview)):
            return _stream_tree(cfg, bytes(source),
                                lambda name, rec, dt: _to_array(rec, dt),
                                decode=self.decode)
        return source


class Q8Backend(WeightBackend):
    """In-memory fixed-point serving: matmul weights become
    ``{"q8","q8s"}`` leaves (per-out-channel int8 + Delta), which the
    model dequantizes in-core after int8 HBM reads (the
    ``dequant_matmul`` head and ``embed_lookup_q8`` gather registry ops,
    in-scan ``dequant_tree``)."""

    name = "q8"

    def load(self, cfg, source):
        if isinstance(source, (bytes, bytearray, memoryview)):
            def convert(name, rec, dt):
                if isinstance(rec, Q8Tensor):
                    return _q8_leaf(rec)
                arr = _to_array(rec, dt)
                if serve_q8_policy(name, arr):
                    return quantize_leaf(arr)
                return arr
            return _stream_tree(cfg, bytes(source), convert,
                                decode=self.decode)
        return quantize_tree_q8(source)


class ContainerBackend(WeightBackend):
    """Serve straight from the DeepCABAC deployment artifact: stream the
    container record-by-record; ``serve-q8`` records stay int8 (decode-free
    fixed-point path), entropy-coded records dequantize to the param
    dtype.  Peak decoded host memory is layer-bound by construction."""

    name = "container"

    def load(self, cfg, source):
        if not isinstance(source, (bytes, bytearray, memoryview)):
            raise TypeError(
                "container backend loads DCBC blobs (bytes); got "
                f"{type(source).__name__} — use the 'bf16' or 'q8' backend "
                "for in-memory pytrees")

        def convert(name, rec, dt):
            if isinstance(rec, Q8Tensor):
                return _q8_leaf(rec)
            return _to_array(rec, dt)
        return _stream_tree(cfg, bytes(source), convert, decode=self.decode)


register_backend("bf16", Bf16Backend)
register_backend("q8", Q8Backend)
register_backend("container", ContainerBackend)
