"""Pluggable serving weight backends: how a ServeSession gets its params.

A ``WeightBackend`` turns a weight *source* (an in-memory pytree or a DCBC
container blob) into the parameter tree the model consumes.  The string
registry mirrors ``repro.compression``'s codec registry — new backends
plug in via :func:`register_backend` without touching any call site:

    ``bf16``       dequantize-on-load: full-precision leaves in memory
                   (blobs are decoded record-by-record, then dropped).
    ``q8``         fixed-point serving: eligible matmul weights become
                   in-memory ``{"q8","q8s"}`` leaves that drive the
                   ``dequant_matmul`` and ``embed_lookup_q8`` registry ops
                   (kernels.get(...); impl/tiles picked by the model's
                   KernelPolicy) through the model (int8 HBM reads,
                   in-core dequant).
    ``container``  the paper's deployment artifact: stream-decode a DCBC
                   blob via the per-tensor iterator
                   (``compression.iter_decompress``), so peak decoded host
                   memory is bounded by the largest tensor — layer-bound,
                   not model-bound.  ``serve-q8`` records stay int8.

Blob loads never materialize the full fp32 tree: the template comes from
``jax.eval_shape`` (shapes/dtypes only) and each decoded tensor is
converted to its destination representation before the next record is
pulled.

Backends also cold-start from a *sharded checkpoint manifest*: pass a
path (the checkpoint step directory, or the ``params.manifest.json``
itself) as the weight source and tensors are assembled shard-by-shard
through ``repro.checkpoint.sharded`` — with a serving ``mesh`` set on the
backend, only the shard files / v3 chunk ranges covering the mesh's local
slices are read and decoded, and parameters arrive as mesh-sharded
``jax.Array``\\ s.  See docs/compression_api.md ("Sharded checkpoints").
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import DecodeOptions, iter_decompress
from ..compression.quantizers import serve_q8_policy
from ..compression.tree import _path_key
from ..core.codec import Q8Tensor
from .quantized import quantize_leaf, quantize_tree_q8


class WeightBackend:
    """Strategy interface: one weight source -> serving parameter tree.

    ``decode`` tunes the entropy-decode of container blobs at cold start:
    v3 cabac records route every chunk of a tensor through the
    lane-parallel engine (``repro.core.cabac_vec``) as one batch, so the
    backend keeps the layer-bound streaming contract *and* vectorized
    decode.  Defaults come from ``DecodeOptions()`` (env-tunable lanes /
    engine).

    ``mesh`` scopes *manifest* cold starts to a serving mesh: entropy-
    coded tensors come back as mesh-sharded ``jax.Array``\\ s assembled
    from only the shards each local device's slice needs.
    """

    name = "?"

    def __init__(self, decode: DecodeOptions | None = None, mesh=None):
        self.decode = decode or DecodeOptions()
        self.mesh = mesh

    def load(self, cfg, source):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry (mirrors compression.registry)
# ---------------------------------------------------------------------------

_BACKENDS: dict = {}


def register_backend(name: str, factory) -> None:
    _BACKENDS[name] = factory


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **overrides) -> WeightBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown weight backend {name!r}; "
                       f"available: {available_backends()}")
    return _BACKENDS[name](**overrides)


def resolve_backend(backend) -> WeightBackend:
    """Accept a registry name or an already-built backend instance."""
    if isinstance(backend, WeightBackend):
        return backend
    return get_backend(backend)


# ---------------------------------------------------------------------------
# Streaming container fold
# ---------------------------------------------------------------------------

def _template_specs(cfg) -> dict:
    """Flat name -> ShapeDtypeStruct map from the abstract init (shapes
    and dtypes only — no weight memory is materialized)."""
    from ..models.transformer import init_params
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        # _path_key is the same join container record names were written
        # with (compression.tree.flatten_tree), so lookups can't drift
        out[_path_key(path)] = leaf
    return out


def _insert(tree: dict, name: str, leaf) -> None:
    parts = name.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = leaf


def _stream_tree(cfg, blob: bytes, convert,
                 decode: DecodeOptions | None = None) -> dict:
    """Fold the per-tensor decode iterator into a nested params dict.

    ``convert(name, record, dtype)`` maps one decoded record to its final
    (device) leaf; the host-side decoded array is dropped before the next
    record is decoded, so decoded-host peak stays one-tensor-bounded.

    Validated against the model template (same contract the old
    ``decompress(blob, like=template)`` path enforced): records the model
    doesn't expect are skipped, shape mismatches raise at load time, and
    a container missing template tensors raises instead of failing deep
    inside ``forward``.
    """
    specs = _template_specs(cfg)
    tree: dict = {}
    seen: set = set()
    for name, record in iter_decompress(blob, dequantize=False, opts=decode):
        spec = specs.get(name)
        if spec is None:
            continue                       # not part of this model
        shape = tuple(record.shape)
        if shape != tuple(spec.shape):
            raise ValueError(
                f"{name}: container shape {shape} != model "
                f"{tuple(spec.shape)}")
        seen.add(name)
        _insert(tree, name, convert(name, record, spec.dtype))
    missing = sorted(set(specs) - seen)
    if missing:
        raise KeyError(
            f"container missing {len(missing)} model tensor(s), e.g. "
            f"{missing[:3]}")
    return tree


def _is_manifest_source(source) -> bool:
    return isinstance(source, (str, os.PathLike))


def _manifest_tree(cfg, source, convert,
                   decode: DecodeOptions | None = None, mesh=None) -> dict:
    """Cold-start from a sharded checkpoint manifest.

    Same template-validation contract as :func:`_stream_tree`, but the
    source is a directory of per-shard DCBC files + manifest
    (``repro.checkpoint.sharded``).  Tensors are assembled one at a time
    (layer-bound decoded-host peak); with ``mesh``, entropy-coded tensors
    skip ``convert`` and arrive as mesh-sharded ``jax.Array``\\ s built
    from only the shards the mesh's local slices cover.
    """
    from ..checkpoint import sharded
    directory = sharded.manifest_dir(str(source))
    manifest = sharded.load_manifest(str(source))
    num_gr = manifest.get("num_gr")
    specs = _template_specs(cfg)
    tree: dict = {}
    seen: set = set()
    for name, tinfo in sorted(manifest["tensors"].items()):
        spec = specs.get(name)
        if spec is None:
            continue                       # not part of this model
        if tuple(tinfo["shape"]) != tuple(spec.shape):
            raise ValueError(
                f"{name}: manifest shape {tuple(tinfo['shape'])} != model "
                f"{tuple(spec.shape)}")
        seen.add(name)
        if mesh is not None and tinfo["encoding"] != "q8":
            leaf = sharded.restore_tensor_on_mesh(
                directory, name, tinfo, mesh, opts=decode, num_gr=num_gr,
                dtype=spec.dtype)
        else:
            rec = sharded.assemble_slice(
                directory, name, tinfo, opts=decode, num_gr=num_gr,
                dequantize=False)
            leaf = convert(name, rec, spec.dtype)
        _insert(tree, name, leaf)
    missing = sorted(set(specs) - seen)
    if missing:
        raise KeyError(
            f"manifest missing {len(missing)} model tensor(s), e.g. "
            f"{missing[:3]}")
    return tree


def _to_array(record, dtype):
    """Decoded record -> device array in the template dtype.

    ``copy=True`` forces a real device buffer (host->HBM on accelerators;
    on the CPU backend jax would otherwise alias the decoded numpy buffer,
    silently pinning every decoded tensor on the host heap and defeating
    the layer-bound streaming contract)."""
    arr = np.asarray(record.dequantize()
                     if hasattr(record, "dequantize") else record)
    return jnp.array(arr, dtype=dtype or arr.dtype, copy=True)


def _q8_leaf(record: Q8Tensor) -> dict:
    return {"q8": jnp.array(record.levels, copy=True),
            "q8s": jnp.array(record.scale, dtype=jnp.float32, copy=True)}


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

class Bf16Backend(WeightBackend):
    """Dequantize-on-load (the classic ServeEngine path): pytrees pass
    through untouched; blobs decode to full-precision leaves in the
    model's param dtype."""

    name = "bf16"

    def load(self, cfg, source):
        if _is_manifest_source(source):
            return _manifest_tree(cfg, source,
                                  lambda name, rec, dt: _to_array(rec, dt),
                                  decode=self.decode, mesh=self.mesh)
        if isinstance(source, (bytes, bytearray, memoryview)):
            return _stream_tree(cfg, bytes(source),
                                lambda name, rec, dt: _to_array(rec, dt),
                                decode=self.decode)
        return source


class Q8Backend(WeightBackend):
    """In-memory fixed-point serving: matmul weights become
    ``{"q8","q8s"}`` leaves (per-out-channel int8 + Delta), which the
    model dequantizes in-core after int8 HBM reads (the
    ``dequant_matmul`` head and ``embed_lookup_q8`` gather registry ops,
    in-scan ``dequant_tree``)."""

    name = "q8"

    def load(self, cfg, source):
        def convert(name, rec, dt):
            if isinstance(rec, Q8Tensor):
                return _q8_leaf(rec)
            arr = _to_array(rec, dt)
            if serve_q8_policy(name, arr):
                return quantize_leaf(arr)
            return arr
        if _is_manifest_source(source):
            # host-side conversion: every decoded tensor becomes an
            # in-memory {"q8","q8s"} leaf, so the mesh-sharded fast path
            # doesn't apply here
            return _manifest_tree(cfg, source, convert, decode=self.decode)
        if isinstance(source, (bytes, bytearray, memoryview)):
            return _stream_tree(cfg, bytes(source), convert,
                                decode=self.decode)
        return quantize_tree_q8(source)


class ContainerBackend(WeightBackend):
    """Serve straight from the DeepCABAC deployment artifact: stream the
    container record-by-record; ``serve-q8`` records stay int8 (decode-free
    fixed-point path), entropy-coded records dequantize to the param
    dtype.  Peak decoded host memory is layer-bound by construction."""

    name = "container"

    def load(self, cfg, source):
        def convert(name, rec, dt):
            if isinstance(rec, Q8Tensor):
                return _q8_leaf(rec)
            return _to_array(rec, dt)
        if _is_manifest_source(source):
            return _manifest_tree(cfg, source, convert,
                                  decode=self.decode, mesh=self.mesh)
        if not isinstance(source, (bytes, bytearray, memoryview)):
            raise TypeError(
                "container backend loads DCBC blobs (bytes) or a sharded-"
                "checkpoint manifest path; got "
                f"{type(source).__name__} — use the 'bf16' or 'q8' backend "
                "for in-memory pytrees")
        return _stream_tree(cfg, bytes(source), convert, decode=self.decode)


register_backend("bf16", Bf16Backend)
register_backend("q8", Q8Backend)
register_backend("container", ContainerBackend)
