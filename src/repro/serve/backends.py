"""Pluggable serving weight backends: how a ServeSession gets its params.

A ``WeightBackend`` turns a weight *source* (an in-memory pytree or a DCBC
container blob) into the parameter tree the model consumes.  The string
registry mirrors ``repro.compression``'s codec registry — new backends
plug in via :func:`register_backend` without touching any call site:

    ``bf16``       dequantize-on-load: full-precision leaves in memory
                   (blobs are decoded record-by-record, then dropped).
    ``q8``         fixed-point serving: eligible matmul weights become
                   in-memory ``{"q8","q8s"}`` leaves that drive the
                   ``dequant_matmul`` and ``embed_lookup_q8`` registry ops
                   (kernels.get(...); impl/tiles picked by the model's
                   KernelPolicy) through the model (int8 HBM reads,
                   in-core dequant).
    ``container``  the paper's deployment artifact: stream-decode a DCBC
                   blob via the per-tensor iterator
                   (``compression.iter_decompress``), so peak decoded host
                   memory is bounded by the largest tensor — layer-bound,
                   not model-bound.  ``serve-q8`` records stay int8.

Blob loads never materialize the full fp32 tree: the template comes from
``jax.eval_shape`` (shapes/dtypes only) and each decoded tensor is
converted to its destination representation before the next record is
pulled.

Backends also cold-start from a *sharded checkpoint manifest*: pass a
path (the checkpoint step directory, or the ``params.manifest.json``
itself) as the weight source and tensors are assembled shard-by-shard
through ``repro.checkpoint.sharded`` — with a serving ``mesh`` set on the
backend, only the shard files / v3 chunk ranges covering the mesh's local
slices are read and decoded, and parameters arrive as mesh-sharded
``jax.Array``\\ s.  See docs/compression_api.md ("Sharded checkpoints").

Live weight swap: a backend built with ``track_levels=True`` keeps the
integer quantization levels of every entropy-decoded tensor resident, so
:meth:`WeightBackend.apply_delta` can patch the serving weights from a
delta ("P-frame") checkpoint step — residuals applied in level space,
bit-identical to a cold start of the new frame — without re-decoding the
whole model.  See docs/serving_api.md ("Live weight swap").
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import DecodeOptions, iter_decompress
from ..compression.quantizers import serve_q8_policy
from ..compression.tree import _path_key
from ..core.codec import (Q8Tensor, QuantizedTensor, decode_delta_record,
                          decode_record)
from ..core.container import ENC_CABAC_DELTA, ContainerReader
from .quantized import quantize_leaf, quantize_tree_q8


class WeightBackend:
    """Strategy interface: one weight source -> serving parameter tree.

    ``decode`` tunes the entropy-decode of container blobs at cold start:
    v3 cabac records route every chunk of a tensor through the
    lane-parallel engine (``repro.core.cabac_vec``) as one batch, so the
    backend keeps the layer-bound streaming contract *and* vectorized
    decode.  Defaults come from ``DecodeOptions()`` (env-tunable lanes /
    engine).

    ``mesh`` scopes *manifest* cold starts to a serving mesh: entropy-
    coded tensors come back as mesh-sharded ``jax.Array``\\ s assembled
    from only the shards each local device's slice needs.

    ``track_levels`` keeps each entropy-decoded tensor's integer
    quantization levels resident next to the converted leaf, which is
    what :meth:`apply_delta` needs to patch the weights live from a delta
    ("P-frame") checkpoint step: residual records apply to the tracked
    base levels in integer space, so the swapped-in weights are
    bit-identical to a cold start of the new frame.  It costs one int64
    copy of the quantized model host-side — leave it off for static
    deployments.  See docs/serving_api.md ("Live weight swap").

    ``policy_table`` (a ``TensorPolicy`` / its dict payload / a JSON
    path — see ``repro.compression.rd_search``) applies a swept
    per-tensor mixed-precision policy to *pytree* sources at load: each
    covered tensor is quantized on its table rule and dequantized back,
    so a pytree-loaded session is numerically identical to one cold-
    started from the matching ``deepcabac-rd`` container.  Container /
    manifest sources ignore it (their tensors were already quantized at
    encode time).
    """

    name = "?"
    # True when the resident tree holds serve-quantized tensors as
    # {"q8","q8s"} leaves (int8 levels + f32 scales) — admission
    # accounting (zoo.model_resident_bytes) sizes those leaves at int8
    # width instead of the param dtype
    q8_resident = False

    def __init__(self, decode: DecodeOptions | None = None, mesh=None,
                 track_levels: bool = False, policy_table=None):
        self.decode = decode or DecodeOptions()
        self.mesh = mesh
        self.track_levels = track_levels
        self.policy_table = policy_table
        self._levels: dict[str, QuantizedTensor] | None = (
            {} if track_levels else None)

    def load(self, cfg, source):
        raise NotImplementedError

    def _apply_policy_tree(self, tree):
        """Quantize-dequantize a pytree source through the backend's
        ``policy_table`` (no-op without one) — the pytree-load equivalent
        of serving the ``deepcabac-rd`` container's reconstruction."""
        if self.policy_table is None:
            return tree
        from ..compression.quantizers import is_float_dtype
        from ..compression.rd_search import PolicyQuantizer, resolve_policy
        table = resolve_policy(self.policy_table)
        quant = PolicyQuantizer(table=table)

        def visit(path, leaf):
            if not hasattr(leaf, "ndim") or not hasattr(leaf, "dtype"):
                return leaf
            name = _path_key(path)
            rule = table.rule_for(name)
            if (rule is None or rule.kind == "raw" or leaf.size == 0
                    or not is_float_dtype(leaf.dtype)):
                return leaf
            rec = quant.quantize(name, np.asarray(leaf))
            return jnp.array(np.asarray(rec.dequantize()),
                             dtype=leaf.dtype, copy=True)
        return jax.tree_util.tree_map_with_path(visit, tree)

    # -- delta ("P-frame") live patching ------------------------------------

    def _convert(self, name: str, rec, dtype):
        """One decoded record -> this backend's resident leaf."""
        return _to_array(rec, dtype)

    def _fold(self, name: str, rec, dtype):
        """The convert hook the streaming folds call: track the quantized
        levels (when enabled) before handing the record to _convert."""
        if self._levels is not None and isinstance(rec, QuantizedTensor):
            self._levels[name] = rec
        return self._convert(name, rec, dtype)

    def _check_mesh_tracking(self, source) -> None:
        if (self.track_levels and self.mesh is not None
                and _is_manifest_source(source)):
            raise RuntimeError(
                "track_levels=True needs host-visible quantized levels, "
                "but a manifest load with mesh= set assembles tensors "
                "straight onto the mesh without materializing them — "
                "load without mesh, or without track_levels")

    def apply_delta(self, cfg, source) -> dict:
        """Patch the resident weights from a delta (P-frame) checkpoint
        step without a full reload.

        ``source`` is the delta step directory (or its
        ``params.manifest.json``).  Residual (``ENC_CABAC_DELTA``) records
        are decoded against the tracked base levels and applied in integer
        level space — the updated tensors are bit-identical to a cold
        start of the new frame; full records in the same container (new /
        reshaped tensors) replace their leaf outright.  The tracked
        levels advance to the new frame, so chains of swaps keep working.

        Returns the flat ``{name: leaf}`` updates (already converted to
        this backend's representation); ``ServeSession.swap_weights``
        installs them between batched decode steps."""
        from ..checkpoint import delta as delta_mod
        from ..checkpoint import sharded
        if not self._levels:
            raise RuntimeError(
                f"{self.name} backend has no tracked base levels — build "
                f"it with track_levels=True and load the base frame from "
                f"a container blob or checkpoint manifest before applying "
                f"deltas")
        directory = sharded.manifest_dir(str(source))
        if not os.path.exists(os.path.join(directory,
                                           sharded.MANIFEST_NAME)):
            raise ValueError(
                f"{directory}: no {sharded.MANIFEST_NAME} — not a delta "
                f"(P-frame) step; full frames go through load()")
        manifest = sharded.load_manifest(str(source))
        if manifest.get("base") is None:
            raise ValueError(
                f"{directory}: not a delta (P-frame) manifest — full "
                f"frames go through load()")
        path = os.path.join(directory, delta_mod.DELTA_FILE)
        if not os.path.exists(path):
            raise delta_mod.DeltaBaseMissingError(
                f"{directory}: manifest present but {delta_mod.DELTA_FILE} "
                f"is missing")
        with open(path, "rb") as f:
            blob = f.read()
        specs = _template_specs(cfg)
        updates: dict = {}
        for hdr, payload in ContainerReader(blob):
            spec = specs.get(hdr.name)
            if spec is None:
                continue                   # not part of this model
            if tuple(hdr.shape) != tuple(spec.shape):
                raise ValueError(
                    f"{hdr.name}: delta record shape {tuple(hdr.shape)} "
                    f"!= model {tuple(spec.shape)}")
            if hdr.encoding == ENC_CABAC_DELTA:
                base = self._levels.get(hdr.name)
                if base is None:
                    raise RuntimeError(
                        f"{hdr.name}: residual record has no tracked base "
                        f"levels — the resident weights were not loaded "
                        f"from this chain's base frame")
                rec = decode_delta_record(hdr, payload, base.levels,
                                          dequantize=False, opts=self.decode)
            else:
                rec = decode_record(hdr, payload, dequantize=False,
                                    opts=self.decode)
            updates[hdr.name] = self._fold(hdr.name, rec, spec.dtype)
        return updates

    def load_entries(self, cfg, entries: dict) -> dict:
        """Build the serving tree from flat reconstructed quantized
        entries (``checkpoint.delta.restore_levels`` output: name ->
        ``QuantizedTensor`` | ``Q8Tensor`` | ndarray).

        This is the cold-start path for a delta-chain *tip*: no single
        container holds the frame — it only exists as keyframe + applied
        residuals — so the chain is host-reconstructed first and each
        entry folded through the same template-validated convert hook a
        blob load uses (tracked levels included)."""
        specs = _template_specs(cfg)
        tree: dict = {}
        seen: set = set()
        for name, rec in entries.items():
            spec = specs.get(name)
            if spec is None:
                continue                   # not part of this model
            if tuple(rec.shape) != tuple(spec.shape):
                raise ValueError(
                    f"{name}: entry shape {tuple(rec.shape)} != model "
                    f"{tuple(spec.shape)}")
            seen.add(name)
            _insert(tree, name, self._fold(name, rec, spec.dtype))
        missing = sorted(set(specs) - seen)
        if missing:
            raise KeyError(
                f"entries missing {len(missing)} model tensor(s), e.g. "
                f"{missing[:3]}")
        return tree

    def warm_from(self, cfg, base_backend: "WeightBackend", base_params,
                  steps) -> dict:
        """Warm-start a delta variant from an already-resident base.

        Instead of decoding the variant's whole chain from disk, copy
        the base backend's tracked levels (safe to share: residual
        decode builds *new* level arrays, it never mutates the base) and
        apply only the variant's own delta steps — ``steps`` is the
        base-exclusive suffix of its chain, in order.  ``base_params``
        leaves are shared, not copied; patched tensors replace their
        leaf in a fresh container structure.  Returns the variant's
        serving tree; this backend's levels advance to the variant
        frame."""
        if not self.track_levels:
            raise RuntimeError(
                f"{self.name}: warm_from needs track_levels=True on the "
                f"warming backend")
        if not base_backend._levels:
            raise RuntimeError(
                f"{self.name}: base backend has no tracked levels to warm "
                f"from — it must be built with track_levels=True and hold "
                f"a loaded frame")
        self._levels = dict(base_backend._levels)
        tree = jax.tree_util.tree_map(lambda leaf: leaf, base_params)
        for step in steps:
            for name, leaf in self.apply_delta(cfg, step).items():
                _insert(tree, name, leaf)
        return tree


# ---------------------------------------------------------------------------
# Registry (mirrors compression.registry)
# ---------------------------------------------------------------------------

_BACKENDS: dict = {}


def register_backend(name: str, factory) -> None:
    _BACKENDS[name] = factory


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **overrides) -> WeightBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown weight backend {name!r}; "
                       f"available: {available_backends()}")
    return _BACKENDS[name](**overrides)


def resolve_backend(backend) -> WeightBackend:
    """Accept a registry name or an already-built backend instance."""
    if isinstance(backend, WeightBackend):
        return backend
    return get_backend(backend)


# ---------------------------------------------------------------------------
# Streaming container fold
# ---------------------------------------------------------------------------

def _template_specs(cfg) -> dict:
    """Flat name -> ShapeDtypeStruct map from the abstract init (shapes
    and dtypes only — no weight memory is materialized)."""
    from ..models.transformer import init_params
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        # _path_key is the same join container record names were written
        # with (compression.tree.flatten_tree), so lookups can't drift
        out[_path_key(path)] = leaf
    return out


def _insert(tree: dict, name: str, leaf) -> None:
    parts = name.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = leaf


def _stream_tree(cfg, blob: bytes, convert,
                 decode: DecodeOptions | None = None) -> dict:
    """Fold the per-tensor decode iterator into a nested params dict.

    ``convert(name, record, dtype)`` maps one decoded record to its final
    (device) leaf; the host-side decoded array is dropped before the next
    record is decoded, so decoded-host peak stays one-tensor-bounded.

    Validated against the model template (same contract the old
    ``decompress(blob, like=template)`` path enforced): records the model
    doesn't expect are skipped, shape mismatches raise at load time, and
    a container missing template tensors raises instead of failing deep
    inside ``forward``.
    """
    specs = _template_specs(cfg)
    tree: dict = {}
    seen: set = set()
    for name, record in iter_decompress(blob, dequantize=False, opts=decode):
        spec = specs.get(name)
        if spec is None:
            continue                       # not part of this model
        shape = tuple(record.shape)
        if shape != tuple(spec.shape):
            raise ValueError(
                f"{name}: container shape {shape} != model "
                f"{tuple(spec.shape)}")
        seen.add(name)
        _insert(tree, name, convert(name, record, spec.dtype))
    missing = sorted(set(specs) - seen)
    if missing:
        raise KeyError(
            f"container missing {len(missing)} model tensor(s), e.g. "
            f"{missing[:3]}")
    return tree


def _is_manifest_source(source) -> bool:
    return isinstance(source, (str, os.PathLike))


def _manifest_tree(cfg, source, convert,
                   decode: DecodeOptions | None = None, mesh=None) -> dict:
    """Cold-start from a sharded checkpoint manifest.

    Same template-validation contract as :func:`_stream_tree`, but the
    source is a directory of per-shard DCBC files + manifest
    (``repro.checkpoint.sharded``).  Tensors are assembled one at a time
    (layer-bound decoded-host peak); with ``mesh``, entropy-coded tensors
    skip ``convert`` and arrive as mesh-sharded ``jax.Array``\\ s built
    from only the shards the mesh's local slices cover.
    """
    from ..checkpoint import sharded
    directory = sharded.manifest_dir(str(source))
    manifest = sharded.load_manifest(str(source))
    num_gr = manifest.get("num_gr")
    specs = _template_specs(cfg)
    tree: dict = {}
    seen: set = set()
    for name, tinfo in sorted(manifest["tensors"].items()):
        spec = specs.get(name)
        if spec is None:
            continue                       # not part of this model
        if tuple(tinfo["shape"]) != tuple(spec.shape):
            raise ValueError(
                f"{name}: manifest shape {tuple(tinfo['shape'])} != model "
                f"{tuple(spec.shape)}")
        seen.add(name)
        if mesh is not None and tinfo["encoding"] != "q8":
            leaf = sharded.restore_tensor_on_mesh(
                directory, name, tinfo, mesh, opts=decode, num_gr=num_gr,
                dtype=spec.dtype)
        else:
            rec = sharded.assemble_slice(
                directory, name, tinfo, opts=decode, num_gr=num_gr,
                dequantize=False)
            leaf = convert(name, rec, spec.dtype)
        _insert(tree, name, leaf)
    missing = sorted(set(specs) - seen)
    if missing:
        raise KeyError(
            f"manifest missing {len(missing)} model tensor(s), e.g. "
            f"{missing[:3]}")
    return tree


def _to_array(record, dtype):
    """Decoded record -> device array in the template dtype.

    ``copy=True`` forces a real device buffer (host->HBM on accelerators;
    on the CPU backend jax would otherwise alias the decoded numpy buffer,
    silently pinning every decoded tensor on the host heap and defeating
    the layer-bound streaming contract)."""
    arr = np.asarray(record.dequantize()
                     if hasattr(record, "dequantize") else record)
    return jnp.array(arr, dtype=dtype or arr.dtype, copy=True)


def _q8_leaf(record: Q8Tensor) -> dict:
    return {"q8": jnp.array(record.levels, copy=True),
            "q8s": jnp.array(record.scale, dtype=jnp.float32, copy=True)}


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

class Bf16Backend(WeightBackend):
    """Dequantize-on-load (the classic ServeEngine path): pytrees pass
    through untouched; blobs decode to full-precision leaves in the
    model's param dtype."""

    name = "bf16"

    def load(self, cfg, source):
        self._check_mesh_tracking(source)
        if _is_manifest_source(source):
            return _manifest_tree(cfg, source, self._fold,
                                  decode=self.decode, mesh=self.mesh)
        if isinstance(source, (bytes, bytearray, memoryview)):
            return _stream_tree(cfg, bytes(source), self._fold,
                                decode=self.decode)
        return self._apply_policy_tree(source)


class Q8Backend(WeightBackend):
    """In-memory fixed-point serving: matmul weights become
    ``{"q8","q8s"}`` leaves (per-out-channel int8 + Delta), which the
    model dequantizes in-core after int8 HBM reads: every attention /
    MLP / MoE projection routes through the fused ``dequant_matmul`` /
    ``dequant_matmul_grouped`` registry ops and the embed gather through
    ``embed_lookup_q8`` — see docs/serving_api.md "Compressed-resident
    serving"."""

    name = "q8"
    q8_resident = True

    def _convert(self, name, rec, dt):
        if isinstance(rec, Q8Tensor):
            return _q8_leaf(rec)
        arr = _to_array(rec, dt)
        if serve_q8_policy(name, arr):
            return quantize_leaf(arr)
        return arr

    def load(self, cfg, source):
        if _is_manifest_source(source):
            # host-side conversion: every decoded tensor becomes an
            # in-memory {"q8","q8s"} leaf, so the mesh-sharded fast path
            # doesn't apply here
            return _manifest_tree(cfg, source, self._fold,
                                  decode=self.decode)
        if isinstance(source, (bytes, bytearray, memoryview)):
            return _stream_tree(cfg, bytes(source), self._fold,
                                decode=self.decode)
        return quantize_tree_q8(self._apply_policy_tree(source))


class ContainerBackend(WeightBackend):
    """Serve straight from the DeepCABAC deployment artifact: stream the
    container record-by-record; ``serve-q8`` records stay int8 (decode-free
    fixed-point path), entropy-coded records dequantize to the param
    dtype.  Peak decoded host memory is layer-bound by construction."""

    name = "container"

    def _convert(self, name, rec, dt):
        if isinstance(rec, Q8Tensor):
            return _q8_leaf(rec)
        return _to_array(rec, dt)

    def load(self, cfg, source):
        self._check_mesh_tracking(source)
        if _is_manifest_source(source):
            return _manifest_tree(cfg, source, self._fold,
                                  decode=self.decode, mesh=self.mesh)
        if not isinstance(source, (bytes, bytearray, memoryview)):
            raise TypeError(
                "container backend loads DCBC blobs (bytes) or a sharded-"
                "checkpoint manifest path; got "
                f"{type(source).__name__} — use the 'bf16' or 'q8' backend "
                "for in-memory pytrees")
        return _stream_tree(cfg, bytes(source), self._fold,
                            decode=self.decode)


register_backend("bf16", Bf16Backend)
register_backend("q8", Q8Backend)
register_backend("container", ContainerBackend)


# ---------------------------------------------------------------------------
# Refcounted blob GC
# ---------------------------------------------------------------------------

class BlobGC:
    """Refcounted key lifetimes over a drop callback.

    Two serving-side stores share the same bug shape: a blob written for
    a consumer that later goes away (a parked KV slot whose request is
    cancelled, a content-addressed shard object whose last referencing
    model is evicted) leaks unless something counts the holders.  This
    helper owns the counting: ``hold(key)`` takes a reference,
    ``release(key)`` gives one back and invokes ``drop(key)`` exactly
    when the last holder leaves.  Unknown keys release as no-ops so
    idempotent cleanup paths stay simple."""

    def __init__(self, drop):
        self._drop = drop
        self._refs: dict[str, int] = {}

    def hold(self, key: str) -> int:
        self._refs[key] = self._refs.get(key, 0) + 1
        return self._refs[key]

    def release(self, key: str) -> bool:
        """Give back one reference; returns True when this release was
        the last one and the key's blob was dropped."""
        n = self._refs.get(key)
        if n is None:
            return False
        if n > 1:
            self._refs[key] = n - 1
            return False
        del self._refs[key]
        self._drop(key)
        return True

    def refs(self, key: str) -> int:
        return self._refs.get(key, 0)

    def live(self) -> list[str]:
        return sorted(self._refs)

    def clear(self) -> None:
        """Drop every held key (store teardown)."""
        for key in list(self._refs):
            del self._refs[key]
            self._drop(key)


# ---------------------------------------------------------------------------
# KV cold stores: where the paged cache's evicted pages live
# ---------------------------------------------------------------------------

class KVColdStore:
    """Host-side blob store for entropy-coded KV pages.

    The paged serving cache (``repro.serve.kv``) evicts cold pages as
    ``kv-q8-cabac`` containers keyed by an opaque string; this registry
    mirrors the weight-backend one so deployments can swap the eviction
    target (in-process host memory, a spill directory, ...) without
    touching the scheduler.  A store owns its blobs: ``close()`` releases
    everything it holds.
    """

    name = "base"

    def put(self, key: str, blob: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def drop(self, key: str) -> None:
        raise NotImplementedError

    def __contains__(self, key: str) -> bool:
        raise NotImplementedError

    def nbytes(self) -> int:
        """Total compressed bytes currently held (capacity accounting)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class HostKVStore(KVColdStore):
    """In-process host-RAM store (the default): a dict of blobs."""

    name = "host"

    def __init__(self):
        self._blobs: dict[str, bytes] = {}

    def put(self, key, blob):
        self._blobs[key] = bytes(blob)

    def get(self, key):
        return self._blobs[key]

    def drop(self, key):
        self._blobs.pop(key, None)

    def __contains__(self, key):
        return key in self._blobs

    def nbytes(self):
        return sum(len(b) for b in self._blobs.values())

    def close(self):
        self._blobs.clear()


class DirKVStore(KVColdStore):
    """Spill-to-directory store: one file per key under ``root`` (a
    private temp dir when unset, removed on ``close``)."""

    name = "dir"

    def __init__(self, root=None):
        import tempfile
        self._own = root is None
        self._root = root or tempfile.mkdtemp(prefix="repro-kv-")
        os.makedirs(self._root, exist_ok=True)
        self._sizes: dict[str, int] = {}

    def _path(self, key: str) -> str:
        import hashlib
        return os.path.join(
            self._root, hashlib.sha256(key.encode()).hexdigest() + ".dcbc")

    def put(self, key, blob):
        with open(self._path(key), "wb") as f:
            f.write(blob)
        self._sizes[key] = len(blob)

    def get(self, key):
        with open(self._path(key), "rb") as f:
            return f.read()

    def drop(self, key):
        if self._sizes.pop(key, None) is not None:
            try:
                os.remove(self._path(key))
            except OSError:
                pass

    def __contains__(self, key):
        return key in self._sizes

    def nbytes(self):
        return sum(self._sizes.values())

    def close(self):
        for key in list(self._sizes):
            self.drop(key)
        if self._own:
            import shutil
            shutil.rmtree(self._root, ignore_errors=True)


_KV_STORES: dict = {}


def register_kv_store(name: str, factory) -> None:
    _KV_STORES[name] = factory


def available_kv_stores() -> list[str]:
    return sorted(_KV_STORES)


def get_kv_store(name: str, **overrides) -> KVColdStore:
    if name not in _KV_STORES:
        raise KeyError(f"unknown KV cold store {name!r}; "
                       f"available: {available_kv_stores()}")
    return _KV_STORES[name](**overrides)


def resolve_kv_store(store) -> KVColdStore:
    """Accept a registry name or an already-built store instance."""
    if isinstance(store, KVColdStore):
        return store
    return get_kv_store(store)


register_kv_store("host", HostKVStore)
register_kv_store("dir", DirKVStore)
