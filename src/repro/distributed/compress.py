"""Cross-pod gradient/update compression with error feedback.

The paper names distributed training as the setting where weight-stream
compression matters (§I, §VI); this module applies its quantize-then-code
recipe to the *gradient* stream that crosses the inter-pod boundary — the
scarcest bandwidth in a multi-pod deployment.

Two layers:

1. :func:`ef_compress_update` — error-feedback int8 quantization of the
   update stream (EF-SGD style): runs inside the pjit train step, keeps a
   persistent per-parameter error accumulator, and is exact-in-expectation.
   Wire bytes for the cross-pod hop are accounted with the CABAC rate model
   (the codes are what DeepCABAC would entropy-code on the wire; see
   benchmarks/comm_compression.py).

2. :func:`cross_pod_psum_compressed` — the explicit collective mechanics:
   inside ``jax.shard_map`` each pod quantizes its local contribution to
   int8 codes + blockwise scales, all-gathers the (4x smaller than f32)
   payload over the pod axis, and dequant-sums locally.  This is the
   building block a production hierarchical reduce would use.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

try:                               # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:             # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from ..compression.q8 import q8_decode, q8_encode


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    ef_decay: float = 1.0          # error-feedback memory (1.0 = full EF)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_update(grads, ef, cfg: CompressionConfig):
    """Returns (compressed grads, new error-feedback state)."""
    if not cfg.enabled:
        return grads, ef

    def one(g, e):
        t = g.astype(jnp.float32) + cfg.ef_decay * e
        codes, scale = q8_encode(t)
        deq = q8_decode(codes, scale)
        return deq.astype(g.dtype), t - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def cross_pod_psum_compressed(x: jnp.ndarray, mesh,
                              pod_axis: str = "pod") -> jnp.ndarray:
    """Quantized hierarchical sum over the pod axis (see module docstring).

    Shape contract (explicit; validated):

    * ``x`` has a **leading pod axis** of global size ``mesh.shape[pod_axis]``
      sharded over ``pod_axis`` — slice ``x[i]`` is pod *i*'s partial sum,
      so each pod's local shard is ``(1, ...)``.
    * The result has the **same global shape**: every pod's slice holds the
      dequantized cross-pod sum (replicated content, pod-sharded layout).

    Payload on the inter-pod wire: int8 codes + f32 scales per 128-block =
    ~1.03 B/param vs 4 B/param f32.
    """
    n_pods = mesh.shape[pod_axis]
    if x.ndim < 1 or x.shape[0] != n_pods:
        raise ValueError(
            f"cross_pod_psum_compressed: leading axis of x {x.shape} must "
            f"be the pod axis (size {n_pods}); got "
            f"{x.shape[0] if x.ndim else 'scalar'}")
    in_spec = jax.sharding.PartitionSpec(pod_axis)

    @partial(_shard_map, mesh=mesh,
             in_specs=(in_spec,), out_specs=in_spec)
    def inner(xp):
        # xp (1, ...): this pod's contribution; drop the size-1 pod slice
        # before encoding so code/scale shapes are position-independent
        part = xp[0].astype(jnp.float32)
        codes, scale = q8_encode(part)
        codes_all = jax.lax.all_gather(codes, pod_axis)    # int8 on the wire
        scale_all = jax.lax.all_gather(scale, pod_axis)
        deq = jax.vmap(q8_decode)(codes_all, scale_all)    # (n_pods, ...)
        return jnp.sum(deq, axis=0)[None]                  # restore pod axis
    return inner(x)


def code_entropy_bits_per_param(codes: jnp.ndarray) -> float:
    """EPMD entropy of int8 codes — the wire rate a CABAC pass achieves
    (upper bound; context adaptation goes below, see core benchmarks)."""
    import numpy as np
    c = np.asarray(codes).ravel()
    _, counts = np.unique(c, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
