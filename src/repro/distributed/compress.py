"""Cross-pod gradient/update compression with error feedback.

The paper names distributed training as the setting where weight-stream
compression matters (§I, §VI); this module applies its quantize-then-code
recipe to the *gradient* stream that crosses the inter-pod boundary — the
scarcest bandwidth in a multi-pod deployment.

Two layers:

1. :func:`ef_compress_update` — error-feedback int8 quantization of the
   update stream (EF-SGD style): runs inside the pjit train step, keeps a
   persistent per-parameter error accumulator, and is exact-in-expectation.
   Wire bytes for the cross-pod hop are accounted with the CABAC rate model
   (the codes are what DeepCABAC would entropy-code on the wire; see
   benchmarks/comm_compression.py).

2. :func:`cross_pod_psum_compressed` — the explicit collective mechanics:
   inside ``jax.shard_map`` each pod quantizes its local contribution to
   int8 codes + blockwise scales, all-gathers the (4x smaller than f32)
   payload over the pod axis, and dequant-sums locally.  This is the
   building block a production hierarchical reduce would use.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..compression.q8 import q8_decode, q8_encode


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    ef_decay: float = 1.0          # error-feedback memory (1.0 = full EF)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_update(grads, ef, cfg: CompressionConfig):
    """Returns (compressed grads, new error-feedback state)."""
    if not cfg.enabled:
        return grads, ef

    def one(g, e):
        t = g.astype(jnp.float32) + cfg.ef_decay * e
        codes, scale = q8_encode(t)
        deq = q8_decode(codes, scale)
        return deq.astype(g.dtype), t - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def cross_pod_psum_compressed(x: jnp.ndarray, mesh,
                              pod_axis: str = "pod") -> jnp.ndarray:
    """Quantized hierarchical sum over the pod axis (see module docstring).

    x is expected sharded/replicated such that the pod axis carries partial
    sums (one contribution per pod).  Payload on the inter-pod wire: int8
    codes + f32 scales per 128-block = ~1.03 B/param vs 4 B/param f32.
    """
    in_spec = jax.sharding.PartitionSpec(pod_axis)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(in_spec,), out_specs=in_spec)
    def inner(xp):
        # xp: this pod's contribution (leading pod dim of size 1 locally)
        codes, scale = q8_encode(xp.astype(jnp.float32))
        codes_all = jax.lax.all_gather(codes, pod_axis)    # int8 on the wire
        scale_all = jax.lax.all_gather(scale, pod_axis)
        deq = jax.vmap(q8_decode)(codes_all, scale_all)
        return jnp.sum(deq, axis=0, keepdims=False)[None] \
            if xp.ndim == codes_all.ndim - 1 else jnp.sum(deq, axis=0)

    return inner(x)


def code_entropy_bits_per_param(codes: jnp.ndarray) -> float:
    """EPMD entropy of int8 codes — the wire rate a CABAC pass achieves
    (upper bound; context adaptation goes below, see core benchmarks)."""
    import numpy as np
    c = np.asarray(codes).ravel()
    _, counts = np.unique(c, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
