from .sharding import (DEFAULT_RULES, activation_sharding,  # noqa: F401
                       build_param_specs, constrain, spec_for)
