"""Logical-axis sharding rules -> PartitionSpecs (DP / FSDP / TP / EP / SP).

Parameters are matched by tree path against a rule table of *logical* axes;
logical axes resolve to mesh axes through a rules dict.  Every resolved axis
is validated for divisibility against the mesh — a dim that doesn't divide
falls back to replication (e.g. GQA kv-heads with kv < |model|), which keeps
one rule table valid across all 10 architectures and any mesh shape.

Activation constraints use a trace-time context (``activation_sharding``)
so model code stays mesh-agnostic: ``constrain(x, "batch", None, "tp")``
is a no-op outside the context.
"""

from __future__ import annotations

import re
import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),      # DP over pods x data
    "fsdp": "data",                # parameter sharding (ZeRO-3 style)
    "fsdp_pod": ("pod", "data"),   # wider FSDP for the largest models
    "tp": "model",                 # megatron-style tensor parallel
    "expert": "model",             # EP: expert banks
    "vocab": "model",              # embedding/logits vocab dim
    "kv_heads": "model",           # replicated automatically if kv < |model|
    "heads": "model",
    "seq": None,                   # set to "data" to enable SP
    "kv_seq": "model",             # decode KV-cache sequence sharding: no
                                   # assigned arch has kv_heads % 16 == 0, so
                                   # the cache uses the model axis via seq
    "moe_group": ("pod", "data"),  # MoE dispatch groups (== batch rows)
}

# Serving: no optimizer state, so parameters are TP-sharded and *replicated*
# over data (FSDP weight all-gathers would move the whole model per decoded
# token).  MoE expert banks instead span (data x model) = 256-way EP — the
# deepseek-v3 routed experts (1.3 TB bf16) cannot replicate over data.
SERVE_RULES: dict[str, object] = {
    **DEFAULT_RULES,
    "fsdp": None,
    "fsdp_pod": None,
    "expert": ("data", "model"),
    "moe_group": None,             # tokens -> expert owners is the all-to-all
}

# Prefill: like serving (no optimizer, no FSDP) but token counts are large,
# so MoE dispatch groups shard with the batch and experts stay on "model"
# (group-local dispatch, no cross-batch exchange).  deepseek-v3 is the
# exception (launch/dryrun.py): its 1.3 TB expert bank does not fit 16-way,
# so it keeps the SERVE_RULES 256-way EP and pays the dispatch all-to-all.
PREFILL_RULES: dict[str, object] = {
    **SERVE_RULES,
    "expert": "model",
    "moe_group": ("pod", "data"),
}


def _mesh_axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([_mesh_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis] if axis in mesh.shape else 1


def _present(mesh, axis):
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 1 pod)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.shape)
        return kept if len(kept) > 1 else (kept[0] if kept else None)
    return axis if axis in mesh.shape else None


def spec_for(shape, logical_axes, mesh, rules=None) -> P:
    """Resolve logical axes for ``shape`` with divisibility fallback.

    Tuple axes degrade gracefully: ("data","model") on a dim of 64 with a
    16x16 mesh falls back to ("model",) (64 % 256 != 0 but 64 % 16 == 0)
    before replicating — e.g. deepseek-moe's 64 experts under 256-way EP.
    """
    rules = rules or DEFAULT_RULES
    out = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            out.append(None)
            continue
        axis = _present(mesh, rules.get(name))
        candidates = [axis]
        if isinstance(axis, tuple):
            candidates += [axis[i:] if len(axis[i:]) > 1 else axis[-1]
                           for i in range(1, len(axis))]
        chosen = None
        for cand in candidates:
            size = _mesh_axis_size(mesh, cand)
            if cand is not None and size > 1 and dim % size == 0:
                chosen = cand
                break
        out.append(chosen)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules (regex on '/'-joined tree path, innermost dims)
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("vocab", "fsdp")),
    (r"head$", ("fsdp", "vocab")),
    # attention (GQA)
    (r"attn/wq$", ("fsdp", "tp")),
    (r"attn/wk$", ("fsdp", "tp")),
    (r"attn/wv$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"attn/b[qkv]$", ("tp",)),
    (r"attn/[qk]_norm$", (None,)),
    # attention (MLA)
    (r"attn/w_dq$", ("fsdp", None)),
    (r"attn/w_uq$", (None, "tp")),
    (r"attn/w_dkv$", ("fsdp", None)),
    (r"attn/w_uk$", (None, "tp")),
    (r"attn/w_uv$", (None, "tp")),
    (r"attn/w_kr$", ("fsdp", None)),
    (r"attn/(q_norm|kv_norm)$", (None,)),
    # dense mlp
    (r"mlp/w_gate$", ("fsdp", "tp")),
    (r"mlp/w_up$", ("fsdp", "tp")),
    (r"mlp/w_down$", ("tp", "fsdp")),
    # moe
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_gate$", ("expert", "fsdp", None)),
    (r"moe/w_up$", ("expert", "fsdp", None)),
    (r"moe/w_down$", ("expert", None, "fsdp")),
    (r"moe/sh_gate$", ("fsdp", "tp")),
    (r"moe/sh_up$", ("fsdp", "tp")),
    (r"moe/sh_down$", ("tp", "fsdp")),
    # ssm
    (r"mixer/w_z$", ("fsdp", "tp")),
    (r"mixer/w_x$", ("fsdp", "tp")),
    (r"mixer/w_b$", ("fsdp", "tp")),
    (r"mixer/w_c$", ("fsdp", "tp")),
    (r"mixer/w_dt$", ("fsdp", "tp")),
    (r"mixer/conv_._w$", ("tp", None)),
    (r"mixer/conv_._b$", ("tp",)),
    (r"mixer/(a_log|dt_bias|d_skip)$", ("tp",)),
    (r"mixer/norm$", ("tp",)),
    (r"mixer/out_proj$", ("tp", "fsdp")),
    # norms / everything 1-D
    (r"(norm|scale|bias)$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_MOMENT_SUFFIXES = ("/m_q", "/v_q", "/m_s", "/v_s", "/m", "/v")


def logical_axes_for_path(path_str: str, ndim: int) -> tuple:
    # optimizer moments / int8-serving codes live under the param path + a
    # suffix and inherit the param's sharding; q8s / blocked scales keep
    # the trailing axes of the rule (divisibility fallback covers the rest)
    tail_axes = False
    if path_str.endswith("/q8s"):
        path_str = path_str[:-4]
        tail_axes = True
    elif path_str.endswith("/q8"):
        path_str = path_str[:-3]
    else:
        for suf in _MOMENT_SUFFIXES:
            if path_str.endswith(suf):
                path_str = path_str[: -len(suf)]
                break
    for pat, axes in PARAM_RULES:
        if re.search(pat, path_str):
            if tail_axes:              # per-out-channel scale vector(s)
                axes = tuple(axes)[-1:]
            if len(axes) < ndim:       # stacked layer (and scale) lead dims
                return (None,) * (ndim - len(axes)) + tuple(axes)
            return tuple(axes[:ndim])
    return (None,) * ndim


def build_param_specs(params, mesh, rules=None):
    """Pytree of PartitionSpec matching ``params`` (works for opt moments too
    since their tree paths embed the same leaf names)."""
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        axes = logical_axes_for_path(ps, np.ndim(leaf))
        return spec_for(np.shape(leaf), axes, mesh, rules)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def named_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation constraints (trace-time context)
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextmanager
def activation_sharding(mesh, rules=None):
    prev = getattr(_CTX, "active", None)
    _CTX.active = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.active = prev


def constrain(x, *logical_axes):
    active = getattr(_CTX, "active", None)
    if active is None:
        return x
    mesh, rules = active
    spec = spec_for(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
