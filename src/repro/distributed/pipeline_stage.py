"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Optional PP feature for the pod axis: layers split into `S = |axis|` stages
with stage parameters sharded on the axis; microbatches stream through the
classic GPipe schedule (stage s runs microbatch m at tick t = s + m, bubble
fraction (S-1)/(M+S-1)).  Activations hop stages with a single
`lax.ppermute` per tick — on hardware that is the only inter-pod traffic,
which is why PP is the axis of choice when the cross-pod links are the
scarce resource (DESIGN.md §6).

This is jax-native (no torch.distributed emulation): the schedule is an
unrolled loop inside one shard_map, so XLA overlaps the permute with the
next tick's compute.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

try:                               # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:             # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def gpipe_apply(stage_fn: Callable, stage_params, x_mb: jnp.ndarray,
                mesh, axis: str = "pod") -> jnp.ndarray:
    """Run `S` parameter stages over `M` microbatches.

    stage_fn(params, x) -> y with x/y of identical shape (a layer block).
    stage_params: pytree with a leading stage dim of size S = mesh.shape[axis]
    (sharded on `axis`).  x_mb: (M, *batch_shape) microbatched input.
    Returns (M, *batch_shape) outputs (after all S stages, in order).
    """
    s = mesh.shape[axis]
    m = x_mb.shape[0]
    ticks = m + s - 1

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)

    @partial(_shard_map, mesh=mesh,
             in_specs=(param_specs, P()), out_specs=P(axis))
    def run(params_local, x_all):
        sid = lax.axis_index(axis)
        local = jax.tree.map(lambda p: p[0], params_local)
        carry = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros((1, *x_all.shape), x_all.dtype)
        perm = [(i, (i + 1) % s) for i in range(s)]
        for t in range(ticks):
            feed_idx = min(max(t, 0), m - 1)
            inp = jnp.where(sid == 0, x_all[feed_idx], carry)
            out = stage_fn(local, inp)
            # the last stage finishes microbatch (t - (S-1)) at tick t
            m_idx = t - (s - 1)
            if 0 <= m_idx < m:
                is_last = sid == (s - 1)
                upd = jnp.where(is_last, out, outputs[0, m_idx])
                outputs = outputs.at[0, m_idx].set(upd)
            carry = lax.ppermute(out, axis, perm)
        return outputs

    stacked = run(stage_params, x_mb)     # (S, M, *batch)
    return stacked[-1]


def split_stages(stacked_layers, n_stages: int):
    """Reshape (L, ...) stacked layer params into (S, L/S, ...) stages."""
    def r(a):
        return a.reshape(n_stages, a.shape[0] // n_stages, *a.shape[1:])
    return jax.tree.map(r, stacked_layers)
