"""Pure-JAX attention impls: online-softmax scan ("scan") and naive ("ref").

The scan path never materializes the full (Sq, Skv) score matrix: it
lax.scan's over KV blocks with an online-softmax carry (running max, running
denominator, accumulator) — the standard memory-safe formulation for 32k+
prefill.  GQA expansion happens inside the einsum (q reshaped to
(B, S, G, rep, D)), so K/V are never repeated in memory.  Both paths honor
ragged per-row ``kv_len`` masks (continuous-batching decode), which the
Pallas kernel does not — the registry records that constraint.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def online_softmax_scan(q5, k, v, qpos, kv_block: int,
                        kv_len: jnp.ndarray | None) -> jnp.ndarray:
    """q5 (B,Sq,G,R,D); k,v (B,Skv,G,D); qpos (B,Sq) global positions.
    Returns (B,Sq,G,R,D)."""
    b, sq, g, r, d = q5.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    nb = -(-skv // kv_block)
    pad = nb * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, kv_block, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, g, dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, i = blk
        kpos = i * kv_block + jnp.arange(kv_block)
        # keep K/V in their storage dtype; accumulate on the MXU in f32
        # (an explicit astype would materialize f32 copies of the whole
        # K/V stream in HBM — observed +8x on the decode memory term)
        s = jnp.einsum("bsgrd,btgd->bgrst", q5, k_i,
                       preferred_element_type=jnp.float32) * scale
        mask = kpos[None, None, None, None, :] <= \
            qpos[:, None, None, :, None]
        if kv_len is not None:
            mask &= kpos[None, None, None, None, :] < \
                kv_len[:, None, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrst,btgd->bgrsd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, g, r, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, g, r, sq), jnp.float32)
    a0 = jnp.zeros((b, g, r, sq, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q5.dtype)  # (B,Sq,G,R,D)


def naive_attend(q5, k, v, qpos, kv_len) -> jnp.ndarray:
    b, sq, g, r, d = q5.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # K/V stay in storage dtype — f32 accumulation happens on the MXU
    s = jnp.einsum("bsgrd,btgd->bgrst", q5, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(skv)
    mask = kpos[None, None, None, None, :] <= qpos[:, None, None, :, None]
    if kv_len is not None:
        mask &= kpos[None, None, None, None, :] < \
            kv_len[:, None, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q5.dtype)
