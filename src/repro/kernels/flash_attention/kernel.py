"""Pallas TPU flash attention (causal, online softmax).

This is the kernel the §Perf analysis calls for on the training/prefill
memory term: the pure-JAX scan formulation materializes every
(bq, bk) probability block in HBM, while this kernel keeps the score block,
the running max/denominator and the output accumulator in VMEM.

Tiling: grid (BH, Sq/BQ, Skv/BK) with the KV index innermost; the f32
accumulator + softmax stats live in VMEM scratch that persists across the
KV loop (standard revisiting pattern).  Causally-dead KV blocks are skipped
with pl.when.  Block shapes are MXU-aligned (128 multiples).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, bq, bk, causal, offs, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal skip: the first key of this block beyond the last query's reach
    live = (not causal) or (ki * bk <= qi * bq + bq - 1 + offs)

    @pl.when(live)
    def _compute():
        q = q_ref[0]                      # (bq, d)
        k = k_ref[0]                      # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows + offs, s, NEG_INF)
        m_prev = m_scr[...][:, :1]                         # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_prev = l_scr[...][:, :1]
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jnp.ndarray:
    """q (BH, Sq, D); k, v (BH, Skv, D).  Sq % bq == Skv % bk == 0."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    grid = (bh, sq // bq, skv // bk)
    scale = 1.0 / (d ** 0.5)
    offs = skv - sq                      # causal alignment (q at the end)
    kernel = functools.partial(
        _flash_kernel, scale=scale, bq=bq, bk=bk, causal=causal, offs=offs,
        n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (col 0)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
