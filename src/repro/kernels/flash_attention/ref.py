"""Pure-jnp oracle for the flash-attention kernel (causal MHA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q (BH, Sq, D); k, v (BH, Skv, D) -> (BH, Sq, D), f32 accumulation.
    Causal alignment: query i attends keys j <= i + (Skv - Sq)."""
    sq, skv = q.shape[1], k.shape[1]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        offs = skv - sq
        mask = (jnp.arange(skv)[None, :]
                <= jnp.arange(sq)[:, None] + offs)
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
