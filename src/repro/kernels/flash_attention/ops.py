"""jit'd wrapper + registry spec: GQA-aware attention over (B,S,H,D).

The registered op ``flash_attention`` covers every attention impl the
model can run:

    ``pallas``     the VMEM-resident TPU kernel (kernel.py)
    ``interpret``  same kernel body, interpreter mode (CPU validation)
    ``scan``       pure-JAX online-softmax scan (compiles everywhere,
                   handles ragged ``kv_len`` and decode)
    ``ref``        naive reference (full score matrix)

The pallas kernel cannot mask ragged per-row ``kv_len`` and requires
``d == dv`` and tile-divisible sequence lengths — those constraints are
declared on the impl, so dispatch falls back to ``scan`` *visibly*
(``registry.dispatch_report()``; raising under ``KernelPolicy(strict=True)``
when pallas was pinned) instead of downgrading silently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import Impl, OpSpec, register_op
from ..tune import pow2_bucket
from .kernel import DEFAULT_BK, DEFAULT_BQ, flash_attention_pallas
from .ref import flash_attention_ref
from .scan import naive_attend, online_softmax_scan


def pick_block(pref: int, size: int, floor: int = 8) -> int | None:
    """Largest power-of-two tile <= pref that divides ``size`` (None when
    no power of two >= ``floor`` divides it)."""
    t = 1 << max(pref, 1).bit_length() >> 1          # round pref down to pow2
    t = min(t, 1 << (max(size, 1).bit_length() - 1))
    while t >= floor:
        if size % t == 0:
            return t
        t //= 2
    return None


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                              "interpret", "use_ref"))
def _flash(qf, kf, vf, *, causal, bq, bk, interpret, use_ref):
    if use_ref:
        return flash_attention_ref(qf, kf, vf, causal=causal)
    return flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False,
                    use_ref: bool = False) -> jnp.ndarray:
    """q (B, Sq, H, D); k, v (B, Skv, G, D) with G | H -> (B, Sq, H, D).

    KV heads are expanded logically (repeat) before the kernel.  Tile
    sizes are clamped to the largest power-of-two divisor of each sequence
    length; sequence lengths with no such divisor >= 8 raise (the registry
    constraint routes those shapes to the scan impl instead)."""
    b, sq, h, d = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        b * h, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        b * h, skv, d)
    bq_eff = pick_block(min(bq, sq), sq)
    bk_eff = pick_block(min(bk, skv), skv)
    if not use_ref and (bq_eff is None or bk_eff is None):
        raise ValueError(
            f"flash_attention: no power-of-two tile >= 8 divides "
            f"sq={sq} / skv={skv}; use the scan impl for these shapes")
    out = _flash(qf, kf, vf, causal=causal, bq=bq_eff or 8, bk=bk_eff or 8,
                 interpret=interpret, use_ref=use_ref)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Registry spec.  Op signature (the model-level contract):
#     (q (B,Sq,H,D), k (B,Skv,G,D), v (B,Skv,G,DV), qpos (B,Sq),
#      *, kv_len=None, kv_block=1024)
# ---------------------------------------------------------------------------

def _qpos_canonical(qpos, sq: int, skv: int) -> bool | None:
    """The pallas kernel hard-codes causal alignment as
    qpos == arange(sq) + (skv - sq).  Returns True/False for concrete
    position arrays, None (unknown, assumed canonical) for tracers — the
    model's jitted forward derives positions from arange, so traced
    positions are canonical by construction for prefill/train shapes."""
    if qpos is None:
        return True
    if isinstance(qpos, jax.core.Tracer):
        return None
    want = np.arange(sq) + (skv - sq)
    return bool(np.all(np.asarray(qpos) == want[None, :]))


def _shape_info(q, k, v, qpos=None, *, kv_len=None, kv_block=1024) -> dict:
    b, sq, h, d = q.shape
    skv = k.shape[1]
    return {"b": b, "sq": sq, "skv": skv, "h": h, "g": k.shape[2],
            "d": d, "dv": v.shape[-1], "ragged": kv_len is not None,
            "qpos_canonical": _qpos_canonical(qpos, sq, skv)}


def _bucket(s: dict) -> str:
    return (f"bh{pow2_bucket(s['b'] * s['h'])}_sq{pow2_bucket(s['sq'])}"
            f"_skv{pow2_bucket(s['skv'])}_d{s['d']}")


def _pallas_constraint(s: dict) -> str | None:
    if s["sq"] <= 1:
        return "decode (Sq == 1): a single-row query tile underfills the MXU"
    if s["ragged"]:
        return "ragged kv_len masking is not implemented in the kernel"
    if s["d"] != s["dv"]:
        return f"d != dv ({s['d']} != {s['dv']})"
    if s["qpos_canonical"] is False:
        return ("qpos is not the canonical right-aligned arange the "
                "kernel's causal mask hard-codes")
    if pick_block(DEFAULT_BQ, s["sq"]) is None:
        return f"sq={s['sq']} has no power-of-two tile >= 8"
    if pick_block(DEFAULT_BK, s["skv"]) is None:
        return f"skv={s['skv']} has no power-of-two tile >= 8"
    return None


def _tile_ok(s: dict, t: dict) -> bool:
    return (t["bq"] <= s["sq"] and s["sq"] % t["bq"] == 0
            and t["bk"] <= s["skv"] and s["skv"] % t["bk"] == 0)


def _default_tiles(s: dict) -> dict:
    return {"bq": pick_block(DEFAULT_BQ, s["sq"]) or DEFAULT_BQ,
            "bk": pick_block(DEFAULT_BK, s["skv"]) or DEFAULT_BK}


def _as_q5(q, k):
    b, sq, h, d = q.shape
    g = k.shape[2]
    return q.reshape(b, sq, g, h // g, d)


def _run_pallas(q, k, v, qpos, *, kv_len=None, kv_block=1024,
                bq=DEFAULT_BQ, bk=DEFAULT_BK):
    del qpos, kv_len, kv_block
    return flash_attention(q, k, v, causal=True, bq=bq, bk=bk)


def _run_interpret(q, k, v, qpos, *, kv_len=None, kv_block=1024,
                   bq=DEFAULT_BQ, bk=DEFAULT_BK):
    del qpos, kv_len, kv_block
    return flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                           interpret=True)


def _run_scan(q, k, v, qpos, *, kv_len=None, kv_block=1024):
    b, sq, h, _ = q.shape
    q5 = _as_q5(q, k)
    if sq > 1:
        out = online_softmax_scan(q5, k, v, qpos, kv_block, kv_len)
    else:                          # decode: one query row, scan degenerates
        out = naive_attend(q5, k, v, qpos, kv_len)
    return out.reshape(b, sq, h, v.shape[-1])


def _run_ref(q, k, v, qpos, *, kv_len=None, kv_block=1024):
    del kv_block
    b, sq, h, _ = q.shape
    out = naive_attend(_as_q5(q, k), k, v, qpos, kv_len)
    return out.reshape(b, sq, h, v.shape[-1])


def _example_inputs(shape):
    b, sq, skv, h, g, d = shape
    rng = np.random.default_rng(b * 13 + sq + skv + h)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, g, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, g, d)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(sq) + (skv - sq), (b, sq))
    return (q, k, v, qpos), {}


@register_op
def _flash_attention_spec() -> OpSpec:
    return OpSpec(
        name="flash_attention",
        impls={
            "pallas": Impl("pallas", _run_pallas, platforms=("tpu",),
                           constraint=_pallas_constraint),
            "interpret": Impl("interpret", _run_interpret,
                              constraint=_pallas_constraint),
            "scan": Impl("scan", _run_scan, uses_tiles=False),
            "ref": Impl("ref", _run_ref, uses_tiles=False),
        },
        defaults={"tpu": "pallas", "*": "scan"},
        # decode is *designed* to take the kv_len-aware scan/naive path —
        # route it there instead of reporting a constraint fallback
        route=lambda s, platform: "scan" if s["sq"] <= 1 else None,
        fallbacks=("scan", "ref"),
        tile_space={"bq": (64, 128, 256, 512),
                    "bk": (128, 256, 512, 1024)},
        default_tiles=_default_tiles,
        tile_ok=_tile_ok,
        shape_info=_shape_info,
        bucket=_bucket,
        example_inputs=_example_inputs,
        oracle=flash_attention_ref,
        tune_impls={"tpu": "pallas", "*": "interpret"},
    )
