"""jit'd wrapper: GQA-aware flash attention over (B, S, H, D) layouts."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BK, DEFAULT_BQ, flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                              "interpret", "use_ref"))
def _flash(qf, kf, vf, *, causal, bq, bk, interpret, use_ref):
    if use_ref:
        return flash_attention_ref(qf, kf, vf, causal=causal)
    return flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False,
                    use_ref: bool = False) -> jnp.ndarray:
    """q (B, Sq, H, D); k, v (B, Skv, G, D) with G | H -> (B, Sq, H, D).

    KV heads are expanded logically (repeat) before the kernel; sequence
    lengths must be multiples of the block sizes (the model pads its own
    sequences; pick bq/bk accordingly for odd shapes or use use_ref)."""
    b, sq, h, d = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        b * h, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        b * h, skv, d)
    bq_eff = min(bq, sq)
    bk_eff = min(bk, skv)
    out = _flash(qf, kf, vf, causal=causal, bq=bq_eff, bk=bk_eff,
                 interpret=interpret, use_ref=use_ref)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
