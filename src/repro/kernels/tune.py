"""Kernel autotuner with a persistent JSON tuning cache.

:func:`autotune` sweeps an op's tile-parameter search space over a list of
shapes (decode ``m = B`` rows, prefill, train), times each feasible config
on the current backend, and persists the winners keyed by
``(op, platform, shape-bucket)``.  Registry dispatch
(:meth:`registry.BoundOp.plan`) consults the cache at trace time, so a
tuned session picks the winning tiles with no per-call cost.

Cache location: ``$REPRO_KERNEL_TUNE_CACHE`` if set, else
``~/.cache/repro/kernel_tune.json``.  Format (version 1)::

    {"version": 1,
     "entries": {"dequant_matmul/cpu/m8_k512_n512":
                     {"tiles": {"bm": 8, "bn": 256, "bk": 512},
                      "time_us": 123.4, "shape": [4, 512, 512]}}}

Shape buckets round the data-dependent axes (rows, sequence lengths) to
the next power of two so a cache tuned at batch 8 serves batch 5..8.
"""

from __future__ import annotations

import itertools
import json
import os
import time
from pathlib import Path

import jax

ENV_VAR = "REPRO_KERNEL_TUNE_CACHE"
CACHE_VERSION = 1


def default_cache_path() -> Path:
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "kernel_tune.json"


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (shape-bucket rounding)."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


class TuningCache:
    """Persisted winners of past autotune sweeps."""

    def __init__(self, path: str | Path | None = None):
        self.path = Path(path) if path is not None else default_cache_path()
        self.entries: dict[str, dict] = {}
        self._load()

    def _load(self) -> None:
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        if raw.get("version") == CACHE_VERSION:
            self.entries = dict(raw.get("entries", {}))

    @staticmethod
    def key(op: str, platform: str, bucket: str) -> str:
        return f"{op}/{platform}/{bucket}"

    def lookup(self, op: str, platform: str, bucket: str) -> dict | None:
        entry = self.entries.get(self.key(op, platform, bucket))
        return dict(entry["tiles"]) if entry else None

    def store(self, op: str, platform: str, bucket: str, tiles: dict,
              time_us: float, shape=None) -> None:
        self.entries[self.key(op, platform, bucket)] = {
            "tiles": dict(tiles), "time_us": round(float(time_us), 3),
            "shape": list(shape) if shape is not None else None}

    def save(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"version": CACHE_VERSION, "entries": self.entries},
            indent=1, sort_keys=True))
        tmp.replace(self.path)


_cache: TuningCache | None = None


def get_cache() -> TuningCache:
    """Process-wide cache singleton; reloads if the env path changed."""
    global _cache
    path = default_cache_path()
    if _cache is None or _cache.path != path:
        _cache = TuningCache(path)
    return _cache


def invalidate_cache() -> None:
    global _cache
    _cache = None


def lookup(op: str, platform: str, bucket: str) -> dict | None:
    return get_cache().lookup(op, platform, bucket)


# ---------------------------------------------------------------------------
# Autotune
# ---------------------------------------------------------------------------

def tile_candidates(op_spec, shapes: dict) -> list[dict]:
    """Cartesian product of the op's tile space, filtered by ``tile_ok``."""
    keys = list(op_spec.tile_space)
    out = []
    for vals in itertools.product(*(op_spec.tile_space[k] for k in keys)):
        tiles = dict(zip(keys, vals))
        if op_spec.tile_ok is None or op_spec.tile_ok(shapes, tiles):
            out.append(tiles)
    if not out and op_spec.default_tiles is not None:
        out = [dict(op_spec.default_tiles(shapes))]
    return out


def _time_config(fn, args, kwargs, tiles, *, repeats: int,
                 warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs, **tiles))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs, **tiles))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(op: str, shapes, *, policy=None, impl: str | None = None,
             repeats: int = 3, warmup: int = 1, cache: TuningCache | None =
             None, save: bool = True, force: bool = False,
             max_configs: int = 64) -> dict:
    """Sweep ``op``'s tile space over ``shapes``; persist winners.

    ``shapes`` is a list of op-specific shape tuples (see the op's
    ``example_inputs``).  The impl timed is ``impl`` if given, else the
    policy's pin, else the op's ``tune_impls`` entry for this platform.
    Existing cache entries are kept unless ``force``.  Returns
    ``{bucket: {"tiles", "time_us", "configs"}}``.
    """
    from . import registry

    op_spec = registry.spec(op)
    if op_spec.example_inputs is None or not op_spec.tile_space:
        raise ValueError(f"op {op!r} has no tunable tile space")
    policy = policy or registry.DEFAULT_POLICY
    platform = (policy.platform if policy.platform != "auto"
                else jax.default_backend())
    impl_name = (impl or policy.impl_for(op)
                 or op_spec.tune_impls.get(platform)
                 or op_spec.tune_impls.get("*"))
    if impl_name is None or impl_name not in op_spec.impls:
        raise ValueError(
            f"{op}: no tunable impl for platform {platform!r} "
            f"(got {impl_name!r})")
    impl_spec = op_spec.impls[impl_name]
    cache = cache or get_cache()

    results: dict[str, dict] = {}
    for shape in shapes:
        args, kwargs = op_spec.example_inputs(shape)
        sh = op_spec.shape_info(*args, **kwargs)
        if impl_spec.constraint is not None:
            why = impl_spec.constraint(sh)
            if why is not None:
                results[str(shape)] = {"skipped": why}
                continue
        bucket = op_spec.bucket(sh) if op_spec.bucket else str(shape)
        if not force and cache.lookup(op, platform, bucket) is not None:
            results[bucket] = {"tiles": cache.lookup(op, platform, bucket),
                               "cached": True}
            continue
        best_tiles, best_t = None, float("inf")
        cands = tile_candidates(op_spec, sh)[:max_configs]
        for tiles in cands:
            t = _time_config(impl_spec.fn, args, kwargs, tiles,
                             repeats=repeats, warmup=warmup)
            if t < best_t:
                best_tiles, best_t = tiles, t
        if best_tiles is None:
            results[bucket] = {"skipped": "no feasible tile config"}
            continue
        cache.store(op, platform, bucket, best_tiles, best_t * 1e6,
                    shape=shape if isinstance(shape, (list, tuple))
                    else [shape])
        results[bucket] = {"tiles": best_tiles,
                           "time_us": round(best_t * 1e6, 3),
                           "configs": len(cands)}
    if save:
        cache.save()
    return results
