# Pallas TPU kernels for DeepCABAC's compute hot-spots, behind one
# registry (see registry.py and docs/kernels_api.md):
#   rd_quant        — eq. (11) RD assignment (encoder hot-spot)
#   dequant_matmul  — int8-level dequantize fused into the serving matmul
#   flash_attention — causal online-softmax attention (pallas/scan/ref)
#   embed_lookup_q8 — int8 embedding-row gather (fixed-point serving)
# Each subpackage ships kernel.py (pallas_call + BlockSpec), ops.py (jit
# wrapper + OpSpec registration) and ref.py (pure-jnp oracle).  Call sites
# outside this package go through kernels.get(name)(..., policy=...);
# direct subpackage imports are reserved for tests and benchmarks.
from . import registry, tune  # noqa: F401  (registry first: specs need it)
from .registry import (  # noqa: F401
    DEFAULT_POLICY, BoundOp, DispatchPlan, Impl, KernelDispatchError,
    KernelPolicy, OpSpec, available_ops, clear_dispatch_report,
    dispatch_report, get, record_event, register_op, spec)
from .tune import TuningCache, autotune  # noqa: F401

# importing the subpackages registers their OpSpecs
from .dequant_matmul import (  # noqa: F401
    dequant_matmul, dequant_matmul_grouped)
from .embed_lookup import embed_lookup_q8, is_q8_leaf  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .rd_quant import pack_rate_params, rd_quant  # noqa: F401
