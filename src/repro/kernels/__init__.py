# Pallas TPU kernels for DeepCABAC's compute hot-spots:
#   rd_quant       — eq. (11) RD assignment (encoder hot-spot)
#   dequant_matmul — int8-level dequantize fused into the serving matmul
# Each subpackage ships kernel.py (pallas_call + BlockSpec), ops.py (jit
# wrapper with interpret switch) and ref.py (pure-jnp oracle).
