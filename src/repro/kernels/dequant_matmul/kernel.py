"""Pallas TPU kernel: int8-level weights dequantized in VMEM, fed to the MXU.

Serving decode is HBM-bandwidth-bound on weight reads; DeepCABAC's
equidistant grid (q = Delta * I, I in int8 for any practical step size) lets
weights live in HBM at 1 byte/param.  This kernel streams (BK, BN) int8 tiles
into VMEM, multiplies by the per-channel Delta, and accumulates x @ w on the
MXU in f32 — the dequantize never round-trips through HBM.

Tiling: grid (M/BM, N/BN, K/BK); K innermost so the f32 accumulator tile
stays resident in VMEM across the K loop (revisiting semantics).  Tiles are
MXU-aligned (128x128 multiples).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 256, 256, 512


def _dequant_matmul_kernel(x_ref, wq_ref, scale_ref, out_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    w = wq_ref[...].astype(jnp.float32) * scale_ref[0, :].astype(jnp.float32)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def dequant_matmul_pallas(x: jnp.ndarray, w_q: jnp.ndarray,
                          scale: jnp.ndarray, *, bm: int = BM, bn: int = BN,
                          bk: int = BK,
                          interpret: bool = False) -> jnp.ndarray:
    """x (M, K) f32/bf16, w_q (K, N) int8, scale (N,) f32 -> (M, N) f32.
    M, K, N must be multiples of the block sizes (ops.py pads)."""
    m, k = x.shape
    _, n = w_q.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, scale.reshape(1, -1))


def _dequant_matmul_grouped_kernel(x_ref, wq_ref, scale_ref, out_ref, *,
                                   n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0].astype(jnp.float32)
    w = (wq_ref[0].astype(jnp.float32)
         * scale_ref[0, 0, :].astype(jnp.float32))
    out_ref[0] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def dequant_matmul_grouped_pallas(x: jnp.ndarray, w_q: jnp.ndarray,
                                  scale: jnp.ndarray, *, bm: int = BM,
                                  bn: int = BN, bk: int = BK,
                                  interpret: bool = False) -> jnp.ndarray:
    """Grouped-expert variant: x (E, M, K), w_q (E, K, N) int8,
    scale (E, N) f32 -> (E, M, N) f32.  One expert per leading grid step;
    within an expert the tiling matches :func:`dequant_matmul_pallas`
    (K innermost, f32 accumulator tile resident in VMEM).  M, K, N must
    be multiples of the block sizes (ops.py pads)."""
    e, m, k = x.shape
    n = w_q.shape[2]
    grid = (e, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_grouped_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, kk: (g, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, kk: (g, kk, j)),
            pl.BlockSpec((1, 1, bn), lambda g, i, j, kk: (g, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, kk: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, scale.reshape(e, 1, n))
