from .ops import dequant_matmul, dequant_matmul_grouped  # noqa: F401
