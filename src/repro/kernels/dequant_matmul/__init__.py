from .ops import dequant_matmul  # noqa: F401
