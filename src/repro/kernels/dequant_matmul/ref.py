"""Pure-jnp oracle for the fused dequantize-matmul serving kernel."""

from __future__ import annotations

import jax.numpy as jnp


def dequant_matmul_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                       scale: jnp.ndarray) -> jnp.ndarray:
    """x (M, K) float  @  dequant(w_q (K, N) int8, scale (N,) f32) -> (M, N).

    q = Delta * level (paper §III-C-1); scale is the per-output-channel Delta.
    Accumulation in f32 as on the MXU.
    """
    w = w_q.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)


def dequant_matmul_grouped_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                               scale: jnp.ndarray) -> jnp.ndarray:
    """Grouped-expert oracle: x (E, M, K) @ dequant(w_q (E, K, N) int8,
    scale (E, N) | (N,)) -> (E, M, N), one independent matmul per expert.

    A (N,)-shaped scale is the stacked-MoE wire format (one per-output-
    channel Delta shared across the layer's experts — see
    ``compression.quantizers.quantize_leaf``); it broadcasts over E.
    """
    if scale.ndim == 1:
        scale = scale[None, :]
    w = w_q.astype(jnp.float32) * scale[:, None, :].astype(jnp.float32)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32), w,
                      preferred_element_type=jnp.float32)
