"""Pure-jnp oracle for the fused dequantize-matmul serving kernel."""

from __future__ import annotations

import jax.numpy as jnp


def dequant_matmul_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                       scale: jnp.ndarray) -> jnp.ndarray:
    """x (M, K) float  @  dequant(w_q (K, N) int8, scale (N,) f32) -> (M, N).

    q = Delta * level (paper §III-C-1); scale is the per-output-channel Delta.
    Accumulation in f32 as on the MXU.
    """
    w = w_q.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)
