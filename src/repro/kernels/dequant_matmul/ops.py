"""jit'd public wrapper + registry spec for the fused dequantize-matmul."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import Impl, OpSpec, register_op
from ..tune import pow2_bucket
from .kernel import BK, BM, BN, dequant_matmul_pallas
from .ref import dequant_matmul_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def default_tiles(m: int, k: int, n: int) -> dict:
    """Shape-adaptive tiles.  ``bm`` clamps to the sublane-padded row count
    so a 1-8 row decode matmul pads to 8 rows, not 256; ``bn``/``bk`` clamp
    to the lane-padded layer dims for small heads."""
    return {"bm": min(BM, _round_up(max(m, 1), 8)),
            "bn": min(BN, _round_up(max(n, 1), 128)),
            "bk": min(BK, _round_up(max(k, 1), 128))}


def _pad_to(x: jnp.ndarray, mult: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                              "use_ref"))
def _dequant_matmul_jit(x, w_q, scale, *, bm, bn, bk, interpret, use_ref):
    if use_ref:
        return dequant_matmul_ref(x, w_q, scale)
    m, n = x.shape[0], w_q.shape[1]
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    sp = _pad_to(scale, (bn,))
    out = dequant_matmul_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]


def dequant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
                   bm: int | None = None, bn: int | None = None,
                   bk: int | None = None, interpret: bool = False,
                   use_ref: bool = False) -> jnp.ndarray:
    """Serving matmul against DeepCABAC-quantized weights.

    x (M, K), w_q (K, N) int8 levels, scale (N,) per-channel Delta.
    Tile sizes default to :func:`default_tiles` (shape-adaptive).
    """
    x, w_q, scale = jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale)
    tiles = default_tiles(x.shape[0], x.shape[1], w_q.shape[1])
    return _dequant_matmul_jit(x, w_q, scale, bm=bm or tiles["bm"],
                               bn=bn or tiles["bn"], bk=bk or tiles["bk"],
                               interpret=interpret, use_ref=use_ref)


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------

def _shape_info(x, w_q, scale) -> dict:
    x, w_q = jnp.asarray(x), jnp.asarray(w_q)
    return {"m": x.shape[0], "k": x.shape[1], "n": w_q.shape[1]}


def _bucket(s: dict) -> str:
    # rows are data-dependent (decode m = live batch) -> pow2 bucket;
    # k/n are model dims -> exact
    return f"m{pow2_bucket(s['m'])}_k{s['k']}_n{s['n']}"


def _tile_ok(s: dict, t: dict) -> bool:
    return (t["bm"] <= max(_round_up(s["m"], 8), 8)
            and t["bn"] <= _round_up(s["n"], 128)
            and t["bk"] <= _round_up(s["k"], 128))


def _example_inputs(shape):
    m, k, n = shape
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.01 + 1e-4, jnp.float32)
    return (x, wq, sc), {}


def _run_pallas(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul(x, w_q, scale, bm=bm, bn=bn, bk=bk)


def _run_interpret(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul(x, w_q, scale, bm=bm, bn=bn, bk=bk,
                          interpret=True)


def _run_ref(x, w_q, scale):
    return dequant_matmul(x, w_q, scale, use_ref=True)


@register_op
def _dequant_matmul_spec() -> OpSpec:
    return OpSpec(
        name="dequant_matmul",
        impls={
            "pallas": Impl("pallas", _run_pallas, platforms=("tpu",)),
            "interpret": Impl("interpret", _run_interpret),
            "ref": Impl("ref", _run_ref, uses_tiles=False),
        },
        defaults={"tpu": "pallas", "*": "ref"},
        fallbacks=("interpret", "ref"),
        tile_space={"bm": (8, 16, 32, 64, 128, 256),
                    "bn": (128, 256, 512),
                    "bk": (128, 256, 512, 1024)},
        default_tiles=lambda s: default_tiles(s["m"], s["k"], s["n"]),
        tile_ok=_tile_ok,
        shape_info=_shape_info,
        bucket=_bucket,
        example_inputs=_example_inputs,
        oracle=dequant_matmul_ref,
        tune_impls={"tpu": "pallas", "*": "interpret"},
    )
