"""jit'd public wrappers + registry specs for the fused dequantize-matmul.

Two ops live here:

``dequant_matmul``          x (..., K) @ dequant(w_q (K, N), scale (N,))
``dequant_matmul_grouped``  x (E, M, K) @ dequant(w_q (E, K, N),
                            scale (E, N) | (N,)) — one matmul per expert.

Leading activation dims are flattened to the kernel's M and restored on the
way out, so attention projections (B, S, K) and MoE capacity buffers route
through the same pallas kernels as 2-D calls.  Explicit/tuned tiles are
clamped against the padded operand dims at dispatch (a pow2-bucketed cache
winner for m=64 must not ride along verbatim to an m=3 decode batch); every
clamp is recorded in ``dispatch_report()`` with ``kind="tile_clamp"``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import Impl, OpSpec, record_event, register_op
from ..tune import pow2_bucket
from .kernel import (BK, BM, BN, dequant_matmul_grouped_pallas,
                     dequant_matmul_pallas)
from .ref import dequant_matmul_grouped_ref, dequant_matmul_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def default_tiles(m: int, k: int, n: int) -> dict:
    """Shape-adaptive tiles.  ``bm`` clamps to the sublane-padded row count
    so a 1-8 row decode matmul pads to 8 rows, not 256; ``bn``/``bk`` clamp
    to the lane-padded layer dims for small heads."""
    return {"bm": min(BM, _round_up(max(m, 1), 8)),
            "bn": min(BN, _round_up(max(n, 1), 128)),
            "bk": min(BK, _round_up(max(k, 1), 128))}


def tile_bounds(m: int, k: int, n: int) -> dict:
    """Hard per-shape ceilings: a tile larger than the padded operand dim
    buys nothing and (for cached/explicit tiles) can exceed the padded
    operand.  Bounds are sublane/lane padded so clamped values stay
    MXU-aligned."""
    return {"bm": max(_round_up(m, 8), 8),
            "bn": _round_up(max(n, 1), 128),
            "bk": _round_up(max(k, 1), 128)}


def _resolve_tiles(requested: dict, m: int, k: int, n: int, *, op: str,
                   impl: str) -> dict:
    """Merge explicit tiles over shape defaults, then clamp to
    :func:`tile_bounds`.  A clamp never crashes the pallas call — it is
    recorded once per trace via :func:`record_event`."""
    tiles = default_tiles(m, k, n)
    tiles.update({p: v for p, v in requested.items() if v is not None})
    bounds = tile_bounds(m, k, n)
    clamped = {p: min(v, bounds[p]) for p, v in tiles.items()}
    if clamped != tiles:
        changed = ", ".join(
            f"{p}={tiles[p]}->{clamped[p]}"
            for p in ("bm", "bn", "bk") if clamped[p] != tiles[p])
        record_event(
            op=op, platform=jax.default_backend(), impl=impl,
            reason=(f"tile clamp for (m={m}, k={k}, n={n}): {changed} "
                    "(cached/explicit tile exceeded padded operand)"),
            kind="tile_clamp")
    return clamped


def _pad_to(x: jnp.ndarray, mult: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                              "use_ref"))
def _dequant_matmul_jit(x, w_q, scale, *, bm, bn, bk, interpret, use_ref):
    if use_ref:
        return dequant_matmul_ref(x, w_q, scale)
    m, n = x.shape[0], w_q.shape[1]
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    sp = _pad_to(scale, (bn,))
    out = dequant_matmul_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]


def dequant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
                   bm: int | None = None, bn: int | None = None,
                   bk: int | None = None, interpret: bool = False,
                   use_ref: bool = False) -> jnp.ndarray:
    """Serving matmul against DeepCABAC-quantized weights.

    x (..., K) float, w_q (K, N) int8 levels, scale (N,) per-channel Delta
    -> (..., N) f32.  Leading dims are flattened to the kernel's M.  Tile
    sizes default to :func:`default_tiles`; explicit/tuned tiles are
    clamped to the padded operand (see :func:`_resolve_tiles`).
    """
    x, w_q, scale = jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale)
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = int(np.prod(lead)) if lead else 1
    n = w_q.shape[1]
    x2 = x.reshape(m, k)
    if use_ref:
        out = _dequant_matmul_jit(x2, w_q, scale, bm=0, bn=0, bk=0,
                                  interpret=False, use_ref=True)
    else:
        t = _resolve_tiles({"bm": bm, "bn": bn, "bk": bk}, m, k, n,
                           op="dequant_matmul",
                           impl="interpret" if interpret else "pallas")
        out = _dequant_matmul_jit(x2, w_q, scale, bm=t["bm"], bn=t["bn"],
                                  bk=t["bk"], interpret=interpret,
                                  use_ref=False)
    return out.reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                              "use_ref"))
def _dequant_matmul_grouped_jit(x, w_q, scale, *, bm, bn, bk, interpret,
                                use_ref):
    if use_ref:
        return dequant_matmul_grouped_ref(x, w_q, scale)
    _, m, _ = x.shape
    n = w_q.shape[2]
    xp = _pad_to(x, (1, bm, bk))
    wp = _pad_to(w_q, (1, bk, bn))
    sp = _pad_to(scale, (1, bn))
    out = dequant_matmul_grouped_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                                        interpret=interpret)
    return out[:, :m, :n]


def dequant_matmul_grouped(x: jnp.ndarray, w_q: jnp.ndarray,
                           scale: jnp.ndarray, *, bm: int | None = None,
                           bn: int | None = None, bk: int | None = None,
                           interpret: bool = False,
                           use_ref: bool = False) -> jnp.ndarray:
    """Grouped-expert serving matmul: one independent matmul per expert.

    x (E, M, K) float, w_q (E, K, N) int8 levels, scale (E, N) f32 or (N,)
    (the stacked-MoE wire format — one per-channel Delta shared across the
    layer's experts) -> (E, M, N) f32.
    """
    x, w_q, scale = jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale)
    e, m, k = x.shape
    n = w_q.shape[2]
    if scale.ndim == 1:
        scale = jnp.broadcast_to(scale[None, :], (e, n))
    if use_ref:
        return _dequant_matmul_grouped_jit(x, w_q, scale, bm=0, bn=0, bk=0,
                                           interpret=False, use_ref=True)
    t = _resolve_tiles({"bm": bm, "bn": bn, "bk": bk}, m, k, n,
                       op="dequant_matmul_grouped",
                       impl="interpret" if interpret else "pallas")
    return _dequant_matmul_grouped_jit(x, w_q, scale, bm=t["bm"],
                                       bn=t["bn"], bk=t["bk"],
                                       interpret=interpret, use_ref=False)


# ---------------------------------------------------------------------------
# Registry specs
# ---------------------------------------------------------------------------

def _shape_info(x, w_q, scale) -> dict:
    x, w_q = jnp.asarray(x), jnp.asarray(w_q)
    m = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    return {"m": m, "k": x.shape[-1], "n": w_q.shape[1]}


def _bucket(s: dict) -> str:
    # rows are data-dependent (decode m = live batch) -> pow2 bucket;
    # k/n are model dims -> exact
    return f"m{pow2_bucket(s['m'])}_k{s['k']}_n{s['n']}"


def _tile_ok(s: dict, t: dict) -> bool:
    b = tile_bounds(s["m"], s["k"], s["n"])
    return all(t[p] <= b[p] for p in ("bm", "bn", "bk"))


def _example_inputs(shape):
    m, k, n = shape
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.01 + 1e-4, jnp.float32)
    return (x, wq, sc), {}


def _run_pallas(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul(x, w_q, scale, bm=bm, bn=bn, bk=bk)


def _run_interpret(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul(x, w_q, scale, bm=bm, bn=bn, bk=bk,
                          interpret=True)


def _run_ref(x, w_q, scale):
    return dequant_matmul(x, w_q, scale, use_ref=True)


@register_op
def _dequant_matmul_spec() -> OpSpec:
    return OpSpec(
        name="dequant_matmul",
        impls={
            "pallas": Impl("pallas", _run_pallas, platforms=("tpu",)),
            "interpret": Impl("interpret", _run_interpret),
            "ref": Impl("ref", _run_ref, uses_tiles=False),
        },
        defaults={"tpu": "pallas", "*": "ref"},
        fallbacks=("interpret", "ref"),
        tile_space={"bm": (8, 16, 32, 64, 128, 256),
                    "bn": (128, 256, 512),
                    "bk": (128, 256, 512, 1024)},
        default_tiles=lambda s: default_tiles(s["m"], s["k"], s["n"]),
        tile_ok=_tile_ok,
        shape_info=_shape_info,
        bucket=_bucket,
        example_inputs=_example_inputs,
        oracle=dequant_matmul_ref,
        tune_impls={"tpu": "pallas", "*": "interpret"},
    )


def _grouped_shape_info(x, w_q, scale) -> dict:
    x, w_q = jnp.asarray(x), jnp.asarray(w_q)
    return {"e": x.shape[0], "m": x.shape[1], "k": x.shape[2],
            "n": w_q.shape[2]}


def _grouped_bucket(s: dict) -> str:
    # expert count and k/n are model dims -> exact; per-expert rows are the
    # (static) capacity buffer, but pow2-bucket anyway for robustness
    return f"e{s['e']}_m{pow2_bucket(s['m'])}_k{s['k']}_n{s['n']}"


def _grouped_example_inputs(shape):
    e, m, k, n = shape
    rng = np.random.default_rng(e * 131 + m * 31 + k * 7 + n)
    x = jnp.asarray(rng.standard_normal((e, m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (e, k, n)), jnp.int8)
    sc = jnp.asarray(rng.random((e, n)) * 0.01 + 1e-4, jnp.float32)
    return (x, wq, sc), {}


def _run_grouped_pallas(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul_grouped(x, w_q, scale, bm=bm, bn=bn, bk=bk)


def _run_grouped_interpret(x, w_q, scale, *, bm, bn, bk):
    return dequant_matmul_grouped(x, w_q, scale, bm=bm, bn=bn, bk=bk,
                                  interpret=True)


def _run_grouped_ref(x, w_q, scale):
    return dequant_matmul_grouped(x, w_q, scale, use_ref=True)


@register_op
def _dequant_matmul_grouped_spec() -> OpSpec:
    return OpSpec(
        name="dequant_matmul_grouped",
        impls={
            "pallas": Impl("pallas", _run_grouped_pallas,
                           platforms=("tpu",)),
            "interpret": Impl("interpret", _run_grouped_interpret),
            "ref": Impl("ref", _run_grouped_ref, uses_tiles=False),
        },
        defaults={"tpu": "pallas", "*": "ref"},
        fallbacks=("interpret", "ref"),
        tile_space={"bm": (8, 16, 32, 64, 128),
                    "bn": (128, 256),
                    "bk": (128, 256, 512)},
        default_tiles=lambda s: default_tiles(s["m"], s["k"], s["n"]),
        tile_ok=_tile_ok,
        shape_info=_grouped_shape_info,
        bucket=_grouped_bucket,
        example_inputs=_grouped_example_inputs,
        oracle=dequant_matmul_grouped_ref,
        tune_impls={"tpu": "pallas", "*": "interpret"},
    )
