"""jit'd public wrapper for the fused dequantize-matmul kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import dequant_matmul_pallas
from .ref import dequant_matmul_ref


def _pad_to(x: jnp.ndarray, mult: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                              "use_ref"))
def _dequant_matmul_jit(x, w_q, scale, *, bm, bn, bk, interpret, use_ref):
    if use_ref:
        return dequant_matmul_ref(x, w_q, scale)
    m, n = x.shape[0], w_q.shape[1]
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w_q, (bk, bn))
    sp = _pad_to(scale, (bn,))
    out = dequant_matmul_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]


def dequant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
                   bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = False,
                   use_ref: bool = False) -> jnp.ndarray:
    """Serving matmul against DeepCABAC-quantized weights.

    x (M, K), w_q (K, N) int8 levels, scale (N,) per-channel Delta.
    """
    return _dequant_matmul_jit(jnp.asarray(x), jnp.asarray(w_q),
                               jnp.asarray(scale), bm=bm, bn=bn, bk=bk,
                               interpret=interpret, use_ref=use_ref)
