"""int8 embedding-row gather (promoted out of serve/quantized.py).

Fixed-point serving keeps the (V, d) embedding table in HBM as int8 levels
with a per-column Delta.  The ``gather`` impl reads B*S int8 rows and
dequantizes in-core — 1 byte/param on the dominant HBM term instead of 4 —
while the ``ref`` impl dequantizes the whole table first (the pure-jnp
oracle: both orders multiply the same rows by the same per-column scale,
so the results are bit-identical).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..registry import Impl, OpSpec, register_op


def is_q8_leaf(leaf) -> bool:
    return isinstance(leaf, dict) and "q8" in leaf and "q8s" in leaf


def embed_lookup_q8(embed_leaf, tokens, dtype):
    """Gather int8 rows first, dequantize after — the gather reads B*S rows
    of int8 instead of the full-precision table."""
    if is_q8_leaf(embed_leaf):
        rows = jnp.take(embed_leaf["q8"], tokens, axis=0)
        return (rows.astype(jnp.float32)
                * embed_leaf["q8s"]).astype(dtype)
    return jnp.take(embed_leaf, tokens, axis=0).astype(dtype)


def embed_lookup_ref(embed_leaf, tokens, dtype):
    """Dequantize-then-gather oracle (numerically identical)."""
    if is_q8_leaf(embed_leaf):
        table = embed_leaf["q8"].astype(jnp.float32) * embed_leaf["q8s"]
        return jnp.take(table, tokens, axis=0).astype(dtype)
    return jnp.take(embed_leaf, tokens, axis=0).astype(dtype)


def _shape_info(embed_leaf, tokens, dtype) -> dict:
    arr = embed_leaf["q8"] if is_q8_leaf(embed_leaf) else embed_leaf
    return {"vocab": arr.shape[0], "d": arr.shape[-1],
            "q8": is_q8_leaf(embed_leaf)}


@register_op
def _embed_lookup_spec() -> OpSpec:
    return OpSpec(
        name="embed_lookup_q8",
        impls={
            "gather": Impl("gather", embed_lookup_q8, uses_tiles=False),
            "ref": Impl("ref", embed_lookup_ref, uses_tiles=False),
        },
        defaults={"*": "gather"},
        fallbacks=("ref",),
        shape_info=_shape_info,
        oracle=embed_lookup_ref,
    )
