from .ops import embed_lookup_q8, is_q8_leaf  # noqa: F401
