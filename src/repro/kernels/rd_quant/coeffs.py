"""Rate-model coefficient packing shared by the kernel and the oracle.

The CABAC rate of a level k decomposes into

    k == 0 : l0_sig[ps]
    k != 0 : l1_sig[ps] + (l_neg | l_pos) + mag_rate[class(|k|)]

with a "magnitude class" that is |k|-1 for |k| <= num_gr and
num_gr + floor(log2(|k| - num_gr)) beyond (the Exp-Golomb exponent).  The
class table folds the AbsGr cumulative costs, the unary exponent costs, the
context cap and the k bypass bits — so the kernel only does one small
one-hot select per candidate instead of a dynamic gather.
"""

from __future__ import annotations

import numpy as np

from ...core.binarization import EG_CTXS
from ...core.rate_model import BinProbs

NUM_SCALARS = 8  # l0_sig0, l0_sig1, l1_sig0, l1_sig1, l_neg, l_pos, pad, pad
EG_CLASSES = 32
SC_L0_SIG0, SC_L0_SIG1, SC_L1_SIG0, SC_L1_SIG1, SC_LNEG, SC_LPOS = range(6)


def num_classes(num_gr: int) -> int:
    return num_gr + EG_CLASSES


def pack_coeffs(probs: BinProbs) -> tuple[np.ndarray, np.ndarray]:
    """Return (scalars (1, NUM_SCALARS) f32, mag_rate (1, classes) f32)."""
    num_gr = probs.num_gr
    scalars = np.zeros(NUM_SCALARS, dtype=np.float64)
    scalars[SC_L0_SIG0] = -np.log2(1.0 - probs.p_sig[0])
    scalars[SC_L0_SIG1] = -np.log2(1.0 - probs.p_sig[1])
    scalars[SC_L1_SIG0] = -np.log2(probs.p_sig[0])
    scalars[SC_L1_SIG1] = -np.log2(probs.p_sig[1])
    scalars[SC_LNEG] = -np.log2(probs.p_sign)
    scalars[SC_LPOS] = -np.log2(1.0 - probs.p_sign)

    cum_gr1 = np.concatenate([[0.0], np.cumsum(-np.log2(probs.p_gr))])
    l0_gr = -np.log2(1.0 - probs.p_gr)
    cum_eg1 = np.concatenate([[0.0], np.cumsum(-np.log2(probs.p_eg))])
    l0_eg = -np.log2(1.0 - probs.p_eg)
    l1_eg_last = -np.log2(probs.p_eg[-1])

    mag = np.zeros(num_classes(num_gr), dtype=np.float64)
    for a in range(1, num_gr + 1):                      # |k| <= num_gr
        mag[a - 1] = cum_gr1[a - 1] + l0_gr[a - 1]
    for k_exp in range(EG_CLASSES):                     # |k| > num_gr
        kk = min(k_exp, EG_CTXS - 1)
        mag[num_gr + k_exp] = (cum_gr1[num_gr] + cum_eg1[kk]
                               + (k_exp - kk) * l1_eg_last + l0_eg[kk]
                               + k_exp)                 # + bypass bits
    return (scalars[None, :].astype(np.float32),
            mag[None, :].astype(np.float32))
