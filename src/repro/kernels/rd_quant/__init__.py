from .ops import pack_rate_params, rd_quant  # noqa: F401
