"""jit'd public wrapper + registry spec for the RD-quantization kernel.

Handles flattening/padding to the (M, 1024) tile layout, coefficient packing
from the numpy rate model, and the prev_sig fixed-point iteration (the same
two-pass scheme as core.quant.rd_assign).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.rate_model import BinProbs
from ..registry import Impl, OpSpec, register_op
from ..tune import pow2_bucket
from .coeffs import pack_coeffs
from .kernel import BLOCK_M, LANES, rd_quant_pallas
from .ref import rd_quant_ref

pack_rate_params = pack_coeffs


def default_block_m(n: int) -> int:
    """Row-block clamped to the sublane-padded row count: small tensors
    (< BLOCK_M * LANES elements) stop padding up to the full 256-row tile."""
    rows = -(-max(int(n), 1) // LANES)
    return min(BLOCK_M, -(-rows // 8) * 8)


def _pad2d(x: jnp.ndarray, fill: float, block_m: int
           ) -> tuple[jnp.ndarray, int]:
    n = x.size
    per_block = block_m * LANES
    m = max((n + per_block - 1) // per_block, 1) * block_m
    padded = jnp.full((m * LANES,), fill, dtype=jnp.float32)
    padded = padded.at[:n].set(x.reshape(-1).astype(jnp.float32))
    return padded.reshape(m, LANES), n


@functools.partial(jax.jit, static_argnames=(
    "step", "lam", "window", "max_level", "num_gr", "passes", "block_m",
    "interpret", "use_ref"))
def _rd_quant_jit(w, fisher, scalars, mag_rate, *, step, lam, window,
                  max_level, num_gr, passes, block_m, interpret, use_ref):
    w2d, n = _pad2d(w, 0.0, block_m)
    f2d, _ = _pad2d(fisher, 1.0, block_m)
    flat_w = w2d.reshape(-1)

    nn = jnp.clip(jnp.round(flat_w / step), -max_level, max_level)
    levels = nn
    for _ in range(max(passes, 1)):
        sig = (levels != 0).astype(jnp.float32)
        ps = jnp.concatenate([jnp.zeros((1,), jnp.float32), sig[:-1]])
        ps2d = ps.reshape(w2d.shape)
        if use_ref:
            out = rd_quant_ref(w2d, f2d, ps2d, scalars, mag_rate, step=step,
                               lam=lam, window=window, max_level=max_level,
                               num_gr=num_gr)
        else:
            out = rd_quant_pallas(w2d, f2d, ps2d, scalars, mag_rate,
                                  step=step, lam=lam, window=window,
                                  max_level=max_level, num_gr=num_gr,
                                  block_m=block_m, interpret=interpret)
        levels = out.reshape(-1).astype(jnp.float32)
    return levels[:n].astype(jnp.int32)


def rd_quant(w, fisher, probs: BinProbs, *, step: float, lam: float,
             window: int = 4, max_level: int = 1 << 20, passes: int = 2,
             block_m: int | None = None, interpret: bool = False,
             use_ref: bool = False) -> jnp.ndarray:
    """RD-quantize a tensor of any shape; returns int32 levels, same shape.

    ``use_ref=True`` routes through the pure-jnp oracle (used on CPU and in
    differential tests); otherwise the Pallas kernel runs (``interpret=True``
    executes the kernel body in Python for validation off-TPU).
    ``block_m`` is the row-block tile (default shape-adaptive).
    """
    scalars, mag_rate = pack_coeffs(probs)
    shape = np.shape(w)
    size = int(np.prod(shape)) if shape else 1
    out = _rd_quant_jit(
        jnp.asarray(w).reshape(-1), jnp.asarray(
            fisher if fisher is not None else np.ones(shape)).reshape(-1),
        jnp.asarray(scalars), jnp.asarray(mag_rate), step=float(step),
        lam=float(lam), window=int(window), max_level=int(max_level),
        num_gr=int(probs.num_gr), passes=int(passes),
        block_m=int(block_m or default_block_m(size)), interpret=interpret,
        use_ref=use_ref)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# Registry spec.  Op signature: (w, fisher, probs, *, step, lam, ...)
# ---------------------------------------------------------------------------

def _shape_info(w, fisher=None, probs=None, **kwargs) -> dict:
    return {"n": int(np.prod(np.shape(w)) or 1)}


def _bucket(s: dict) -> str:
    return f"n{pow2_bucket(s['n'])}"


def _example_inputs(shape):
    from ...core.quant import nearest_level
    from ...core.rate_model import estimate_bin_probs
    n = int(shape[0]) if isinstance(shape, (tuple, list)) else int(shape)
    rng = np.random.default_rng(n)
    w = (rng.standard_normal(n) * 0.05).astype(np.float32)
    w[rng.random(n) < 0.5] = 0
    step = 0.008
    probs = estimate_bin_probs(nearest_level(w, step))
    return (w, None, probs), {"step": step, "lam": 2e-4}


def _run_pallas(w, fisher, probs, *, block_m=None, **kw):
    return rd_quant(w, fisher, probs, block_m=block_m, **kw)


def _run_interpret(w, fisher, probs, *, block_m=None, **kw):
    return rd_quant(w, fisher, probs, block_m=block_m, interpret=True, **kw)


def _run_ref(w, fisher, probs, **kw):
    return rd_quant(w, fisher, probs, use_ref=True, **kw)


@register_op
def _rd_quant_spec() -> OpSpec:
    return OpSpec(
        name="rd_quant",
        impls={
            "pallas": Impl("pallas", _run_pallas, platforms=("tpu",)),
            "interpret": Impl("interpret", _run_interpret),
            "ref": Impl("ref", _run_ref, uses_tiles=False),
        },
        defaults={"tpu": "pallas", "*": "ref"},
        fallbacks=("ref",),
        tile_space={"block_m": (8, 64, 128, 256, 512)},
        default_tiles=lambda s: {"block_m": default_block_m(s["n"])},
        shape_info=_shape_info,
        bucket=_bucket,
        example_inputs=_example_inputs,
        oracle=rd_quant_ref,
        tune_impls={"tpu": "pallas", "*": "interpret"},
    )
