"""jit'd public wrapper for the RD-quantization kernel.

Handles flattening/padding to the (M, 1024) tile layout, coefficient packing
from the numpy rate model, and the prev_sig fixed-point iteration (the same
two-pass scheme as core.quant.rd_assign).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.rate_model import BinProbs
from .coeffs import pack_coeffs
from .kernel import BLOCK_M, LANES, rd_quant_pallas
from .ref import rd_quant_ref

pack_rate_params = pack_coeffs


def _pad2d(x: jnp.ndarray, fill: float) -> tuple[jnp.ndarray, int]:
    n = x.size
    per_block = BLOCK_M * LANES
    m = max((n + per_block - 1) // per_block, 1) * BLOCK_M
    padded = jnp.full((m * LANES,), fill, dtype=jnp.float32)
    padded = padded.at[:n].set(x.reshape(-1).astype(jnp.float32))
    return padded.reshape(m, LANES), n


@functools.partial(jax.jit, static_argnames=(
    "step", "lam", "window", "max_level", "num_gr", "passes", "interpret",
    "use_ref"))
def _rd_quant_jit(w, fisher, scalars, mag_rate, *, step, lam, window,
                  max_level, num_gr, passes, interpret, use_ref):
    w2d, n = _pad2d(w, 0.0)
    f2d, _ = _pad2d(fisher, 1.0)
    flat_w = w2d.reshape(-1)

    nn = jnp.clip(jnp.round(flat_w / step), -max_level, max_level)
    levels = nn
    for _ in range(max(passes, 1)):
        sig = (levels != 0).astype(jnp.float32)
        ps = jnp.concatenate([jnp.zeros((1,), jnp.float32), sig[:-1]])
        ps2d = ps.reshape(w2d.shape)
        if use_ref:
            out = rd_quant_ref(w2d, f2d, ps2d, scalars, mag_rate, step=step,
                               lam=lam, window=window, max_level=max_level,
                               num_gr=num_gr)
        else:
            out = rd_quant_pallas(w2d, f2d, ps2d, scalars, mag_rate,
                                  step=step, lam=lam, window=window,
                                  max_level=max_level, num_gr=num_gr,
                                  interpret=interpret)
        levels = out.reshape(-1).astype(jnp.float32)
    return levels[:n].astype(jnp.int32)


def rd_quant(w, fisher, probs: BinProbs, *, step: float, lam: float,
             window: int = 4, max_level: int = 1 << 20, passes: int = 2,
             interpret: bool = False, use_ref: bool = False) -> jnp.ndarray:
    """RD-quantize a tensor of any shape; returns int32 levels, same shape.

    ``use_ref=True`` routes through the pure-jnp oracle (used on CPU and in
    differential tests); otherwise the Pallas kernel runs (``interpret=True``
    executes the kernel body in Python for validation off-TPU).
    """
    scalars, mag_rate = pack_coeffs(probs)
    shape = np.shape(w)
    out = _rd_quant_jit(
        jnp.asarray(w).reshape(-1), jnp.asarray(
            fisher if fisher is not None else np.ones(shape)).reshape(-1),
        jnp.asarray(scalars), jnp.asarray(mag_rate), step=float(step),
        lam=float(lam), window=int(window), max_level=int(max_level),
        num_gr=int(probs.num_gr), passes=int(passes), interpret=interpret,
        use_ref=use_ref)
    return out.reshape(shape)
