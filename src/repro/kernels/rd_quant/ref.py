"""Pure-jnp oracle for the RD-quantization kernel (paper eq. 11)."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .coeffs import (SC_L0_SIG0, SC_L0_SIG1, SC_L1_SIG0, SC_L1_SIG1, SC_LNEG,
                     SC_LPOS)


def exp2_floor_log2(i: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(i)) for integer-valued f32 i >= 1, exact via the IEEE
    exponent field (f32 is exact for i < 2^24)."""
    bits = lax.bitcast_convert_type(i.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def level_rate(k: jnp.ndarray, prev_sig: jnp.ndarray, scalars: jnp.ndarray,
               mag_rate: jnp.ndarray, num_gr: int) -> jnp.ndarray:
    """Bits to code integer level array ``k`` (f32, integer-valued)."""
    s = scalars.reshape(-1)
    m = mag_rate.reshape(-1)
    ps = prev_sig.astype(jnp.float32)
    l0 = s[SC_L0_SIG0] * (1.0 - ps) + s[SC_L0_SIG1] * ps
    l1 = s[SC_L1_SIG0] * (1.0 - ps) + s[SC_L1_SIG1] * ps

    a = jnp.abs(k)
    small = a <= num_gr
    cls_small = jnp.maximum(a - 1.0, 0.0)
    i = jnp.maximum(a - num_gr, 1.0)
    cls_big = num_gr + exp2_floor_log2(i).astype(jnp.float32)
    cls = jnp.where(small, cls_small, cls_big).astype(jnp.int32)
    # one-hot select over the small class table (kernel-compatible: no gather)
    mag = jnp.zeros_like(a)
    for c in range(m.shape[0]):
        mag = mag + jnp.where(cls == c, m[c], 0.0)
    sign_cost = jnp.where(k < 0, s[SC_LNEG], s[SC_LPOS])
    return jnp.where(a == 0, l0, l1 + sign_cost + mag)


def rd_quant_ref(w: jnp.ndarray, fisher: jnp.ndarray, prev_sig: jnp.ndarray,
                 scalars: jnp.ndarray, mag_rate: jnp.ndarray, *, step: float,
                 lam: float, window: int, max_level: int,
                 num_gr: int) -> jnp.ndarray:
    """argmin_k F (w - step k)^2 + lam * rate(k) over k in a window around
    the nearest-neighbour level.  Shapes: all inputs elementwise-aligned."""
    w = w.astype(jnp.float32)
    f = fisher.astype(jnp.float32)
    nn = jnp.clip(jnp.round(w / step), -max_level, max_level)
    best_cost = jnp.full(w.shape, jnp.inf, dtype=jnp.float32)
    best_k = nn
    # window candidates + the zero level (large-lambda escape; see
    # core.quant.rd_assign)
    for d in list(range(-window, window + 1)) + [None]:
        k = (jnp.clip(nn + d, -max_level, max_level) if d is not None
             else jnp.zeros_like(nn))
        dist = f * jnp.square(w - step * k)
        rate = level_rate(k, prev_sig, scalars, mag_rate, num_gr)
        cost = dist + lam * rate
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_k = jnp.where(better, k, best_k)
    return best_k.astype(jnp.int32)
