"""Pallas TPU kernel for the eq. (11) RD assignment.

Tiling: the flattened weight tensor is viewed as (M, LANES) with
LANES = 1024 (8 sublanes x 128 lanes); each grid step processes a
(BLOCK_M, 1024) tile of w / fisher / prev_sig resident in VMEM
(3 x 1 MB in + 1 MB out at BLOCK_M = 256, f32), leaving headroom for the
unrolled candidate loop.  The rate model arrives as two tiny replicated
coefficient rows (see coeffs.py) so no dynamic gather is needed — the
magnitude-class select unrolls into compare/selects on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .coeffs import (SC_L0_SIG0, SC_L0_SIG1, SC_L1_SIG0, SC_L1_SIG1, SC_LNEG,
                     SC_LPOS)

LANES = 1024
BLOCK_M = 256


def _floor_log2(i: jnp.ndarray) -> jnp.ndarray:
    bits = lax.bitcast_convert_type(i.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _rate(k, ps, s_row, m_row, num_gr, n_classes):
    l0 = s_row[SC_L0_SIG0] * (1.0 - ps) + s_row[SC_L0_SIG1] * ps
    l1 = s_row[SC_L1_SIG0] * (1.0 - ps) + s_row[SC_L1_SIG1] * ps
    a = jnp.abs(k)
    small = a <= num_gr
    cls_small = jnp.maximum(a - 1.0, 0.0)
    i = jnp.maximum(a - num_gr, 1.0)
    cls_big = num_gr + _floor_log2(i).astype(jnp.float32)
    cls = jnp.where(small, cls_small, cls_big).astype(jnp.int32)
    mag = jnp.zeros_like(a)
    for c in range(n_classes):
        mag = mag + jnp.where(cls == c, m_row[c], 0.0)
    sign_cost = jnp.where(k < 0, s_row[SC_LNEG], s_row[SC_LPOS])
    return jnp.where(a == 0, l0, l1 + sign_cost + mag)


def _rd_quant_kernel(w_ref, f_ref, ps_ref, sc_ref, mag_ref, out_ref, *,
                     step, lam, window, max_level, num_gr, n_classes):
    w = w_ref[...]
    f = f_ref[...]
    ps = ps_ref[...]
    s_row = sc_ref[0, :]
    m_row = mag_ref[0, :]
    inv_step = 1.0 / step
    nn = jnp.clip(jnp.round(w * inv_step), -max_level, max_level)
    best_cost = jnp.full(w.shape, jnp.inf, dtype=jnp.float32)
    best_k = nn
    # window candidates + the zero level (large-lambda escape)
    for d in list(range(-window, window + 1)) + [None]:
        k = (jnp.clip(nn + d, -max_level, max_level) if d is not None
             else jnp.zeros_like(nn))
        dist = f * jnp.square(w - step * k)
        cost = dist + lam * _rate(k, ps, s_row, m_row, num_gr, n_classes)
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_k = jnp.where(better, k, best_k)
    out_ref[...] = best_k.astype(jnp.int32)


def rd_quant_pallas(w2d: jnp.ndarray, f2d: jnp.ndarray, ps2d: jnp.ndarray,
                    scalars: jnp.ndarray, mag_rate: jnp.ndarray, *,
                    step: float, lam: float, window: int, max_level: int,
                    num_gr: int, block_m: int = BLOCK_M,
                    interpret: bool = False) -> jnp.ndarray:
    """Inputs already shaped (M, LANES) with M % block_m == 0."""
    m = w2d.shape[0]
    n_classes = mag_rate.shape[-1]
    grid = (m // block_m,)
    tile = pl.BlockSpec((block_m, LANES), lambda i: (i, 0))
    rep_s = pl.BlockSpec((1, scalars.shape[-1]), lambda i: (0, 0))
    rep_m = pl.BlockSpec((1, n_classes), lambda i: (0, 0))
    kernel = functools.partial(
        _rd_quant_kernel, step=step, lam=lam, window=window,
        max_level=max_level, num_gr=num_gr, n_classes=n_classes)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, rep_s, rep_m],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((m, LANES), jnp.int32),
        interpret=interpret,
    )(w2d, f2d, ps2d, scalars, mag_rate)
