"""Kernel-op registry: one dispatch point for every compute kernel.

Every op (``rd_quant``, ``dequant_matmul``, ``flash_attention``,
``embed_lookup_q8``) registers an :class:`OpSpec` via :func:`register_op`:
named implementations (``pallas`` / ``interpret`` / ``ref`` / ...), a
tile-parameter search space, shape constraints, and a pure-jnp oracle.
Call sites then do::

    from repro import kernels
    out = kernels.get("dequant_matmul")(x, w_q, scale, policy=cfg.kernels)

and dispatch picks the implementation by platform (TPU -> pallas,
CPU -> interpret/ref), honors a single :class:`KernelPolicy`, consults the
persistent tuning cache (:mod:`repro.kernels.tune`) for tile parameters at
trace time, and surfaces every constraint-driven fallback through
:func:`dispatch_report` instead of downgrading silently.  Requesting an
impl explicitly (a policy override) that cannot run raises under
``KernelPolicy(strict=True)``.

Dispatch happens at Python call time — inside a ``jax.jit`` that is trace
time, so impl/tile choices are compile-time constants and repeated calls
with cached shapes pay no dispatch overhead.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import jax


class KernelDispatchError(RuntimeError):
    """An explicitly requested impl cannot run under the given policy."""


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelPolicy:
    """Session-wide kernel selection policy (hashable; lives on ModelConfig).

    platform        "auto" (jax.default_backend()) or a pin ("tpu"/"cpu").
    strict          a constraint-driven fallback on an *explicitly
                    requested* impl raises instead of downgrading.
    use_tuning_cache  consult the persistent tuning cache for tile params.
    overrides       ((op, impl), ...) per-op impl pins.
    tile_overrides  ((op, ((param, value), ...)), ...) per-op tile pins
                    (win over both defaults and the tuning cache).
    """

    platform: str = "auto"
    strict: bool = False
    use_tuning_cache: bool = True
    overrides: tuple = ()
    tile_overrides: tuple = ()

    def impl_for(self, op: str) -> str | None:
        for name, impl in self.overrides:
            if name == op:
                return impl
        return None

    def tiles_for(self, op: str) -> dict:
        for name, tiles in self.tile_overrides:
            if name == op:
                return dict(tiles)
        return {}

    def override(self, op: str, impl: str) -> "KernelPolicy":
        """Return a policy with ``op`` pinned to ``impl`` (replaces any
        existing pin for the same op — idempotent)."""
        kept = tuple((n, i) for n, i in self.overrides if n != op)
        return dataclasses.replace(self, overrides=kept + ((op, impl),))

    def with_tiles(self, op: str, **tiles) -> "KernelPolicy":
        kept = tuple((n, t) for n, t in self.tile_overrides if n != op)
        pin = (op, tuple(sorted(tiles.items())))
        return dataclasses.replace(self, tile_overrides=kept + (pin,))


DEFAULT_POLICY = KernelPolicy()


# ---------------------------------------------------------------------------
# Op specification
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Impl:
    """One named implementation of an op.

    fn          callable with the op's public signature, plus the op's tile
                parameters as keyword arguments when ``uses_tiles``.
    platforms   backends the impl can execute on.
    constraint  shapes-dict -> None (ok) or a human-readable reason string.
    """

    name: str
    fn: Callable
    platforms: tuple = ("cpu", "gpu", "tpu")
    constraint: Callable | None = None
    uses_tiles: bool = True


@dataclass
class OpSpec:
    """Registered kernel op: impls, platform defaults, tile search space.

    defaults     platform -> impl name; "*" is the required catch-all.
    route        optional shape-based routing hook consulted before
                 ``defaults`` when no impl is pinned: (shapes, platform)
                 -> impl name or None.  Use it for *designed* shape
                 routing (e.g. decode -> scan) so the choice is not
                 reported as a constraint fallback.
    fallbacks    ordered impl names to try when the primary choice fails
                 its constraint or platform check.
    tile_space   tile param -> candidate values (the autotune sweep).
    default_tiles  shapes-dict -> tile dict (shape-adaptive defaults).
    tile_ok      (shapes, tiles) -> bool filter over the search space.
    shape_info   (*args, **kwargs) -> shapes dict fed to constraints,
                 default_tiles and bucket.
    bucket       shapes-dict -> tuning-cache key segment.
    example_inputs  shape tuple -> (args, kwargs) for autotune/benchmarks.
    oracle       pure-jnp reference callable (differential tests).
    tune_impls   platform -> impl name the autotuner times ("*" catch-all).
    """

    name: str
    impls: dict
    defaults: dict
    route: Callable | None = None
    fallbacks: tuple = ()
    tile_space: dict = field(default_factory=dict)
    default_tiles: Callable | None = None
    tile_ok: Callable | None = None
    shape_info: Callable = lambda *a, **k: {}
    bucket: Callable | None = None
    example_inputs: Callable | None = None
    oracle: Callable | None = None
    tune_impls: dict = field(default_factory=dict)


_OPS: dict[str, OpSpec] = {}
_REPORT: deque = deque(maxlen=512)


def register_op(build: Callable[[], OpSpec]) -> Callable[[], OpSpec]:
    """Decorator: ``build`` returns an OpSpec, registered at import time."""
    op = build()
    _OPS[op.name] = op
    return build


def available_ops() -> list[str]:
    return sorted(_OPS)


def spec(name: str) -> OpSpec:
    if name not in _OPS:
        raise KeyError(
            f"unknown kernel op {name!r}; available: {available_ops()}")
    return _OPS[name]


def dispatch_report() -> list[dict]:
    """Constraint-driven fallbacks observed so far (most recent last).

    Each record: {op, platform, requested, impl, reason}.  ``requested`` is
    the impl the policy asked for (None when the platform default fell
    back), ``impl`` what actually ran."""
    return list(_REPORT)


def clear_dispatch_report() -> None:
    _REPORT.clear()


def record_event(*, op: str, platform: str, impl: str, reason: str,
                 requested: str | None = None, kind: str = "event") -> None:
    """Append a non-dispatch event to the report stream.

    Dispatch itself records constraint-driven fallbacks automatically;
    this hook is for adjacent decisions that must be just as loud — a
    tile clamp at kernel dispatch (``kind="tile_clamp"``), a loop-body
    dequantize of a tensor the fused q8 path can't take
    (``kind="loop_dequant"``).  Records share the fallback schema
    ({op, platform, requested, impl, reason}) plus ``kind``, so existing
    ``dispatch_report()`` consumers keep working and new ones can filter
    by kind.  Call sites fire at trace time (inside ``jax.jit`` tracing),
    so a recorded event costs nothing per executed step."""
    _REPORT.append({"op": op, "platform": platform, "requested": requested,
                    "impl": impl, "reason": reason, "kind": kind})


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DispatchPlan:
    """What :class:`BoundOp` decided for one call, without executing it."""

    op: str
    platform: str
    requested: str | None        # explicit policy pin, if any
    impl: str                    # impl that will run
    tiles: tuple                 # ((param, value), ...) sorted
    fallback_reason: str | None  # why the primary choice was downgraded
    cache_hit: bool              # tiles came from the tuning cache


class BoundOp:
    """Callable handle returned by :func:`get`; dispatches on call."""

    def __init__(self, op_spec: OpSpec):
        self.spec = op_spec

    def __repr__(self):
        return f"BoundOp({self.spec.name!r}, impls={sorted(self.spec.impls)})"

    def plan(self, *args, policy: KernelPolicy | None = None,
             **kwargs) -> DispatchPlan:
        """Resolve platform, impl and tiles for these arguments."""
        s = self.spec
        policy = policy or DEFAULT_POLICY
        platform = (policy.platform if policy.platform != "auto"
                    else jax.default_backend())
        shapes = s.shape_info(*args, **kwargs)
        requested = policy.impl_for(s.name)
        if requested is not None and requested not in s.impls:
            raise KeyError(
                f"{s.name}: unknown impl {requested!r}; "
                f"available: {sorted(s.impls)}")
        primary = requested
        if primary is None and s.route is not None:
            primary = s.route(shapes, platform)
        if primary is None:
            primary = s.defaults.get(platform, s.defaults["*"])

        reason = None
        chosen = None
        for cand in [primary] + [f for f in s.fallbacks if f != primary]:
            impl = s.impls.get(cand)
            if impl is None:
                continue
            if platform not in impl.platforms:
                why = f"impl {cand!r} unavailable on platform {platform!r}"
            else:
                why = impl.constraint(shapes) if impl.constraint else None
            if why is None:
                chosen = cand
                break
            if cand == primary:
                reason = why
        if chosen is None:
            raise KernelDispatchError(
                f"{s.name}: no feasible impl on {platform!r} "
                f"(primary {primary!r}: {reason})")

        tiles: dict = {}
        cache_hit = False
        impl = s.impls[chosen]
        if impl.uses_tiles and s.tile_space:
            if s.default_tiles is not None:
                tiles.update(s.default_tiles(shapes))
            if policy.use_tuning_cache and s.bucket is not None:
                from . import tune
                hit = tune.lookup(s.name, platform, s.bucket(shapes))
                if hit:
                    tiles.update(hit)
                    cache_hit = True
            tiles.update(policy.tiles_for(s.name))
        return DispatchPlan(
            op=s.name, platform=platform, requested=requested, impl=chosen,
            tiles=tuple(sorted(tiles.items())),
            fallback_reason=reason if chosen != primary else None,
            cache_hit=cache_hit)

    def __call__(self, *args, policy: KernelPolicy | None = None, **kwargs):
        plan = self.plan(*args, policy=policy, **kwargs)
        if plan.fallback_reason is not None:
            _REPORT.append({
                "op": plan.op, "platform": plan.platform,
                "requested": plan.requested, "impl": plan.impl,
                "reason": plan.fallback_reason, "kind": "fallback",
            })
            if (policy is not None and policy.strict
                    and plan.requested is not None):
                raise KernelDispatchError(
                    f"{plan.op}: requested impl {plan.requested!r} cannot "
                    f"run ({plan.fallback_reason}) and policy is strict")
        impl = self.spec.impls[plan.impl]
        tiles = dict(plan.tiles) if impl.uses_tiles else {}
        return impl.fn(*args, **kwargs, **tiles)


def get(name: str) -> BoundOp:
    """Look up a registered op; the returned handle dispatches per call."""
    return BoundOp(spec(name))
