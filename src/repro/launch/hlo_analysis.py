"""Trip-count-aware FLOP/byte analysis of post-SPMD HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
10-iteration scan of matmuls reports 1 matmul of FLOPs), which silently
undercounts every scanned-layer model by ~L x.  This module re-derives
per-device FLOPs and HBM traffic from the HLO text with loop-body
multiplicities:

* FLOPs: dot ops only (2 * prod(result dims) * prod(contracted dims)),
  which dominates transformer arithmetic; elementwise FLOPs are absorbed
  into the bytes term where they belong (they are bandwidth-bound).
* bytes: for every op in an executable computation, result bytes + operand
  bytes (fusion internals excluded — a fusion's callsite accounts its
  inputs/outputs, matching what HBM actually sees under XLA fusion).
* multiplicities: while bodies multiplied by the trip count extracted from
  the loop condition (shared with the collective accounting in dryrun.py).
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")
_DEF_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
                          r"(?P<res>\(?[^=]*?\)?)\s+(?P<op>[\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter",
                   "constant", "after-all", "partition-id", "replica-id"}

# data-movement ops: traffic is the RESULT slice (read + write), never the
# full operand — a dynamic-slice pulling one layer's weights from the
# (L, ...) scan stack touches 1/L of the stack, not all of it
_RESULT_ONLY_OPS = {"dynamic-slice", "slice", "gather", "reshape",
                    "transpose", "copy", "broadcast", "concatenate",
                    "reverse", "pad", "iota"}

# converts fuse into their consumers on TPU (dequantize-in-core: int8 HBM
# reads feed the MXU without a round-trip) — charge no traffic for the
# convert itself and resolve consumer operand reads through it to the
# storage dtype (this is what makes int8 weights/KV show their real
# bandwidth win in the roofline)
_ALIAS_OPS = {"convert"}


def _shape_dims(text: str) -> list[tuple[int, list[int]]]:
    """All (elem_bytes, dims) array shapes in a type string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((_DTYPE_BYTES[m.group(1)], dims))
    return out


def _nbytes(text: str, bf16_adjust: bool = False) -> int:
    """bf16_adjust: count f32 arrays at 2 B/elem — the CPU backend legalizes
    bf16 compute to f32, so f32 buffers in the lowered module are bf16 on
    the TPU target (intentional f32 — logits, softmax stats — is a small
    fraction; the adjusted number is the TPU-target estimate, the raw
    number the upper bound)."""
    total = 0
    for eb, dims in _shape_dims(text):
        if bf16_adjust and eb == 4:
            eb = 2
        n = 1
        for d in dims:
            n *= d
        total += eb * n
    return total


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")


def fusion_callees(text: str) -> list[str]:
    return _CALLS_RE.findall(text)


def analyze_computation(text: str) -> tuple[float, float, float]:
    """(flops, bytes, bytes_bf16_adjusted) for one computation, once."""
    # symbol table: name -> full type string (shape incl. tuples)
    sym: dict[str, str] = {}
    alias: dict[str, str] = {}
    for line in text.splitlines():
        m = _DEF_LINE_RE.match(line)
        if m:
            sym[m.group("name")] = m.group("res")
            if m.group("op") in _ALIAS_OPS:
                call = line.split("(", 1)[1] if "(" in line else ""
                ops = _OPERAND_RE.findall(call.split(")", 1)[0])
                if ops:
                    alias[m.group("name")] = ops[0]

    def resolve(name: str) -> str:
        for _ in range(8):
            if name in alias:
                name = alias[name]
            else:
                break
        return name

    flops = 0.0
    nbytes = 0.0
    nbytes_adj = 0.0
    for line in text.splitlines():
        m = _DEF_LINE_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        res = m.group("res")
        if op == "dot":
            call = line.split("dot(", 1)[1]
            args = call.split(")", 1)[0]
            ops = _OPERAND_RE.findall(args)
            cm = _CONTRACT_RE.search(line)
            contract = 1
            if ops and cm is not None:
                lhs_shape = _shape_dims(sym.get(ops[0], ""))
                if lhs_shape:
                    dims = lhs_shape[0][1]
                    for idx in (cm.group(1).split(",")
                                if cm.group(1) else []):
                        i = int(idx)
                        if i < len(dims):
                            contract *= dims[i]
            n_res = 1
            for eb, dims in _shape_dims(res)[:1]:
                for d in dims:
                    n_res *= d
            flops += 2.0 * n_res * contract
        if op in _SKIP_BYTES_OPS or op in _ALIAS_OPS:
            continue
        if op.endswith("-done"):
            continue
        call = line.split("(", 1)[1] if "(" in line else ""
        args = call.split(")", 1)[0]
        operands = [n for n in _OPERAND_RE.findall(args)]
        if op == "dynamic-update-slice":
            # in-place on TPU: traffic = the updated slice (write + read),
            # not the whole buffer
            upd = sym.get(operands[1], "") if len(operands) > 1 else ""
            nbytes += 2 * _nbytes(upd)
            nbytes_adj += 2 * _nbytes(upd, True)
            continue
        if op in _RESULT_ONLY_OPS:
            nbytes += 2 * _nbytes(res)
            nbytes_adj += 2 * _nbytes(res, True)
            continue
        b = _nbytes(res)
        ba = _nbytes(res, True)
        # operand reads: resolved through convert aliases to storage dtype
        for name in operands:
            src = resolve(name)
            if src in sym:
                b += _nbytes(sym[src])
                ba += _nbytes(sym[src], True)
            elif name in sym:
                b += _nbytes(sym[name])
                ba += _nbytes(sym[name], True)
        nbytes += b
        nbytes_adj += ba
    return flops, nbytes, nbytes_adj


def trip_aware_cost(hlo_text: str, comps: dict[str, str],
                    mult: dict[str, float]) -> dict:
    raw = {name: analyze_computation(text) for name, text in comps.items()}
    flops = 0.0
    nbytes = 0.0
    nbytes_adj = 0.0
    per_comp = {}
    for name, m in mult.items():
        text = comps.get(name)
        if text is None:
            continue
        f, b, ba = raw[name]
        # dots fused into kLoop/kOutput fusions (e.g. M=1 matvecs on CPU)
        # live in the fusion body computation — count their flops at the
        # callsite's multiplicity (bytes stay at the fusion boundary)
        for callee in fusion_callees(text):
            if callee in raw:
                f += raw[callee][0]
        per_comp[name] = {"mult": m, "flops": f, "bytes": b}
        flops += f * m
        nbytes += b * m
        nbytes_adj += ba * m
    return {"flops": flops, "bytes": nbytes, "bytes_bf16": nbytes_adj,
            "per_comp": per_comp}
