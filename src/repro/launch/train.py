"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); omit it on a real
TPU slice to train the full assigned configuration.  The loop checkpoints
(DeepCABAC-compressed), resumes after restarts, EF-compresses the cross-pod
gradient stream when ``--compress-grads`` is set, and reports straggler
steps.
"""

from __future__ import annotations

import argparse

import jax

from ..checkpoint.manager import CheckpointConfig
from .. import configs
from ..configs import ARCH_IDS
from ..distributed.compress import CompressionConfig
from ..optim.adamw import AdamWConfig
from ..train.loop import LoopConfig, train_loop
from .mesh import make_local_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = len(jax.devices())
        mesh = make_local_mesh(data=n, model=1)
    loop = LoopConfig(total_steps=args.steps, batch=args.batch,
                      seq=args.seq, ckpt_every=args.ckpt_every)
    ckpt = (CheckpointConfig(args.ckpt_dir, params_mode="cabac",
                             async_save=True)
            if args.ckpt_dir else None)
    res = train_loop(cfg, mesh, loop,
                     opt_cfg=AdamWConfig(lr=args.lr),
                     comp_cfg=CompressionConfig(enabled=args.compress_grads),
                     ckpt_cfg=ckpt)
    print(f"steps={res.final_step} first_loss={res.losses[0]:.4f} "
          f"last_loss={res.losses[-1]:.4f} "
          f"stragglers={len(res.straggler_steps)}")


if __name__ == "__main__":
    main()
