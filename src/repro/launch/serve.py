"""Serving entry point: batched generation, optionally from a DeepCABAC
container.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --ckpt /tmp/model.dcbc --batch 4 --prompt-len 16 --steps 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models.transformer import init_params
from ..serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="DeepCABAC container (.dcbc); random init if unset")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.prompt_len + args.steps
    if args.ckpt:
        with open(args.ckpt, "rb") as f:
            engine = ServeEngine.from_compressed(cfg, f.read(),
                                                 max_len=max_len)
    else:
        engine = ServeEngine(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                             max_len=max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, steps=args.steps,
                          temperature=args.temperature)
    print(f"generated {out.shape} tokens; first row tail: "
          f"{out[0, -min(16, out.shape[1]):].tolist()}")


if __name__ == "__main__":
    main()
