"""Serving entry point: request-level continuous batching over a
pluggable weight backend, optionally from a DeepCABAC container.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --ckpt /tmp/model.dcbc --backend container --batch 4 \
        --prompt-len 16 --steps 32

``--backend``: ``bf16`` (full-precision weights), ``q8`` (in-memory int8
fixed-point matmul weights), ``container`` (stream-decode the DCBC blob;
serve-q8 records stay int8).  Without ``--ckpt`` the bf16/q8 backends use
random init; the container backend packs a serve-q8 container in-process
first so the streaming load path is still exercised.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from .. import kernels
from .. import configs
from ..configs import ARCH_IDS
from ..models.transformer import init_params
from ..serve.backends import available_backends
from ..serve.session import ServeConfig, ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="DeepCABAC container (.dcbc); random init if unset")
    ap.add_argument("--backend", choices=available_backends(),
                    default="bf16", help="weight backend (see serve/backends)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="KV slots (0 = one per request)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kernel-impl", action="append", default=[],
                    metavar="OP=IMPL",
                    help="pin a kernel impl (repeatable), e.g. "
                         "flash_attention=pallas dequant_matmul=interpret")
    ap.add_argument("--strict-kernels", action="store_true",
                    help="a pinned impl that cannot run raises instead of "
                         "falling back (see kernels.dispatch_report)")
    ap.add_argument("--no-tuning-cache", action="store_true",
                    help="ignore the persistent kernel tuning cache")
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    pol = cfg.kernels
    for pin in args.kernel_impl:
        op, _, impl = pin.partition("=")
        if op not in kernels.available_ops():
            ap.error(f"--kernel-impl: unknown op {op!r}; "
                     f"available: {kernels.available_ops()}")
        if impl not in kernels.spec(op).impls:
            ap.error(f"--kernel-impl: unknown impl {impl!r} for {op}; "
                     f"available: {sorted(kernels.spec(op).impls)}")
        pol = pol.override(op, impl)
    pol = dataclasses.replace(pol, strict=args.strict_kernels,
                              use_tuning_cache=not args.no_tuning_cache)
    cfg = cfg.replace(kernels=pol)
    max_len = args.prompt_len + args.steps
    if args.ckpt:
        with open(args.ckpt, "rb") as f:
            weights = f.read()
    elif args.backend == "container":
        from .. import compression
        params = init_params(cfg, jax.random.PRNGKey(0))
        weights = compression.get("serve-q8").compress(params).blob
        print(f"packed serve-q8 container in-process: "
              f"{len(weights) / 2**20:.1f} MiB")
    else:
        weights = init_params(cfg, jax.random.PRNGKey(0))

    scfg = ServeConfig(slots=args.slots or args.batch, max_len=max_len)
    session = ServeSession(cfg, weights, backend=args.backend,
                           serve_cfg=scfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    handles = [session.submit(p, max_new_tokens=args.steps,
                              temperature=args.temperature)
               for p in prompts]
    session.run()
    out = np.stack([h.result() for h in handles])
    print(f"backend={args.backend} slots={scfg.slots}: generated "
          f"{out.shape} tokens; first row tail: "
          f"{out[0, -min(16, out.shape[1]):].tolist()}")
    for rec in kernels.dispatch_report():
        print(f"kernel fallback: {rec['op']}: "
              f"{rec['requested'] or 'default'} -> {rec['impl']} "
              f"({rec['reason']})")


if __name__ == "__main__":
    main()
