"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for tests/examples on whatever devices exist."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
