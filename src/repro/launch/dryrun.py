import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod, 2x16x16
multi-pod), resolves all input/state shardings, lowers the appropriate step
(train_step for train shapes, prefill for prefill shapes, serve_step for
decode shapes) against ShapeDtypeStruct stand-ins (no allocation), compiles,
and records:

  - memory_analysis()           (proves the per-device footprint)
  - cost_analysis()             (HLO FLOPs / bytes for the roofline)
  - collective bytes            (parsed from the post-SPMD HLO text)

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json; the
roofline report (benchmarks/roofline.py) reads them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--skip-existing]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import configs
from ..configs import ARCH_IDS, SHAPES, shapes_for
from ..distributed.compress import CompressionConfig
from ..distributed.sharding import (DEFAULT_RULES, PREFILL_RULES,
                                    SERVE_RULES)
from ..models.transformer import init_cache, init_params
from ..optim.adamw import AdamWConfig, adamw_init
from ..train.steps import (batch_specs, cache_logical_specs,
                           init_train_state, make_decode_step,
                           make_prefill_step, make_train_step, state_specs)
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# 8-bit Adam moments where the fp32-moment footprint does not fit 16 GB HBM
# at 256 chips (see DESIGN.md §5).
Q8_MOMENT_ARCHS = {"deepseek-v3-671b"}


def opt_config(arch: str) -> AdamWConfig:
    return AdamWConfig(quantized_moments=arch in Q8_MOMENT_ARCHS)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(arch: str, shape_name: str) -> dict:
    """Model inputs for one cell, as ShapeDtypeStructs."""
    cfg = configs.get(arch)
    seq, batch, kind = SHAPES[shape_name]
    out: dict = {}
    if kind == "train":
        if cfg.embed_input:
            out["tokens"] = _sds((batch, seq), jnp.int32)
        else:
            out["embeds"] = _sds((batch, seq, cfg.d_model), cfg.compute_dtype)
        out["labels"] = _sds((batch, seq), jnp.int32)
        if cfg.m_rope:
            out["pos3d"] = _sds((3, batch, seq), jnp.int32)
    elif kind == "prefill":
        if cfg.embed_input:
            out["tokens"] = _sds((batch, seq), jnp.int32)
        else:
            out["embeds"] = _sds((batch, seq, cfg.d_model), cfg.compute_dtype)
        if cfg.m_rope:
            out["pos3d"] = _sds((3, batch, seq), jnp.int32)
    else:  # decode: one new token against a seq_len KV/state cache
        if cfg.embed_input:
            out["tokens"] = _sds((batch,), jnp.int32)
        else:
            out["embeds"] = _sds((batch, 1, cfg.d_model), cfg.compute_dtype)
        if cfg.m_rope:
            out["pos3d"] = _sds((3, batch, 1), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Collective-bytes accounting from post-SPMD HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# defining line: `%name = <result shape(s)> <kind>[-start](operands...)`
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<res>.*?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _group_size(line: str, n_chips: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))     # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_chips


def _wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    """Per-device ICI traffic estimate (ring algorithms).

    all-reduce: 2*S*(n-1)/n of the (operand==result) size S;
    all-gather: result holds the gathered array, each device receives
    S*(n-1)/n; reduce-scatter: operand = result*n, wire = result*(n-1);
    all-to-all: each device exchanges (n-1)/n of its data (result size);
    collective-permute: result size.
    """
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "all-gather":
        return result_bytes * f
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)
    if kind == "all-to-all":
        return result_bytes * f
    return float(result_bytes)     # collective-permute


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+).*?body=%?"
                       r"([\w.\-]+)", re.S)
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALLSITE_RE = re.compile(
    r"(?:condition|body|to_apply|branch_computations=\{)[=%]*%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> tuple[dict[str, str], str]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = ""
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}, entry


def _trip_count(cond_text: str) -> int:
    consts = [int(m.group(1)) for m in _S32_CONST_RE.finditer(cond_text)]
    return max(consts) if consts else 1


def computation_multiplicities(hlo_text: str):
    """(computations, entry_name, multiplicity per executable computation)
    with while-body trip counts propagated through the call graph."""
    comps, entry = _split_computations(hlo_text)
    body_trip: dict[str, int] = {}
    for text in comps.values():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            body_trip[body] = _trip_count(comps.get(cond, ""))
    mult: dict[str, float] = {}
    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        if m <= mult.get(name, 0.0):
            continue
        mult[name] = m
        text = comps.get(name, "")
        for cm in _CALLSITE_RE.finditer(text):
            callee = cm.group(1)
            if callee not in comps:
                continue
            factor = body_trip.get(callee, 1)
            stack.append((callee, m * factor))
    return comps, entry, mult


def collective_bytes(hlo_text: str, n_chips: int) -> dict:
    """Per-device collective traffic from the post-SPMD HLO.

    Collectives inside while bodies (the layer scan) are multiplied by the
    loop trip count, extracted from the loop condition's s32 bound.  Only
    defining lines count (`-done` carries no new traffic); result shapes in
    the partitioned module are already per-device.  Records both raw result
    bytes and a ring-algorithm wire estimate per kind.
    """
    comps, entry, mult = computation_multiplicities(hlo_text)

    per_kind = {k: 0.0 for k in _COLL_KINDS}
    wire_kind = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for name, text in comps.items():
        m = mult.get(name, 1.0)
        for line in text.splitlines():
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            kind = dm.group("kind")
            b = _shape_list_bytes(dm.group("res"))
            if dm.group("start") and kind in ("all-reduce", "reduce-scatter"):
                b //= 2   # async start result carries (operand, result)
            n = _group_size(line, n_chips)
            per_kind[kind] += b * m
            wire_kind[kind] += _wire_bytes(kind, b, n) * m
            counts[kind] += 1
    return {"per_device_bytes": per_kind,
            "wire_bytes": wire_kind,
            "op_counts": counts,
            "total_per_device_bytes": sum(per_kind.values()),
            "total_wire_bytes": sum(wire_kind.values())}


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, cfg_overrides=None,
               int8_serving: bool = False):
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    seq, batch, kind = SHAPES[shape_name]
    if kind == "train":
        rules = DEFAULT_RULES
    elif kind == "prefill":
        rules = PREFILL_RULES
        if arch == "deepseek-v3-671b":
            # the 1.3 TB expert bank cannot replicate over data at 16-way
            # EP: shard the expert d/f dims FSDP-style over "data" — the
            # per-layer weight gathers amortize over 1M prefill tokens
            # (§Perf iteration: 256-way-EP serve rules produced 827 s of
            # collectives from unsharded dispatch groups)
            rules = {**PREFILL_RULES, "fsdp": "data"}
    else:
        rules = SERVE_RULES
    inputs = input_specs(arch, shape_name)

    if kind == "train":
        ocfg = opt_config(arch)
        ccfg = CompressionConfig(enabled=False)
        state_shape = jax.eval_shape(
            lambda: init_train_state(cfg, ocfg, ccfg))
        step_fn, _ = make_train_step(cfg, mesh, ocfg, ccfg)
        st_specs = state_specs(state_shape, mesh, rules)
        b_specs = batch_specs(inputs, mesh, rules)
        jitted = jax.jit(step_fn,
                         in_shardings=(_shardings(st_specs, mesh),
                                       _shardings(b_specs, mesh)),
                         donate_argnums=(0,))
        return jitted.lower(state_shape, inputs)

    if int8_serving:
        # fixed-point serving (paper §III-C-1): int8 weights + int8 KV cache
        from ..serve.quantized import quantize_params_for_serving
        cfg = cfg.replace(q8_cache=True)
        params_shape = jax.eval_shape(
            lambda: quantize_params_for_serving(
                init_params(cfg, jax.random.PRNGKey(0))))
    else:
        params_shape = jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0)))
    from ..distributed.sharding import build_param_specs
    p_specs = build_param_specs(params_shape, mesh, rules)

    if kind == "prefill":
        step_fn = make_prefill_step(cfg, mesh, rules, max_len=seq)
        b_specs = batch_specs(inputs, mesh, rules)
        cache_shape = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
        c_specs = cache_logical_specs(cache_shape, mesh, rules)
        out_sh = (NamedSharding(mesh, P(None, None)),
                  _shardings(c_specs, mesh))
        jitted = jax.jit(step_fn,
                         in_shardings=(_shardings(p_specs, mesh),
                                       _shardings(b_specs, mesh)),
                         out_shardings=out_sh)
        return jitted.lower(params_shape, inputs)

    # decode
    cache_shape = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
    c_specs = cache_logical_specs(cache_shape, mesh, rules)
    step_fn = make_decode_step(cfg, mesh, rules)
    in_specs = batch_specs(inputs, mesh, rules)
    jitted = jax.jit(
        step_fn,
        in_shardings=(_shardings(p_specs, mesh),
                      _shardings(c_specs, mesh),
                      _shardings(in_specs, mesh), NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(None, None)),
                       _shardings(c_specs, mesh)),
        donate_argnums=(1,))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(params_shape, cache_shape, inputs, pos)


def analyze(lowered, compiled, n_chips: int) -> dict:
    from .hlo_analysis import trip_aware_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax returned [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text, n_chips)
    comps, _, mult = computation_multiplicities(hlo_text)
    ta = trip_aware_cost(hlo_text, comps, mult)
    return {
        # cost_analysis counts while bodies once (verified); the trip-aware
        # numbers below are the roofline inputs
        "flops_per_device_xla": float(cost.get("flops", 0.0)),
        "bytes_per_device_xla": float(cost.get("bytes accessed", 0.0)),
        "flops_per_device": ta["flops"],
        "bytes_per_device": ta["bytes"],
        "bytes_per_device_bf16": ta["bytes_bf16"],
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "live_bytes_est": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        },
        "n_chips": n_chips,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             cfg_overrides=None, int8_serving: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = 512 if multi else 256
    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh, cfg_overrides, int8_serving)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    print(compiled.memory_analysis())
    cost = compiled.cost_analysis()
    print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
    res = analyze(lowered, compiled, n_chips)
    res.update({"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2)})
    return res


def cell_path(arch, shape, mesh_kind, suffix=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--int8", action="store_true",
                    help="fixed-point serving (int8 weights + KV cache)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in shapes_for(arch):
                for mesh_kind in ("single", "multi"):
                    cells.append((arch, shape, mesh_kind, False))
                # int8 fixed-point serving variant for the serve shapes
                if SHAPES[shape][2] == "decode":
                    cells.append((arch, shape, "single", True))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.mesh, args.int8)]

    failures = []
    for arch, shape, mesh_kind, int8 in cells:
        suffix = "__int8" if int8 else ""
        path = cell_path(arch, shape, mesh_kind, suffix)
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {arch} {shape} {mesh_kind}{suffix}")
            continue
        print(f"=== {arch} | {shape} | {mesh_kind}{suffix} ===", flush=True)
        try:
            res = run_cell(arch, shape, mesh_kind, int8_serving=int8)
            res["int8_serving"] = int8
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"[ok] lower={res['lower_s']}s compile={res['compile_s']}s "
                  f"coll={res['collectives']['total_per_device_bytes']/1e6:.1f}MB/dev",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append((arch, shape, mesh_kind, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("all cells compiled OK")


if __name__ == "__main__":
    main()
