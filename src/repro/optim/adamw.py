"""AdamW with optional 8-bit block-quantized moments.

The 8-bit variant stores first/second moments as int8 with per-block (128
along the last axis) absmax scales — the same quantize-where-you-store
philosophy as the paper, applied to optimizer state.  At 671B params this is
the difference between Adam state fitting a 16 GB v5e or not
(fp32 m+v = 8 B/param -> int8 m+v + scales ~ 2.06 B/param).

Pure pytree-functional: ``state = adamw_init(params, cfg)``;
``updates, state = adamw_update(grads, state, params, cfg, step)``.
All ops are elementwise/jit-friendly and shard trivially under pjit (scales
inherit the blocking of the last axis, which is the TP axis blocking).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..compression.q8 import (Q8_BLOCK as MOMENT_BLOCK, q8_decode,  # noqa: F401
                              q8_decode_sqrt, q8_encode, q8_encode_sqrt,
                              q8_scale_shape)

# Back-compat aliases — the 8-bit moment codecs moved to
# repro.compression.q8 so distributed/serve share them without reaching
# into optimizer privates.
_q8_encode = q8_encode
_q8_decode = q8_decode
_q8_encode_sqrt = q8_encode_sqrt
_q8_decode_sqrt = q8_decode_sqrt
_moment_scale_shape = q8_scale_shape


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_moments: bool = False   # int8 m/v with blockwise scales


# -- init / update ------------------------------------------------------------

def adamw_init(params, cfg: AdamWConfig):
    def zeros_like_moment(p):
        if cfg.quantized_moments:
            return {
                "m_q": jnp.zeros(p.shape, jnp.int8),
                "m_s": jnp.zeros(_moment_scale_shape(p.shape), jnp.float32),
                "v_q": jnp.zeros(p.shape, jnp.int8),
                "v_s": jnp.zeros(_moment_scale_shape(p.shape), jnp.float32),
            }
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}

    return {"count": jnp.zeros((), jnp.int32),
            "moments": jax.tree.map(zeros_like_moment, params)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mom):
        g = g.astype(jnp.float32) * clip
        if cfg.quantized_moments:
            m = _q8_decode(mom["m_q"], mom["m_s"])
            v = _q8_decode_sqrt(mom["v_q"], mom["v_s"])
        else:
            m, v = mom["m"], mom["v"]
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if cfg.quantized_moments:
            m_q, m_s = _q8_encode(m)
            v_q, v_s = _q8_encode_sqrt(v)
            return new_p, {"m_q": m_q, "m_s": m_s, "v_q": v_q, "v_s": v_s}
        return new_p, {"m": m, "v": v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["moments"])
    new_p, new_m = [], []
    for p, g, mom in zip(flat_p, flat_g, flat_m):
        np_, nm_ = upd(p, g, mom)
        new_p.append(np_)
        new_m.append(nm_)
    return (jax.tree.unflatten(treedef, new_p),
            {"count": count, "moments": jax.tree.unflatten(treedef, new_m)})
