"""AdamW with optional 8-bit block-quantized moments.

The 8-bit variant stores first/second moments as int8 with per-block (128
along the last axis) absmax scales — the same quantize-where-you-store
philosophy as the paper, applied to optimizer state.  At 671B params this is
the difference between Adam state fitting a 16 GB v5e or not
(fp32 m+v = 8 B/param -> int8 m+v + scales ~ 2.06 B/param).

Pure pytree-functional: ``state = adamw_init(params, cfg)``;
``updates, state = adamw_update(grads, state, params, cfg, step)``.
All ops are elementwise/jit-friendly and shard trivially under pjit (scales
inherit the blocking of the last axis, which is the TP axis blocking).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

MOMENT_BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_moments: bool = False   # int8 m/v with blockwise scales


# -- 8-bit moment codecs ------------------------------------------------------

def _blockable(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 1 and shape[-1] % MOMENT_BLOCK == 0


def _q8_encode(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (int8 codes, float32 blockwise scales)."""
    if _blockable(x.shape):
        b = x.reshape(*x.shape[:-1], x.shape[-1] // MOMENT_BLOCK, MOMENT_BLOCK)
        scale = jnp.max(jnp.abs(b), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        codes = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
        return codes.reshape(x.shape), scale.squeeze(-1).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _q8_decode(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    if codes.ndim >= 1 and codes.shape[-1] % MOMENT_BLOCK == 0 and \
            scale.ndim == codes.ndim:
        b = codes.reshape(*codes.shape[:-1],
                          codes.shape[-1] // MOMENT_BLOCK, MOMENT_BLOCK)
        return (b.astype(jnp.float32) * scale[..., None]).reshape(codes.shape)
    return codes.astype(jnp.float32) * scale


def _q8_encode_sqrt(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Second moment in sqrt-space: v spans many orders of magnitude, so
    linear absmax codes flush small entries to zero and destabilize
    1/sqrt(v).  Quantizing sqrt(v) halves the dynamic range in log terms —
    the same trick 8-bit optimizers use via nonlinear quantization maps."""
    return _q8_encode(jnp.sqrt(jnp.maximum(v, 0.0)))


def _q8_decode_sqrt(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    r = _q8_decode(codes, scale)
    return jnp.square(r)


def _moment_scale_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    if _blockable(shape):
        return (*shape[:-1], shape[-1] // MOMENT_BLOCK)
    return ()


# -- init / update ------------------------------------------------------------

def adamw_init(params, cfg: AdamWConfig):
    def zeros_like_moment(p):
        if cfg.quantized_moments:
            return {
                "m_q": jnp.zeros(p.shape, jnp.int8),
                "m_s": jnp.zeros(_moment_scale_shape(p.shape), jnp.float32),
                "v_q": jnp.zeros(p.shape, jnp.int8),
                "v_s": jnp.zeros(_moment_scale_shape(p.shape), jnp.float32),
            }
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}

    return {"count": jnp.zeros((), jnp.int32),
            "moments": jax.tree.map(zeros_like_moment, params)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mom):
        g = g.astype(jnp.float32) * clip
        if cfg.quantized_moments:
            m = _q8_decode(mom["m_q"], mom["m_s"])
            v = _q8_decode_sqrt(mom["v_q"], mom["v_s"])
        else:
            m, v = mom["m"], mom["v"]
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if cfg.quantized_moments:
            m_q, m_s = _q8_encode(m)
            v_q, v_s = _q8_encode_sqrt(v)
            return new_p, {"m_q": m_q, "m_s": m_s, "v_q": v_q, "v_s": v_s}
        return new_p, {"m": m, "v": v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["moments"])
    new_p, new_m = [], []
    for p, g, mom in zip(flat_p, flat_g, flat_m):
        np_, nm_ = upd(p, g, mom)
        new_p.append(np_)
        new_m.append(nm_)
    return (jax.tree.unflatten(treedef, new_p),
            {"count": count, "moments": jax.tree.unflatten(treedef, new_m)})
