"""Deterministic synthetic token pipeline.

Stateless-by-construction: batch contents are a pure function of
(seed, step), so (a) restart-after-failure resumes the exact stream from the
checkpointed step with no pipeline state to persist, and (b) each host can
materialize just its shard (deterministic per-host slicing) — the property a
1000-node data plane needs for straggler-free, coordination-free input.

The stream is a noisy affine-recurrence language
    t_{k+1} = (a * t_k + b) mod V   with prob (1 - noise), else uniform
so models can actually learn it (loss decreases), which the end-to-end
examples and convergence tests rely on.
"""

from __future__ import annotations

import numpy as np


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(cfg, step: int, *, batch: int, seq: int, seed: int = 1234,
               noise: float = 0.1) -> dict:
    """Batch dict matching the arch's input signature (tokens or embeds)."""
    rng = _rng_for(seed, step)
    v = cfg.vocab_size
    a, b = 31, 17
    start = rng.integers(0, v, size=(batch, 1))
    toks = np.empty((batch, seq + 1), dtype=np.int64)
    toks[:, :1] = start
    for t in range(seq):
        nxt = (a * toks[:, t] + b) % v
        flip = rng.random(batch) < noise
        nxt = np.where(flip, rng.integers(0, v, batch), nxt)
        toks[:, t + 1] = nxt
    out: dict = {"labels": toks[:, 1:].astype(np.int32)}
    if cfg.embed_input:
        out["tokens"] = toks[:, :-1].astype(np.int32)
    else:
        # stub frontend: deterministic per-token embedding (fixed projection)
        emb_rng = _rng_for(seed, -1)
        table = emb_rng.standard_normal((v, cfg.d_model)).astype(np.float32)
        out["embeds"] = table[toks[:, :-1]]
    if cfg.m_rope:
        pos = np.broadcast_to(np.arange(seq)[None, None], (3, batch, seq))
        out["pos3d"] = pos.astype(np.int32)
    return out


def make_eval_batches(cfg, n: int, *, batch: int, seq: int,
                      seed: int = 9999) -> list[dict]:
    return [make_batch(cfg, 10_000_000 + i, batch=batch, seq=seq, seed=seed)
            for i in range(n)]
