from .pipeline import make_batch, make_eval_batches  # noqa: F401
