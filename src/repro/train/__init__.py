from .steps import (TrainState, batch_specs, cache_logical_specs,  # noqa
                    init_train_state, make_decode_step, make_prefill_step,
                    make_train_step)
