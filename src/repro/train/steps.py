"""pjit step factories: train_step / prefill / decode with full shardings.

Each factory resolves parameter / optimizer / cache / batch shardings from
the logical rule tables and returns a jitted function whose tracing happens
inside the ``activation_sharding`` context, so every
``with_sharding_constraint`` in the model resolves against the same mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..distributed.compress import (CompressionConfig, ef_compress_update,
                                    init_error_feedback)
from ..distributed.sharding import (DEFAULT_RULES, PREFILL_RULES,
                                    SERVE_RULES, activation_sharding,
                                    build_param_specs, spec_for)
from ..models.config import ModelConfig
from ..models.transformer import (forward, init_cache, init_params,
                                  train_loss)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.schedule import linear_warmup_cosine

TrainState = dict  # {"params", "opt", "ef", "step"}


# ---------------------------------------------------------------------------
# Sharding resolution
# ---------------------------------------------------------------------------

def batch_specs(batch: dict, mesh, rules=None):
    def one(name, x):
        nd = np.ndim(x)
        if name == "pos3d":
            return spec_for(np.shape(x), (None, "batch", "seq"), mesh, rules)
        axes = ("batch", "seq", None)[:nd]
        return spec_for(np.shape(x), axes, mesh, rules)
    return {k: one(k, v) for k, v in batch.items()}


_CACHE_AXES = {
    "k": (None, "batch", "kv_seq", None, None),
    "v": (None, "batch", "kv_seq", None, None),
    "ckv": (None, "batch", "kv_seq", None),
    "kr": (None, "batch", "kv_seq", None),
    "x": (None, "batch", None, "tp"),
    "b": (None, "batch", None, "tp"),
    "c": (None, "batch", None, "tp"),
    "state": (None, "batch", "heads", None, None),
}


def cache_logical_specs(cache, mesh, rules=None):
    def leaf(path, x):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        axes = _CACHE_AXES.get(name, (None,) * np.ndim(x))
        return spec_for(np.shape(x), axes, mesh, rules)
    return jax.tree_util.tree_map_with_path(leaf, cache)


def state_specs(state, mesh, rules=None):
    return {
        "params": build_param_specs(state["params"], mesh, rules),
        "opt": {"count": P(),
                "moments": build_param_specs(state["opt"]["moments"], mesh,
                                             rules)},
        "ef": (build_param_specs(state["ef"], mesh, rules)
               if state.get("ef") is not None else None),
        "step": P(),
    }


def _sharded(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# State init
# ---------------------------------------------------------------------------

def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     comp_cfg: CompressionConfig | None = None,
                     seed: int = 0) -> TrainState:
    params = init_params(cfg, jax.random.PRNGKey(seed))
    state: TrainState = {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "ef": (init_error_feedback(params)
               if comp_cfg and comp_cfg.enabled else None),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh, opt_cfg: AdamWConfig,
                    comp_cfg: CompressionConfig | None = None,
                    rules=None, total_steps: int = 10000,
                    warmup: int = 100):
    """Returns (train_step(state, batch) -> (state, metrics), specs dict)."""
    rules = rules or DEFAULT_RULES
    comp_cfg = comp_cfg or CompressionConfig(enabled=False)

    def step_fn(state: TrainState, batch: dict):
        with activation_sharding(mesh, rules):
            loss, grads = jax.value_and_grad(train_loss)(
                state["params"], batch, cfg)
            ef = state["ef"]
            if comp_cfg.enabled:
                grads, ef = ef_compress_update(grads, ef, comp_cfg)
            lr_scale = linear_warmup_cosine(state["step"], warmup,
                                            total_steps)
            params, opt = adamw_update(grads, state["opt"], state["params"],
                                       opt_cfg, lr_scale)
        new_state = {"params": params, "opt": opt, "ef": ef,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "lr_scale": lr_scale}
        return new_state, metrics

    def specs_of(state, batch):
        return state_specs(state, mesh, rules), batch_specs(batch, mesh,
                                                            rules)

    def jitted(state, batch):
        st_specs, b_specs = specs_of(state, batch)
        return jax.jit(
            step_fn,
            in_shardings=(_sharded(st_specs, mesh), _sharded(b_specs, mesh)),
            out_shardings=(_sharded(st_specs, mesh),
                           NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )

    return step_fn, jitted


def make_prefill_step(cfg: ModelConfig, mesh, rules=None, max_len=None):
    rules = rules or PREFILL_RULES

    def step_fn(params, batch):
        with activation_sharding(mesh, rules):
            b = (batch.get("tokens") if batch.get("tokens") is not None
                 else batch["embeds"]).shape[0]
            s = (batch.get("tokens") if batch.get("tokens") is not None
                 else batch["embeds"]).shape[1]
            caches = init_cache(cfg, b, max_len or s)
            logits, new_caches, _ = forward(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos3d=batch.get("pos3d"),
                caches=caches, last_only=True)
        return logits[:, 0, :], new_caches

    return step_fn


def make_decode_step(cfg: ModelConfig, mesh, rules=None):
    """serve_step: one new token against a filled KV/state cache."""
    rules = rules or SERVE_RULES

    def step_fn(params, caches, inputs, pos):
        with activation_sharding(mesh, rules):
            logits, new_caches, _ = forward(
                params, cfg,
                tokens=(inputs["tokens"][:, None]
                        if "tokens" in inputs else None),
                embeds=inputs.get("embeds"),
                pos3d=inputs.get("pos3d"),
                caches=caches, cache_pos=pos, last_only=True)
        return logits[:, 0, :], new_caches

    return step_fn
