"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
straggler accounting, async compressed checkpointing.

Scale posture (DESIGN.md §6): the loop owns no data-pipeline state (batches
are pure functions of the step), checkpoints are atomic and elastic
(restorable onto a different mesh), SIGTERM triggers a final synchronous
save, and per-step wall times feed a straggler monitor that flags steps
slower than ``straggler_factor`` x the running median — on a real cluster
that signal drives host replacement; here it is logged and surfaced in the
returned metrics.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.manager import CheckpointConfig, CheckpointManager
from ..data.pipeline import make_batch
from ..distributed.compress import CompressionConfig
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig
from .steps import init_train_state, make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 200
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    resume: bool = True


@dataclass
class LoopResult:
    losses: list[float] = field(default_factory=list)
    step_times: list[float] = field(default_factory=list)
    straggler_steps: list[int] = field(default_factory=list)
    final_step: int = 0


def train_loop(cfg: ModelConfig, mesh, loop: LoopConfig,
               opt_cfg: AdamWConfig | None = None,
               comp_cfg: CompressionConfig | None = None,
               ckpt_cfg: CheckpointConfig | None = None) -> LoopResult:
    opt_cfg = opt_cfg or AdamWConfig()
    state = init_train_state(cfg, opt_cfg, comp_cfg, seed=loop.seed)
    mgr = CheckpointManager(ckpt_cfg) if ckpt_cfg else None

    start_step = 0
    if mgr and loop.resume and mgr.latest_step() is not None:
        state, meta = mgr.restore(state)
        start_step = int(meta["step"])

    step_fn, make_jitted = make_train_step(
        cfg, mesh, opt_cfg, comp_cfg, total_steps=loop.total_steps)
    probe = make_batch(cfg, 0, batch=loop.batch, seq=loop.seq,
                       seed=loop.seed)
    fn = make_jitted(state, probe)

    stop = {"flag": False}

    def on_term(signum, frame):
        stop["flag"] = True
    prev_handler = signal.signal(signal.SIGTERM, on_term)

    result = LoopResult()
    times: list[float] = []
    try:
        for step in range(start_step, loop.total_steps):
            batch = make_batch(cfg, step, batch=loop.batch, seq=loop.seq,
                               seed=loop.seed)
            t0 = time.monotonic()
            state, metrics = fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            times.append(dt)
            result.losses.append(loss)
            result.step_times.append(dt)
            if len(times) > 8:
                med = float(np.median(times[-64:]))
                if dt > loop.straggler_factor * med:
                    result.straggler_steps.append(step)
            if mgr and (step + 1) % loop.ckpt_every == 0:
                mgr.save(state, step + 1)
            if stop["flag"]:
                break
        result.final_step = int(jax.device_get(state["step"]))
        if mgr:
            mgr.save(state, result.final_step)
            mgr.wait()
    finally:
        signal.signal(signal.SIGTERM, prev_handler)
    return result
