"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  Backbone only: the EnCodec frontend is a stub —
input_specs supplies precomputed frame embeddings (B, S, d_model)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    act="gelu", norm="layernorm", rope_theta=10000.0,
    embed_input=False,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=128, param_dtype="float32",
    compute_dtype="float32", attn_kv_block=64,
)
