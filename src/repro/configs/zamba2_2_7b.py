"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].  Simplifications vs. the released model (DESIGN.md §10):
the shared block consumes the hidden state directly (no concat-with-
embedding projection, no per-invocation LoRA)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_conv=4, ssm_chunk=128,
    shared_attn_every=6, rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16, shared_attn_every=2,
    param_dtype="float32", compute_dtype="float32", attn_kv_block=64,
)
