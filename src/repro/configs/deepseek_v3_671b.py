"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8
[arXiv:2412.19437].  MTP head omitted (orthogonal to weight coding,
see DESIGN.md §10).  First 3 layers dense (d_ff 18432) per the paper."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129280,
    attention="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, capacity_factor=1.25,
    rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    q_lora_rank=48, kv_lora_rank=32,
    qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
    num_experts=8, num_shared_experts=1, top_k=2, moe_d_ff=64,
    first_dense_layers=1,
    param_dtype="float32", compute_dtype="float32", attn_kv_block=64,
)
