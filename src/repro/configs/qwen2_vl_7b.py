"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].
Backbone only: the vision tower is a stub — input_specs supplies
precomputed patch/text embeddings (B, S, d_model) and 3-D position ids."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064,
    qkv_bias=True, m_rope=True, m_rope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    embed_input=False,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, m_rope_sections=(4, 6, 6),
    param_dtype="float32", compute_dtype="float32", attn_kv_block=64,
)
