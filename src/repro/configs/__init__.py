"""Assigned-architecture registry.

String-addressed lookup — ``configs.get(name)`` / ``configs.names()`` —
so the model zoo, benches, and tests never import config modules by
hand.  ``get_config``/``get_smoke_config`` remain as thin wrappers for
older call sites."""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = [
    "llama3-8b", "qwen1.5-4b", "mistral-nemo-12b", "qwen3-8b",
    "deepseek-v3-671b", "deepseek-moe-16b", "mamba2-2.7b",
    "musicgen-medium", "qwen2-vl-7b", "zamba2-2.7b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

# (seq_len, global_batch, kind);  kind: train | prefill | decode
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (see DESIGN.md §5): only the
# SSM/hybrid archs run it; pure full-attention archs skip.
LONG_CTX_ARCHS = {"mamba2-2.7b", "zamba2-2.7b"}


def shapes_for(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CTX_ARCHS:
        out.append("long_500k")
    return out


def names() -> list[str]:
    """Registered architecture ids, in registry order."""
    return list(ARCH_IDS)


def get(name: str, *, smoke: bool = False) -> ModelConfig:
    """Look up a registered architecture config by string id.

    ``smoke=True`` returns the tiny CPU-runnable variant every config
    module exposes alongside the paper-scale one.
    """
    try:
        mod = importlib.import_module(f".{_MOD[name]}", __name__)
    except KeyError:
        raise KeyError(
            f"unknown config {name!r}; available: {', '.join(ARCH_IDS)}"
        ) from None
    return mod.SMOKE if smoke else mod.CONFIG


def get_config(arch: str) -> ModelConfig:
    return get(arch)


def get_smoke_config(arch: str) -> ModelConfig:
    return get(arch, smoke=True)
