"""qwen1.5-4b [dense] — MHA with QKV bias [hf:Qwen/Qwen1.5 family]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    head_dim=128, d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=1000000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512, param_dtype="float32", compute_dtype="float32",
    attn_kv_block=64,
)
