"""mamba2-2.7b [ssm] — SSD, attention-free [arXiv:2405.21060]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50280,
    attention="none",
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_conv=4, ssm_chunk=128,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16,
    param_dtype="float32", compute_dtype="float32",
)
