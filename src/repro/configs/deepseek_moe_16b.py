"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066].  First layer dense (d_ff 10944) per the paper."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=10944, vocab_size=102400,
    num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1, capacity_factor=1.25,
    rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    num_experts=8, num_shared_experts=2, top_k=2, moe_d_ff=64,
    first_dense_layers=1,
    param_dtype="float32", compute_dtype="float32", attn_kv_block=64,
)
