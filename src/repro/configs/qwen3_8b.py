"""qwen3-8b [dense] — GQA with qk-norm [hf:Qwen/Qwen3-8B]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1000000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, param_dtype="float32", compute_dtype="float32",
    attn_kv_block=64,
)
