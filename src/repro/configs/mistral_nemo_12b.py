"""mistral-nemo-12b [dense] — GQA, 128k ctx, head_dim 128
[hf:mistralai/Mistral-Nemo-Base-2407]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1000000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=160, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=320, vocab_size=512, param_dtype="float32", compute_dtype="float32",
    attn_kv_block=64,
)
