"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=128256,
    rope_theta=500000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, param_dtype="float32", compute_dtype="float32",
    attn_kv_block=64,
)
