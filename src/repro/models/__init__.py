from .config import ModelConfig  # noqa: F401
from .transformer import (decode_step, init_params, prefill,  # noqa: F401
                          train_loss)
