"""Model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from .. import kernels as _kernels

KernelPolicy = _kernels.KernelPolicy


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention flavour
    attention: str = "gqa"         # gqa | mla | none (ssm)
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500000.0
    m_rope: bool = False           # 3-section rope (qwen2-vl)
    m_rope_sections: tuple[int, int, int] = (16, 24, 24)

    # MLA (deepseek-v3) dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # deepseek: leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2): shared attention block applied every N ssm layers
    shared_attn_every: int = 0

    # io / embedding
    embed_input: bool = True       # False: stub frontend supplies embeddings
    tie_embeddings: bool = False
    act: str = "silu"              # silu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    q8_cache: bool = False         # int8 KV cache (fixed-point serving)
    kv_cache_delta: float = 1.0 / 16.0   # int8 KV grid step; calibrate via
    # serve.quantized.calibrate_kv_cache_delta (or ServeConfig.kv_cache_delta)

    # kernel selection: one policy for every registered op (platform
    # dispatch, per-op impl pins, tuning cache) — see repro.kernels.registry.
    # Per-op pins go through KernelPolicy(overrides={...}) / .override();
    # the pre-registry q8_matmul_impl / attn_impl string fields are gone.
    kernels: KernelPolicy = KernelPolicy()

    # distribution / performance knobs (see distributed/sharding.py)
    remat: str = "block"           # none | block | dots
    scan_layers: bool = True
    attn_kv_block: int = 1024
    moe_impl: str = "scatter"      # scatter | dense

    @property
    def d_inner(self) -> int:      # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
