"""Unified decoder-only backbone covering all assigned families.

Layers are *stacked* (leading L axis) and traversed with lax.scan so the
lowered HLO is one layer body regardless of depth — essential for 61-layer
compile times and for the per-layer remat policy.  Families:

  dense  — pre-norm GQA/MLA attention + SwiGLU (llama/qwen/mistral/musicgen/
           qwen2-vl flavours via config flags)
  moe    — attention + capacity-routed MoE (+ leading dense layers)
  ssm    — Mamba2 mixer stack (attention-free)
  hybrid — Mamba2 stack with a *shared* attention block applied every
           `shared_attn_every` layers (Zamba2-style; the shared weights are
           reused at every invocation — DeepCABAC codes them once)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .. import kernels as _kernels
from ..distributed.sharding import constrain
from ..serve.quantized import dequant_leaf, is_q8
from .attention import gqa_attention, mla_attention
from .config import ModelConfig
from .layers import rms_norm, swiglu_mlp
from .moe import moe_block
from .ssm import mamba2_mixer

# q8 leaves the fused dequant_matmul path consumes in place: attention
# projections (gqa + mla), dense/shared MLP projections, MoE router and
# stacked expert banks.  Anything else (ssm mixer tensors, conv kernels,
# biases that happened to quantize) falls back to a loop-body dequantize —
# explicitly, reported once per tensor via dispatch_report().
_FUSED_ELIGIBLE = frozenset({
    "wq", "wk", "wv", "wo",                       # gqa projections
    "w_dq", "w_uq", "w_dkv", "w_kr", "w_uk", "w_uv",   # mla projections
    "w_gate", "w_up", "w_down",                   # dense MLP / expert banks
    "sh_gate", "sh_up", "sh_down", "router",      # MoE shared + router
})

# (tensor name, reason) pairs already reported — loop-body dequant is a
# per-tensor decision, so report it once, not once per compile per step.
_reported_loop_dequant: set = set()


def _record_loop_dequant(name: str, reason: str) -> None:
    if name in _reported_loop_dequant:
        return
    _reported_loop_dequant.add(name)
    _kernels.record_event(
        op="dequant_matmul", platform=jax.default_backend(),
        impl="loop_dequant", reason=f"{name}: {reason}",
        kind="loop_dequant")


def _fused_layer_params(lp, dt):
    """Per-layer param pass inside the scan body.

    Eligible q8 leaves pass through *intact* — their consumers
    (:func:`~repro.models.layers.q8_einsum`, ``_expert_einsum``) feed the
    int8 levels straight to the fused ``dequant_matmul`` kernels, so the
    stacked parameters are only ever read from HBM as int8.  Ineligible q8
    leaves are dequantized here (the old loop-body path), recorded once per
    tensor with ``kind="loop_dequant"`` so the fallback is loud instead of
    a silent per-step bf16 re-materialization."""
    def visit(path, leaf):
        if not is_q8(leaf):
            return leaf
        name = next((p.key for p in reversed(path)
                     if isinstance(p, jax.tree_util.DictKey)), "<leaf>")
        if name in _FUSED_ELIGIBLE:
            return leaf
        _record_loop_dequant(
            name, "no fused q8 consumer for this tensor (not an "
            "attention/MLP/MoE projection)")
        return dequant_leaf(leaf, dt)

    return jax.tree_util.tree_map_with_path(visit, lp, is_leaf=is_q8)


def _norm(x, p, cfg):
    if isinstance(p, dict):        # layernorm {scale, bias}
        mean = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
        var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
        y = (x.astype(jnp.float32) - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    return rms_norm(x, p, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def _norm_init(cfg, d, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return jnp.ones((d,), dtype)


def _init_attn(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 8)
    h, g, dh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    if cfg.attention == "mla":
        p = {
            "w_dkv": _dense(ks[0], d, cfg.kv_lora_rank, dtype),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
            "w_uk": _dense(ks[1], cfg.kv_lora_rank,
                           h * cfg.qk_nope_head_dim, dtype),
            "w_uv": _dense(ks[2], cfg.kv_lora_rank,
                           h * cfg.v_head_dim, dtype),
            "w_kr": _dense(ks[3], d, cfg.qk_rope_head_dim, dtype),
            "wo": _dense(ks[4], h * cfg.v_head_dim, d, dtype),
        }
        if cfg.q_lora_rank:
            p["w_dq"] = _dense(ks[5], d, cfg.q_lora_rank, dtype)
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
            p["w_uq"] = _dense(ks[6], cfg.q_lora_rank, h * (
                cfg.qk_nope_head_dim + cfg.qk_rope_head_dim), dtype)
        else:
            p["w_uq"] = _dense(ks[6], d, h * (
                cfg.qk_nope_head_dim + cfg.qk_rope_head_dim), dtype)
        return p
    p = {
        "wq": _dense(ks[0], d, h * dh, dtype),
        "wk": _dense(ks[1], d, g * dh, dtype),
        "wv": _dense(ks[2], d, g * dh, dtype),
        "wo": _dense(ks[3], h * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((g * dh,), dtype)
        p["bv"] = jnp.zeros((g * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _init_mlp(cfg, key, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": _dense(k1, cfg.d_model, d_ff, dtype),
            "w_up": _dense(k2, cfg.d_model, d_ff, dtype),
            "w_down": _dense(k3, d_ff, cfg.d_model, dtype)}


def _init_moe(cfg, key, dtype):
    ks = jax.random.split(key, 7)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": _dense(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        p["sh_gate"] = _dense(ks[4], d, fs, dtype)
        p["sh_up"] = _dense(ks[5], d, fs, dtype)
        p["sh_down"] = _dense(ks[6], fs, d, dtype)
    return p


def _init_ssm(cfg, key, dtype):
    ks = jax.random.split(key, 9)
    d, d_in = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    w = cfg.ssm_conv

    def conv_init(key, ch):
        return (jax.random.normal(key, (ch, w), jnp.float32)
                * w ** -0.5).astype(dtype)

    return {
        "w_z": _dense(ks[0], d, d_in, dtype),
        "w_x": _dense(ks[1], d, d_in, dtype),
        "w_b": _dense(ks[2], d, g * n, dtype),
        "w_c": _dense(ks[3], d, g * n, dtype),
        "w_dt": _dense(ks[4], d, h, dtype),
        "conv_x_w": conv_init(ks[5], d_in),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_b_w": conv_init(ks[6], g * n),
        "conv_b_b": jnp.zeros((g * n,), dtype),
        "conv_c_w": conv_init(ks[7], g * n),
        "conv_c_b": jnp.zeros((g * n,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),       # A = -1
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "d_skip": jnp.ones((h,), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": _dense(ks[8], d_in, d, dtype),
    }


def _init_dense_layer(cfg, key, dtype, d_ff=None):
    k1, k2 = jax.random.split(key)
    return {"attn_norm": _norm_init(cfg, cfg.d_model, dtype),
            "attn": _init_attn(cfg, k1, dtype),
            "mlp_norm": _norm_init(cfg, cfg.d_model, dtype),
            "mlp": _init_mlp(cfg, k2, dtype, d_ff)}


def _init_moe_layer(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    return {"attn_norm": _norm_init(cfg, cfg.d_model, dtype),
            "attn": _init_attn(cfg, k1, dtype),
            "mlp_norm": _norm_init(cfg, cfg.d_model, dtype),
            "moe": _init_moe(cfg, k2, dtype)}


def _init_ssm_layer(cfg, key, dtype):
    return {"norm": _norm_init(cfg, cfg.d_model, dtype),
            "mixer": _init_ssm(cfg, key, dtype)}


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict = {}
    if cfg.embed_input:
        params["embed"] = (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)

    def stack(init_one, n, key):
        return jax.vmap(lambda k: init_one(cfg, k, dtype))(
            jax.random.split(key, n))

    if cfg.family == "dense":
        params["layers"] = stack(_init_dense_layer, cfg.num_layers, keys[1])
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["dense_layers"] = jax.vmap(
                lambda k: _init_dense_layer(cfg, k, dtype, cfg.d_ff))(
                jax.random.split(keys[2], nd))
        params["layers"] = stack(_init_moe_layer, cfg.num_layers - nd,
                                 keys[1])
    elif cfg.family == "ssm":
        params["layers"] = stack(_init_ssm_layer, cfg.num_layers, keys[1])
    elif cfg.family == "hybrid":
        params["layers"] = stack(_init_ssm_layer, cfg.num_layers, keys[1])
        params["shared"] = _init_dense_layer(cfg, keys[3], dtype)
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = _norm_init(cfg, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = _dense(keys[4], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_dispatch(x, lp, cfg, positions, pos3d, cache, cache_pos,
                   cache_pages=None):
    if cfg.attention == "mla":
        return mla_attention(x, lp, cfg, positions, cache=cache,
                             cache_pos=cache_pos, cache_pages=cache_pages)
    return gqa_attention(x, lp, cfg, positions, cache=cache,
                         cache_pos=cache_pos, positions_3d=pos3d,
                         cache_pages=cache_pages)


def _dense_block(x, lp, cfg, positions, pos3d, cache, cache_pos,
                 cache_pages=None):
    a, new_cache = _attn_dispatch(_norm(x, lp["attn_norm"], cfg), lp["attn"],
                                  cfg, positions, pos3d, cache, cache_pos,
                                  cache_pages)
    x = constrain(x + a, "batch", "seq", None)
    x = x + swiglu_mlp(_norm(x, lp["mlp_norm"], cfg), lp["mlp"], cfg.act,
                       policy=cfg.kernels)
    return constrain(x, "batch", "seq", None), new_cache, \
        jnp.zeros((), jnp.float32)


def _moe_layer_block(x, lp, cfg, positions, pos3d, cache, cache_pos,
                     cache_pages=None):
    a, new_cache = _attn_dispatch(_norm(x, lp["attn_norm"], cfg), lp["attn"],
                                  cfg, positions, pos3d, cache, cache_pos,
                                  cache_pages)
    x = constrain(x + a, "batch", "seq", None)
    m, aux = moe_block(_norm(x, lp["mlp_norm"], cfg), lp["moe"], cfg)
    return constrain(x + m, "batch", "seq", None), new_cache, aux


def _ssm_block(x, lp, cfg, positions, pos3d, cache, cache_pos,
               cache_pages=None):
    del positions, pos3d, cache_pos, cache_pages
    m, new_cache = mamba2_mixer(_norm(x, lp["norm"], cfg), lp["mixer"], cfg,
                                cache=cache)
    return constrain(x + m, "batch", "seq", None), new_cache, \
        jnp.zeros((), jnp.float32)


_BLOCKS = {"dense": _dense_block, "moe": _moe_layer_block,
           "ssm": _ssm_block, "hybrid": _ssm_block}


def _maybe_remat(fn, cfg):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _scan_stack(x, stacked, block, cfg, positions, pos3d, caches, cache_pos,
                cache_pages=None):
    """lax.scan over stacked layer params (and per-layer caches).

    q8-quantized serving weights are dequantized *inside* the loop body, so
    HBM reads of the stacked parameters stay int8 (1 B/param).  Under
    paged decode the per-layer cache leaf is that layer's page *pool* and
    ``cache_pages`` (shared across layers — one page table entry covers
    every layer's slice of a token page) rides in the closure."""
    dt = jnp.dtype(cfg.compute_dtype)
    body = _maybe_remat(
        functools.partial(block, cfg=cfg, positions=positions, pos3d=pos3d,
                          cache_pos=cache_pos, cache_pages=cache_pages), cfg)

    if caches is None:
        def f(carry, lp):
            h, aux = carry
            h2, _, a = body(h, _fused_layer_params(lp, dt), cache=None)
            return (h2, aux + a), None
        (x, aux), _ = lax.scan(f, (x, jnp.zeros((), jnp.float32)), stacked)
        return x, None, aux

    def f(carry, xs):
        h, aux = carry
        lp, cache_l = xs
        h2, newc, a = body(h, _fused_layer_params(lp, dt), cache=cache_l)
        return (h2, aux + a), newc
    (x, aux), new_caches = lax.scan(
        f, (x, jnp.zeros((), jnp.float32)), (stacked, caches))
    return x, new_caches, aux


def _hybrid_scan(x, params, cfg, positions, pos3d, caches, cache_pos):
    """Zamba2: groups of `shared_attn_every` mamba layers, then the shared
    attention block (same weights every invocation)."""
    per = cfg.shared_attn_every
    ng = cfg.num_layers // per
    dt = jnp.dtype(cfg.compute_dtype)
    stacked = jax.tree.map(
        lambda a: a.reshape(ng, per, *a.shape[1:]), params["layers"],
        is_leaf=lambda a: hasattr(a, "shape"))
    shared = _fused_layer_params(params["shared"], dt)
    ssm_body = _maybe_remat(
        functools.partial(_ssm_block, cfg=cfg, positions=positions,
                          pos3d=pos3d, cache_pos=cache_pos), cfg)
    attn_body = _maybe_remat(
        functools.partial(_dense_block, cfg=cfg, positions=positions,
                          pos3d=pos3d, cache_pos=cache_pos), cfg)

    ssm_caches = None if caches is None else caches["ssm"]
    attn_caches = None if caches is None else caches["attn"]

    def group(carry, xs):
        h = carry
        if caches is None:
            lps = xs

            def inner(hh, lp):
                h2, _, _ = ssm_body(hh, _fused_layer_params(lp, dt), cache=None)
                return h2, None
            h, _ = lax.scan(inner, h, lps)
            h, _, _ = attn_body(h, shared, cache=None)
            return h, None
        lps, ssm_c, attn_c = xs

        def inner(hh, xs_i):
            lp, c = xs_i
            h2, nc, _ = ssm_body(hh, _fused_layer_params(lp, dt), cache=c)
            return h2, nc
        h, new_ssm = lax.scan(inner, h, (lps, ssm_c))
        h, new_attn, _ = attn_body(h, shared, cache=attn_c)
        return h, (new_ssm, new_attn)

    if caches is None:
        x, _ = lax.scan(group, x, stacked)
        return x, None, jnp.zeros((), jnp.float32)
    ssm_g = jax.tree.map(lambda a: a.reshape(ng, per, *a.shape[1:]),
                         ssm_caches)
    x, (new_ssm, new_attn) = lax.scan(group, x, (stacked, ssm_g, attn_caches))
    new_caches = {"ssm": jax.tree.map(
        lambda a: a.reshape(ng * per, *a.shape[2:]), new_ssm),
        "attn": new_attn}
    return x, new_caches, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, pos3d=None, caches=None, cache_pos=None,
            cache_pages=None, last_only: bool = False, last_index=None):
    """Returns (logits, new_caches, aux).

    last_only takes position -1; last_index (B,) int32 gathers one
    per-row position instead (padded-bucket prefill) — both project the
    head on a single position, never the full sequence.

    cache_pages (B, n_max) int32 switches attention to *paged* decode:
    ``caches`` leaves are page pools (L, P, page, ...) and each row's KV
    is scattered/gathered through its page-table row (``repro.serve.kv``).
    Attention families only — an SSM/hybrid state cache has no token axis
    to page."""
    if cfg.embed_input:
        x = _kernels.get("embed_lookup_q8")(params["embed"], tokens,
                                            jnp.dtype(cfg.compute_dtype),
                                            policy=cfg.kernels)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = constrain(x, "batch", "seq", None)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        if cache_pos is None:
            base = 0
        else:
            cp = jnp.asarray(cache_pos)
            # (B,) per-slot offsets (ragged continuous batching) broadcast
            # down the sequence axis; scalars broadcast as before
            base = cp[:, None] if cp.ndim == 1 else cp
        positions = base + jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.m_rope and pos3d is None:
        pos3d = jnp.broadcast_to(positions[None], (3, b, s))

    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        if cache_pages is not None:
            raise ValueError(
                "paged KV decode requires an attention-family cache; the "
                f"{cfg.family!r} state cache has no token axis to page")
        x, new_caches, aux = _hybrid_scan(x, params, cfg, positions, pos3d,
                                          caches, cache_pos)
    else:
        if cache_pages is not None and cfg.family == "ssm":
            raise ValueError(
                "paged KV decode requires an attention-family cache; the "
                "'ssm' state cache has no token axis to page")
        new_caches = {}
        if cfg.family == "moe" and cfg.first_dense_layers:
            dc = None if caches is None else caches["dense"]
            x, ndc, a1 = _scan_stack(x, params["dense_layers"], _dense_block,
                                     cfg, positions, pos3d, dc, cache_pos,
                                     cache_pages)
            aux += a1
            if caches is not None:
                new_caches["dense"] = ndc
        mc = caches if caches is None else (
            caches["main"] if cfg.family == "moe" and cfg.first_dense_layers
            else caches)
        x, nmc, a2 = _scan_stack(x, params["layers"], _BLOCKS[cfg.family],
                                 cfg, positions, pos3d, mc, cache_pos,
                                 cache_pages)
        aux += a2
        if caches is not None:
            if cfg.family == "moe" and cfg.first_dense_layers:
                new_caches["main"] = nmc
            else:
                new_caches = nmc
        else:
            new_caches = None

    x = _norm(x, params["final_norm"], cfg)
    if last_index is not None:
        x = x[jnp.arange(b), last_index][:, None, :]
    elif last_only:
        x = x[:, -1:, :]
    logits = _head_logits(x, params, cfg)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, new_caches, aux


def _head_logits(x, params, cfg: ModelConfig):
    """Final projection.  An untied q8 head (d, V) with per-vocab-channel
    scales matches the fused dequant-matmul kernel contract exactly, so the
    fixed-point serving path reads int8 weights from HBM and dequantizes
    in-core (kernels.get("dequant_matmul"); impl/tiles chosen by the
    cfg.kernels policy — decode rows get clamped bm tiles, see
    kernels/dequant_matmul ``default_tiles``)."""
    from ..serve.quantized import is_q8

    head_leaf = params["embed"] if cfg.tie_embeddings else params["head"]
    bsz, s, d = x.shape
    if not cfg.tie_embeddings and is_q8(head_leaf):
        out = _kernels.get("dequant_matmul")(
            x.reshape(bsz * s, d).astype(jnp.float32),
            head_leaf["q8"], head_leaf["q8s"], policy=cfg.kernels)
        return out.reshape(bsz, s, -1)
    if cfg.tie_embeddings and is_q8(head_leaf):
        # transposing the (V, d) embedding puts the per-vocab-row scales on
        # the *input* dim — the kernel contract wants per-output-channel
        # scales, so the tied head is fused-ineligible by design
        _record_loop_dequant(
            "embed.T (tied head)", "tied embedding head transposes "
            "per-vocab-row scales onto the contraction dim")
    head = (dequant_leaf(head_leaf, jnp.float32).T if cfg.tie_embeddings
            else dequant_leaf(head_leaf, jnp.float32))
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      head.astype(jnp.float32))


def train_loss(params, batch: dict, cfg: ModelConfig):
    """batch: tokens/embeds + labels (B,S) int32 (+ pos3d for m-rope)."""
    logits, _, aux = forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        pos3d=batch.get("pos3d"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_weight * aux / max(cfg.num_layers, 1)
    return loss


# -- caches -------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Preallocated decode caches, stacked on the layer axis."""
    dt = jnp.int8 if cfg.q8_cache else jnp.dtype(cfg.compute_dtype)
    la = cfg.num_layers

    def attn_cache(n_layers):
        if cfg.attention == "mla":
            return {"ckv": jnp.zeros((n_layers, batch, max_len,
                                      cfg.kv_lora_rank), dt),
                    "kr": jnp.zeros((n_layers, batch, max_len,
                                     cfg.qk_rope_head_dim), dt)}
        return {"k": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dt)}

    def ssm_cache(n_layers):
        w1 = cfg.ssm_conv - 1
        gn = cfg.ssm_ngroups * cfg.ssm_state
        cdt = jnp.dtype(cfg.compute_dtype)   # conv tail stays full precision
        return {"conv": {
                    "x": jnp.zeros((n_layers, batch, w1, cfg.d_inner), cdt),
                    "b": jnp.zeros((n_layers, batch, w1, gn), cdt),
                    "c": jnp.zeros((n_layers, batch, w1, gn), cdt)},
                "state": jnp.zeros((n_layers, batch, cfg.ssm_nheads,
                                    cfg.ssm_headdim, cfg.ssm_state),
                                   jnp.float32)}

    if cfg.family == "dense":
        return attn_cache(la)
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            return {"dense": attn_cache(nd), "main": attn_cache(la - nd)}
        return attn_cache(la)
    if cfg.family == "ssm":
        return ssm_cache(la)
    if cfg.family == "hybrid":
        ng = cfg.num_layers // cfg.shared_attn_every
        return {"ssm": ssm_cache(la), "attn": attn_cache(ng)}
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            pos3d=None, max_len: int | None = None):
    """Process the prompt, return (last-position logits (B,V), caches)."""
    b = (tokens if tokens is not None else embeds).shape[0]
    s = (tokens if tokens is not None else embeds).shape[1]
    caches = init_cache(cfg, b, max_len or s)
    logits, new_caches, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                                    pos3d=pos3d, caches=caches,
                                    last_only=True)
    return logits[:, 0, :], new_caches


def decode_step(params, cfg: ModelConfig, caches, pos, *, tokens=None,
                embeds=None, pos3d=None, cache_pages=None):
    """One token step.  tokens (B,) or embeds (B,1,d).

    pos: scalar int32 (all rows at one offset) or (B,) int32 per-row
    offsets — the ragged continuous-batching path, where each KV-cache
    row is scattered at its own position and masked to its own length.
    cache_pages (B, n_max) int32 selects paged decode over page-pool
    caches (see :func:`forward`).  Returns (logits (B,V), new_caches)."""
    if tokens is not None:
        tokens = tokens[:, None]
    logits, new_caches, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                                    pos3d=pos3d, caches=caches,
                                    cache_pos=pos, cache_pages=cache_pages,
                                    last_only=True)
    return logits[:, 0, :], new_caches
