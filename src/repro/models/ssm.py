"""Mamba2 — SSD (state-space duality) block, chunked scan + single-step decode.

Chunked SSD (arXiv:2405.21060 §6): the sequence is split into chunks of
length Q; within a chunk the recurrence is computed as a masked quadratic
form (attention-like, MXU-friendly), across chunks a short lax.scan passes
the (H, P, N) state.  This is the TPU-native adaptation: the quadratic
intra-chunk part maps to the MXU, the O(S/Q) scan is cheap.

Decode is the exact linear recurrence: state = a*state + dt*B*x per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rms_norm


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., l) -> (..., l, l) with out[t, s] = sum_{u in (s, t]} a_u
    (lower-triangular; -inf above the diagonal)."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    d = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, a_log, b_mat, c_mat, chunk: int, state0=None):
    """x (B,S,H,P); a_log (B,S,H) (= dt*A, negative); b_mat,c_mat (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a_log.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)

    # intra-chunk (quadratic, MXU): y_diag[t] = sum_{s<=t} C_t B_s L_{t,s} x_s
    ll = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))    # (B,nc,H,l,l)
    cb = jnp.einsum("bctgn,bcsgn->bcgts", cc, bc)      # (B,nc,G,l,l)
    cb = cb.reshape(bsz, nc, g, 1, chunk, chunk) * ll.reshape(
        bsz, nc, g, rep, chunk, chunk)
    y_diag = jnp.einsum("bcgrts,bcsgrp->bctgrp", cb,
                        xc.reshape(bsz, nc, chunk, g, rep, p))

    # chunk states: contribution of each chunk to the running state
    a_cum = jnp.cumsum(ac, axis=2)                     # (B,nc,l,H)
    a_tot = a_cum[:, :, -1, :]                         # (B,nc,H)
    decay_out = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,nc,l,H)
    states = jnp.einsum(
        "bcsgn,bcsgr,bcsgrp->bcgrpn", bc,
        decay_out.reshape(bsz, nc, chunk, g, rep),
        xc.reshape(bsz, nc, chunk, g, rep, p)).reshape(bsz, nc, h, p, n)

    # inter-chunk recurrence
    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, n), dtype=jnp.float32)

    def step(carry, inp):
        st_c, a_t = inp
        new = carry * jnp.exp(a_t)[:, :, None, None] + st_c
        return new, carry                               # emit state *before*

    final, prev_states = lax.scan(
        step, state0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         a_tot.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk output: y_off[t] = C_t * decay_in[t] * state_prev
    decay_in = jnp.exp(a_cum)                           # (B,nc,l,H)
    y_off = jnp.einsum(
        "bctgn,bctgr,bcgrpn->bctgrp", cc,
        decay_in.reshape(bsz, nc, chunk, g, rep),
        prev_states.reshape(bsz, nc, g, rep, p, n)).reshape(
            bsz, nc, chunk, h, p)
    y = y_diag.reshape(bsz, nc, chunk, h, p) + y_off
    return y.reshape(bsz, s, h, p), final


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                 tail: jnp.ndarray | None = None):
    """Depthwise causal conv. u (B,S,C), w (C,W), bias (C,).
    Returns (out (B,S,C), new_tail (B,W-1,C))."""
    width = w.shape[1]
    if tail is None:
        tail = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[:, i][None, None, :]
              for i in range(width))
    new_tail = up[:, -(width - 1):, :] if width > 1 else tail
    return out + bias[None, None, :], new_tail


def mamba2_mixer(x, p, cfg, *, cache=None):
    """One Mamba2 mixer.  x (B,S,d_model).

    cache (decode): {"conv": (B,W-1,convC), "state": (B,H,P,N)}; S must be 1.
    Returns (y (B,S,d_model), new_cache | final-state cache for prefill).
    """
    bsz, s, _ = x.shape
    h, pdim, n, g = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                     cfg.ssm_ngroups)
    d_in = cfg.d_inner

    # separate projections + per-segment depthwise convs (math-identical to
    # the fused in_proj/conv, but every tensor dim shards cleanly on the TP
    # axis — see DESIGN.md §6)
    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
    xr = jnp.einsum("bsd,dk->bsk", x, p["w_x"])
    br = jnp.einsum("bsd,dk->bsk", x, p["w_b"])
    cr = jnp.einsum("bsd,dk->bsk", x, p["w_c"])
    dt_raw = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])     # (B,S,H)

    tails = cache["conv"] if cache is not None else {"x": None, "b": None,
                                                     "c": None}
    xr, tx = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"], tails["x"])
    br, tb = _causal_conv(br, p["conv_b_w"], p["conv_b_b"], tails["b"])
    cr, tc = _causal_conv(cr, p["conv_c_w"], p["conv_c_b"], tails["c"])
    new_tail = {"x": tx, "b": tb, "c": tc}
    xs = jax.nn.silu(xr).reshape(bsz, s, h, pdim)
    b_mat = jax.nn.silu(br).reshape(bsz, s, g, n)
    c_mat = jax.nn.silu(cr).reshape(bsz, s, g, n)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])  # (B,S,H)
    neg_a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,)
    a_log = dt * neg_a[None, None, :]

    if cache is not None and s == 1:                    # decode step
        state = cache["state"]                          # (B,H,P,N) f32
        rep = h // g
        a1 = jnp.exp(a_log[:, 0, :])                    # (B,H)
        bx = jnp.einsum("bgn,bgrp,bgr->bgrpn",
                        b_mat[:, 0].astype(jnp.float32),
                        xs[:, 0].reshape(bsz, g, rep, pdim).astype(
                            jnp.float32),
                        dt[:, 0].reshape(bsz, g, rep)).reshape(
                            bsz, h, pdim, n)
        state = state * a1[:, :, None, None] + bx
        y = jnp.einsum("bgn,bgrpn->bgrp",
                       c_mat[:, 0].astype(jnp.float32),
                       state.reshape(bsz, g, rep, pdim, n)).reshape(
                           bsz, 1, h, pdim).astype(x.dtype)
        new_cache = {"conv": new_tail, "state": state}
    else:
        xdt = xs * dt[..., None]                         # fold dt into x
        # front-pad to a chunk multiple: zero inputs with zero initial state
        # contribute nothing, so this is exact (incl. the final state)
        pad = (-s) % cfg.ssm_chunk
        if pad:
            def fp(a):
                widths = [(0, 0)] * a.ndim
                widths[1] = (pad, 0)
                return jnp.pad(a, widths)
            xdt, a_log, b_mat, c_mat = map(fp, (xdt, a_log, b_mat, c_mat))
        y, final_state = ssd_chunked(xdt.astype(jnp.float32), a_log,
                                     b_mat.astype(jnp.float32),
                                     c_mat.astype(jnp.float32),
                                     cfg.ssm_chunk)
        y = y[:, pad:].astype(x.dtype)
        new_cache = {"conv": new_tail, "state": final_state}

    y = y + p["d_skip"][None, None, :, None] * xs
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_cache
