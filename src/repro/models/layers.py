"""Shared layer primitives: norms, activations, RoPE / M-RoPE, MLP.

:func:`q8_einsum` is the compressed-resident projection: any ``x @ w``
whose weight may be a serving-quantized ``{"q8","q8s"}`` leaf goes through
it, so int8 levels stay resident in HBM and dequantize inside the
``dequant_matmul`` kernel instead of re-materializing full-precision
weights per step.  Dense weights take the exact pre-existing einsum.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import kernels as _kernels


def q8_einsum(x: jnp.ndarray, w, *, policy=None) -> jnp.ndarray:
    """x (..., K) @ w -> (..., N) in ``x.dtype``.

    ``w`` is either a dense (K, N) array (plain einsum, unchanged math) or
    a q8 leaf {"q8": (K, N) int8, "q8s": (N,) f32} — routed through
    ``kernels.get("dequant_matmul")`` (impl/tiles per ``policy``, normally
    ``cfg.kernels``), which computes in f32 and is cast back to
    ``x.dtype``.  With f32 activations this is bit-identical to
    dequantize-then-einsum; see docs/kernels_api.md for eligibility.
    """
    if _kernels.is_q8_leaf(w):
        out = _kernels.get("dequant_matmul")(x, w["q8"], w["q8s"],
                                             policy=policy)
        return out.astype(x.dtype)
    return jnp.einsum("...k,kn->...n", x, w)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(dtype)


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def swiglu_mlp(x: jnp.ndarray, p: dict, act: str,
               policy=None) -> jnp.ndarray:
    gate = activation(q8_einsum(x, p["w_gate"], policy=policy), act)
    up = q8_einsum(x, p["w_up"], policy=policy)
    return q8_einsum(gate * up, p["w_down"], policy=policy)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (..., S, H, D); positions (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jnp.ndarray, positions_3d: jnp.ndarray, theta: float,
                 sections: tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  x (B, S, H, D), positions_3d (3, B, S).

    The D/2 rotation frequencies are split into (t, h, w) sections; each
    section rotates by its own positional stream (temporal / height / width).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang_all = positions_3d[..., None].astype(jnp.float32) * freqs  # (3,B,S,D/2)
    sec = jnp.zeros((d // 2,), dtype=jnp.int32)
    sec = sec.at[sections[0]:sections[0] + sections[1]].set(1)
    sec = sec.at[sections[0] + sections[1]:].set(2)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]                                          # (B,S,D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
