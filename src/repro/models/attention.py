"""Attention: GQA (+qk-norm, +bias, +M-RoPE) and MLA, with flash-scan.

The flash-scan path never materializes the full (Sq, Skv) score matrix: it
lax.scan's over KV blocks with an online-softmax carry (running max, running
denominator, accumulator) — the standard memory-safe formulation for 32k+
prefill.  GQA expansion happens inside the einsum (q reshaped to
(B, S, KVH, rep, D)), so K/V are never repeated in memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.sharding import constrain
from ..serve.quantized import dequant_cache_value, quantize_cache_value
from .layers import apply_m_rope, apply_rope, rms_norm


def _cache_store(x, cache_arr, delta):
    """Quantize to the cache's storage dtype (int8 fixed-point serving)."""
    if cache_arr.dtype == jnp.int8:
        return quantize_cache_value(x, delta)
    return x.astype(cache_arr.dtype)


def _cache_load(arr, dtype, delta):
    if arr.dtype == jnp.int8:
        return dequant_cache_value(arr, dtype, delta)
    return arr


def _cache_update(cache_arr, new_vals, cache_pos, delta):
    """Write this step's K/V into the preallocated cache.

    cache_pos scalar: all rows write at the same offset (one-shot batch).
    cache_pos (B,) int32: per-slot ragged positions (continuous batching) —
    each row scatters its single new entry at its own offset.
    """
    vals = _cache_store(new_vals, cache_arr, delta)
    cp = jnp.asarray(cache_pos)
    if cp.ndim == 0:
        return lax.dynamic_update_slice_in_dim(cache_arr, vals, cache_pos,
                                               axis=1)
    assert new_vals.shape[1] == 1, "ragged cache update is decode-only (S=1)"
    b = cache_arr.shape[0]
    return cache_arr.at[jnp.arange(b), cp].set(vals[:, 0])

NEG_INF = -1e30


def _online_softmax_scan(q5, k, v, qpos, kv_block: int,
                         kv_len: jnp.ndarray | None) -> jnp.ndarray:
    """q5 (B,Sq,G,R,D); k,v (B,Skv,G,D); qpos (B,Sq) global positions.
    Returns (B,Sq,G,R,D)."""
    b, sq, g, r, d = q5.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    nb = -(-skv // kv_block)
    pad = nb * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, kv_block, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, g, dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, i = blk
        kpos = i * kv_block + jnp.arange(kv_block)
        # keep K/V in their storage dtype; accumulate on the MXU in f32
        # (an explicit astype would materialize f32 copies of the whole
        # K/V stream in HBM — observed +8x on the decode memory term)
        s = jnp.einsum("bsgrd,btgd->bgrst", q5, k_i,
                       preferred_element_type=jnp.float32) * scale
        mask = kpos[None, None, None, None, :] <= \
            qpos[:, None, None, :, None]
        if kv_len is not None:
            mask &= kpos[None, None, None, None, :] < \
                kv_len[:, None, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrst,btgd->bgrsd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, g, r, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, g, r, sq), jnp.float32)
    a0 = jnp.zeros((b, g, r, sq, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q5.dtype)  # (B,Sq,G,R,D)


def _naive_attend(q5, k, v, qpos, kv_len) -> jnp.ndarray:
    b, sq, g, r, d = q5.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # K/V stay in storage dtype — f32 accumulation happens on the MXU
    s = jnp.einsum("bsgrd,btgd->bgrst", q5, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(skv)
    mask = kpos[None, None, None, None, :] <= qpos[:, None, None, :, None]
    if kv_len is not None:
        mask &= kpos[None, None, None, None, :] < \
            kv_len[:, None, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q5.dtype)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           qpos: jnp.ndarray, *, impl: str = "scan", kv_block: int = 1024,
           kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """q (B,Sq,H,D); k,v (B,Skv,G,D) with G | H.  qpos (B,Sq).

    impl: "scan" (pure-JAX flash, compiles everywhere incl. the dry-run),
    "pallas_flash" (the VMEM-resident TPU kernel; kernels/flash_attention),
    "naive" (reference).  Decode (Sq == 1) always takes the naive path.
    """
    b, sq, h, d = q.shape
    g = k.shape[2]
    dv = v.shape[-1]
    if impl == "pallas_flash" and sq > 1 and kv_len is None and d == dv:
        from ..kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=True)
    q5 = q.reshape(b, sq, g, h // g, d)
    if impl == "scan" and sq > 1:
        out = _online_softmax_scan(q5, k, v, qpos, kv_block, kv_len)
    else:
        out = _naive_attend(q5, k, v, qpos, kv_len)
    return out.reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_attention(x, p, cfg, positions, *, cache=None, cache_pos=None,
                  positions_3d=None):
    """x (B,S,d).  Returns (out (B,S,d), new_cache | None).

    Prefill/train: cache None (train) or dict to fill (prefill).
    Decode: S == 1, cache holds (B, Smax, G, D); cache_pos is a scalar
    (whole batch at one offset) or a (B,) int32 vector of per-row offsets
    (ragged continuous batching — see _cache_update).
    """
    b, s, _ = x.shape
    h, g, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, h, dh), "batch", "seq", "heads", None)
    k = constrain(k.reshape(b, s, g, dh), "batch", "seq", "kv_heads", None)
    v = constrain(v.reshape(b, s, g, dh), "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.m_rope:
        q = apply_m_rope(q, positions_3d, cfg.rope_theta, cfg.m_rope_sections)
        k = apply_m_rope(k, positions_3d, cfg.rope_theta, cfg.m_rope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_len = None
    delta = cfg.kv_cache_delta
    if cache is not None and cache_pos is not None:        # decode step
        ck = _cache_update(cache["k"], k, cache_pos, delta)
        cv = _cache_update(cache["v"], v, cache_pos, delta)
        new_cache = {"k": ck, "v": cv}
        k = _cache_load(ck, q.dtype, delta)
        v = _cache_load(cv, q.dtype, delta)
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None:                                 # prefill: fill
        ck = lax.dynamic_update_slice_in_dim(
            cache["k"], _cache_store(k, cache["k"], delta), 0, axis=1)
        cv = lax.dynamic_update_slice_in_dim(
            cache["v"], _cache_store(v, cache["v"], delta), 0, axis=1)
        new_cache = {"k": ck, "v": cv}

    out = attend(q, k, v, positions, impl=cfg.attn_impl,
                 kv_block=cfg.attn_kv_block, kv_len=kv_len)
    out = jnp.einsum("bsk,kd->bsd", out.reshape(b, s, h * dh), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_attention(x, p, cfg, positions, *, cache=None, cache_pos=None):
    """Latent-cache attention: the KV cache stores only (c_kv, k_rope)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        ql = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"],
                      cfg.norm_eps)
        q = jnp.einsum("bsr,rk->bsk", ql, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dk->bsk", x, p["w_uq"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)
    kr = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"])[:, :, None, :],
                    positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    kv_len = None
    delta = cfg.kv_cache_delta
    if cache is not None and cache_pos is not None:        # decode
        ckv_all = _cache_update(cache["ckv"], ckv, cache_pos, delta)
        kr_all = _cache_update(cache["kr"], kr, cache_pos, delta)
        new_cache = {"ckv": ckv_all, "kr": kr_all}
        ckv = _cache_load(ckv_all, x.dtype, delta)
        kr = _cache_load(kr_all, x.dtype, delta)
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None:                                 # prefill
        ckv_all = lax.dynamic_update_slice_in_dim(
            cache["ckv"], _cache_store(ckv, cache["ckv"], delta), 0, axis=1)
        kr_all = lax.dynamic_update_slice_in_dim(
            cache["kr"], _cache_store(kr, cache["kr"], delta), 0, axis=1)
        new_cache = {"ckv": ckv_all, "kr": kr_all}

    # up-project latents (recompute path; absorbed path is a perf option)
    k_nope = jnp.einsum("bsr,rk->bsk", ckv, p["w_uk"]).reshape(b, -1, h, dn)
    vv = jnp.einsum("bsr,rk->bsk", ckv, p["w_uv"]).reshape(b, -1, h, dv)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (*kr.shape[:2], h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attend(q_full, k_full, vv, positions, impl=cfg.attn_impl,
                 kv_block=cfg.attn_kv_block, kv_len=kv_len)
    out = jnp.einsum("bsk,kd->bsd", out.reshape(b, s, h * dv), p["wo"])
    return out, new_cache
