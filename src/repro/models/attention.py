"""Attention: GQA (+qk-norm, +bias, +M-RoPE) and MLA.

The attention math itself lives in the kernel registry
(``kernels.get("flash_attention")``): the pure-JAX online-softmax scan, the
naive reference, and the Pallas TPU kernel are registered impls, selected
per platform/shape by the model config's :class:`~repro.kernels.KernelPolicy`
(``cfg.kernels``).  Constraint-driven fallbacks (ragged ``kv_len``,
``d != dv``) are recorded in ``kernels.dispatch_report()`` and raise when a
pinned impl meets ``KernelPolicy(strict=True)``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .. import kernels as _kernels
from ..distributed.sharding import constrain
from ..serve.quantized import dequant_cache_value, quantize_cache_value
from .layers import apply_m_rope, apply_rope, q8_einsum, rms_norm


def _cache_store(x, cache_arr, delta):
    """Quantize to the cache's storage dtype (int8 fixed-point serving)."""
    if cache_arr.dtype == jnp.int8:
        return quantize_cache_value(x, delta)
    return x.astype(cache_arr.dtype)


def _cache_load(arr, dtype, delta):
    if arr.dtype == jnp.int8:
        return dequant_cache_value(arr, dtype, delta)
    return arr


def _cache_update(cache_arr, new_vals, cache_pos, delta):
    """Write this step's K/V into the preallocated cache.

    cache_pos scalar: all rows write at the same offset (one-shot batch).
    cache_pos (B,) int32: per-slot ragged positions (continuous batching) —
    each row scatters its single new entry at its own offset.
    """
    vals = _cache_store(new_vals, cache_arr, delta)
    cp = jnp.asarray(cache_pos)
    if cp.ndim == 0:
        return lax.dynamic_update_slice_in_dim(cache_arr, vals, cache_pos,
                                               axis=1)
    assert new_vals.shape[1] == 1, "ragged cache update is decode-only (S=1)"
    b = cache_arr.shape[0]
    return cache_arr.at[jnp.arange(b), cp].set(vals[:, 0])


def _paged_update_load(pool, new_vals, cache_pos, cache_pages, delta, dtype):
    """Paged decode: write one token into the page pool, read the batch's
    logical views back.

    pool (P, page, ...) is the shared hot-page pool (layer axis already
    consumed by the scan); cache_pages (B, n_max) int32 maps each row's
    logical page index to a pool page id.  Row ``i``'s new K/V lands in
    page ``cache_pages[i, pos // page]`` at offset ``pos % page``; the
    gathered view ``pool[cache_pages]`` reshapes to the row's contiguous
    (B, n_max*page, ...) cache.  Pool page 0 is the scheduler's scratch
    page: padding rows point every logical page at it, so their writes
    collide harmlessly there and never touch a live page.

    Returns (updated pool, per-row contiguous values in ``dtype``).
    """
    b = cache_pages.shape[0]
    assert new_vals.shape[1] == 1, "paged cache update is decode-only (S=1)"
    page_len = pool.shape[1]
    cp = jnp.asarray(cache_pos, jnp.int32)
    if cp.ndim == 0:
        cp = jnp.broadcast_to(cp, (b,))
    pid = jnp.take_along_axis(cache_pages, (cp // page_len)[:, None],
                              axis=1)[:, 0]
    pool = pool.at[pid, cp % page_len].set(
        _cache_store(new_vals, pool, delta)[:, 0])
    view = jnp.take(pool, cache_pages, axis=0)       # (B, n_max, page, ...)
    view = view.reshape(b, view.shape[1] * page_len, *view.shape[3:])
    return pool, _cache_load(view, dtype, delta)

# attend(impl=...) values -> registry impl names (the historical attend
# vocabulary predates the kernel registry, so "naive"/"pallas_flash"
# alias the registry's "ref"/"pallas")
_ATTN_IMPLS = {"scan": "scan", "naive": "ref",
               "pallas_flash": "pallas", "pallas": "pallas",
               "interpret": "interpret", "ref": "ref"}


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           qpos: jnp.ndarray, *, impl: str | None = None,
           policy=None, kv_block: int = 1024,
           kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """q (B,Sq,H,D); k,v (B,Skv,G,D) with G | H.  qpos (B,Sq).

    Dispatches through ``kernels.get("flash_attention")``.  ``policy``
    (normally ``cfg.kernels``) picks the impl per platform; ``impl`` is the
    legacy pin ("scan" / "naive" / "pallas_flash") mapped onto a policy
    override.  Decode (Sq == 1) resolves to the naive path inside the scan
    impl; the Pallas kernel's constraints (no ragged ``kv_len``,
    ``d == dv``) surface via ``kernels.dispatch_report()`` or raise under
    ``KernelPolicy(strict=True)``.
    """
    policy = policy or _kernels.KernelPolicy()
    if impl is not None:
        if impl not in _ATTN_IMPLS:
            raise ValueError(
                f"unknown attention impl {impl!r}; "
                f"one of {sorted(_ATTN_IMPLS)}")
        policy = policy.override("flash_attention", _ATTN_IMPLS[impl])
    return _kernels.get("flash_attention")(q, k, v, qpos, kv_block=kv_block,
                                           kv_len=kv_len, policy=policy)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_attention(x, p, cfg, positions, *, cache=None, cache_pos=None,
                  positions_3d=None, cache_pages=None):
    """x (B,S,d).  Returns (out (B,S,d), new_cache | None).

    Prefill/train: cache None (train) or dict to fill (prefill).
    Decode: S == 1, cache holds (B, Smax, G, D); cache_pos is a scalar
    (whole batch at one offset) or a (B,) int32 vector of per-row offsets
    (ragged continuous batching — see _cache_update).
    Paged decode: cache leaves are page *pools* (P, page, G, D) and
    cache_pages (B, n_max) int32 maps logical page index -> pool page id
    (see _paged_update_load; the serving page table lives in
    ``repro.serve.kv``).
    """
    b, s, _ = x.shape
    h, g, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = q8_einsum(x, p["wq"], policy=cfg.kernels)
    k = q8_einsum(x, p["wk"], policy=cfg.kernels)
    v = q8_einsum(x, p["wv"], policy=cfg.kernels)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, h, dh), "batch", "seq", "heads", None)
    k = constrain(k.reshape(b, s, g, dh), "batch", "seq", "kv_heads", None)
    v = constrain(v.reshape(b, s, g, dh), "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.m_rope:
        q = apply_m_rope(q, positions_3d, cfg.rope_theta, cfg.m_rope_sections)
        k = apply_m_rope(k, positions_3d, cfg.rope_theta, cfg.m_rope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_len = None
    delta = cfg.kv_cache_delta
    if cache is not None and cache_pages is not None:      # paged decode
        ck, k = _paged_update_load(cache["k"], k, cache_pos, cache_pages,
                                   delta, q.dtype)
        cv, v = _paged_update_load(cache["v"], v, cache_pos, cache_pages,
                                   delta, q.dtype)
        new_cache = {"k": ck, "v": cv}
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None and cache_pos is not None:      # decode step
        ck = _cache_update(cache["k"], k, cache_pos, delta)
        cv = _cache_update(cache["v"], v, cache_pos, delta)
        new_cache = {"k": ck, "v": cv}
        k = _cache_load(ck, q.dtype, delta)
        v = _cache_load(cv, q.dtype, delta)
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None:                                 # prefill: fill
        ck = lax.dynamic_update_slice_in_dim(
            cache["k"], _cache_store(k, cache["k"], delta), 0, axis=1)
        cv = lax.dynamic_update_slice_in_dim(
            cache["v"], _cache_store(v, cache["v"], delta), 0, axis=1)
        new_cache = {"k": ck, "v": cv}

    out = attend(q, k, v, positions, policy=cfg.kernels,
                 kv_block=cfg.attn_kv_block, kv_len=kv_len)
    out = q8_einsum(out.reshape(b, s, h * dh), p["wo"], policy=cfg.kernels)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_attention(x, p, cfg, positions, *, cache=None, cache_pos=None,
                  cache_pages=None):
    """Latent-cache attention: the KV cache stores only (c_kv, k_rope).

    ``cache_pages`` selects the paged-decode path exactly as in
    :func:`gqa_attention` — the pools are (P, page, R) latent pages."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        ql = rms_norm(q8_einsum(x, p["w_dq"], policy=cfg.kernels),
                      p["q_norm"], cfg.norm_eps)
        q = q8_einsum(ql, p["w_uq"], policy=cfg.kernels)
    else:
        q = q8_einsum(x, p["w_uq"], policy=cfg.kernels)
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rms_norm(q8_einsum(x, p["w_dkv"], policy=cfg.kernels),
                   p["kv_norm"], cfg.norm_eps)
    kr = apply_rope(
        q8_einsum(x, p["w_kr"], policy=cfg.kernels)[:, :, None, :],
        positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    kv_len = None
    delta = cfg.kv_cache_delta
    if cache is not None and cache_pages is not None:      # paged decode
        ckv_all, ckv = _paged_update_load(cache["ckv"], ckv, cache_pos,
                                          cache_pages, delta, x.dtype)
        kr_all, kr = _paged_update_load(cache["kr"], kr, cache_pos,
                                        cache_pages, delta, x.dtype)
        new_cache = {"ckv": ckv_all, "kr": kr_all}
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None and cache_pos is not None:      # decode
        ckv_all = _cache_update(cache["ckv"], ckv, cache_pos, delta)
        kr_all = _cache_update(cache["kr"], kr, cache_pos, delta)
        new_cache = {"ckv": ckv_all, "kr": kr_all}
        ckv = _cache_load(ckv_all, x.dtype, delta)
        kr = _cache_load(kr_all, x.dtype, delta)
        kv_len = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32) + s, (b,))
    elif cache is not None:                                 # prefill
        ckv_all = lax.dynamic_update_slice_in_dim(
            cache["ckv"], _cache_store(ckv, cache["ckv"], delta), 0, axis=1)
        kr_all = lax.dynamic_update_slice_in_dim(
            cache["kr"], _cache_store(kr, cache["kr"], delta), 0, axis=1)
        new_cache = {"ckv": ckv_all, "kr": kr_all}

    # up-project latents (recompute path; absorbed path is a perf option)
    k_nope = q8_einsum(ckv, p["w_uk"],
                       policy=cfg.kernels).reshape(b, -1, h, dn)
    vv = q8_einsum(ckv, p["w_uv"], policy=cfg.kernels).reshape(b, -1, h, dv)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (*kr.shape[:2], h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attend(q_full, k_full, vv, positions, policy=cfg.kernels,
                 kv_block=cfg.attn_kv_block, kv_len=kv_len)
    out = q8_einsum(out.reshape(b, s, h * dv), p["wo"], policy=cfg.kernels)
    return out, new_cache
