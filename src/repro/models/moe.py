"""Capacity-routed top-k MoE (DeepSeek-style shared + routed experts).

Grouped scatter/gather dispatch (GShard groups == sequences):

* tokens stay (G=batch, S, d) — the scatter into the per-group expert buffer
  (G, E, C, d) is *group-local*, so under pjit the G dim shards with the
  batch axes and no cross-group collective is generated (the naive global
  scatter lowered to a full-buffer all-reduce: ~150 GB/layer for
  deepseek-v3 train_4k — observed, then fixed by this formulation);
* the expert dim of the buffer is shard-constrained to the EP axis
  ("expert"); the token->expert-shard boundary is where the partitioner
  inserts the all-to-all / masked-psum exchange;
* rule sets pick the EP axis: training shards experts on "model" (G on the
  batch axes), serving shards experts on ("data","model") = 256-way with G
  replicated — a 1.3 TB expert bank cannot replicate over data (DESIGN §6).

Tokens past an expert's per-group capacity are dropped (contribution
zeroed), standard for capacity routing; the aux load-balance loss keeps
drop rates low.  The dense (G,S,E,C) one-hot einsum formulation of
GShard/Switch is a non-starter at 256 experts top-8 (~34 TB dispatch
tensor for deepseek-v3 train_4k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .. import kernels as _kernels
from ..distributed.sharding import constrain
from .layers import activation, q8_einsum


def _expert_einsum(buf: jnp.ndarray, w, *, policy=None) -> jnp.ndarray:
    """Per-expert matmul buf (G, E, C, K) @ w (E, K, N) -> (G, E, C, N).

    ``w`` is either the dense stacked expert bank (plain einsum) or a q8
    leaf {"q8": (E, K, N) int8, "q8s": (E, N) | (N,) f32} — the (N,) form
    is the stacked-MoE wire format, one per-channel Delta shared across
    the layer's experts.  The q8 path flattens the group/capacity dims to
    the grouped kernel's per-expert M and routes through
    ``kernels.get("dequant_matmul_grouped")`` so the expert bank stays
    int8-resident in HBM.
    """
    if _kernels.is_q8_leaf(w):
        g, e, c, k = buf.shape
        xg = buf.transpose(1, 0, 2, 3).reshape(e, g * c, k)
        out = _kernels.get("dequant_matmul_grouped")(
            xg, w["q8"], w["q8s"], policy=policy)
        return (out.reshape(e, g, c, -1).transpose(1, 0, 2, 3)
                .astype(buf.dtype))
    return jnp.einsum("gecd,edf->gecf", buf, w)


def moe_capacity(group_tokens: int, cfg) -> int:
    cap = int(group_tokens * cfg.top_k * cfg.capacity_factor
              / cfg.num_experts)
    return max(cap - cap % -8, 8)   # round up to a multiple of 8


def moe_block(x: jnp.ndarray, p: dict, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).  Group g = batch row."""
    g, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k

    if _kernels.is_q8_leaf(p["router"]):
        logits = q8_einsum(x.astype(jnp.float32), p["router"],
                           policy=cfg.kernels)
    else:
        logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                            p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, k)                     # (g, s, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    cap = moe_capacity(s, cfg)
    buf = jnp.zeros((g, e, cap, d), dtype=x.dtype)
    base = jnp.zeros((g, e), dtype=jnp.int32)
    slot_pos, slot_keep = [], []
    scatter = jax.vmap(lambda bg, ei, ci, vi: bg.at[ei, ci].add(vi))
    for j in range(k):
        ej = topi[..., j]                                # (g, s)
        oh = jax.nn.one_hot(ej, e, dtype=jnp.int32)      # (g, s, e)
        pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1), ej[..., None],
                                  axis=2)[..., 0] - 1
        pos = pos + jnp.take_along_axis(base, ej, axis=1)
        base = base + jnp.sum(oh, axis=1)
        keep = pos < cap
        cpos = jnp.clip(pos, 0, cap - 1)
        contrib = jnp.where(keep, 1.0, 0.0).astype(x.dtype)[..., None] * x
        buf = scatter(buf, ej, cpos, contrib)
        slot_pos.append(cpos)
        slot_keep.append(keep)

    # routed experts: stacked SwiGLU on the EP-sharded buffer
    buf = constrain(buf, "moe_group", "expert", None, None)
    gate = activation(_expert_einsum(buf, p["w_gate"], policy=cfg.kernels),
                      cfg.act)
    up = _expert_einsum(buf, p["w_up"], policy=cfg.kernels)
    hbuf = _expert_einsum(gate * up, p["w_down"], policy=cfg.kernels)
    hbuf = constrain(hbuf, "moe_group", "expert", None, None)

    gather = jax.vmap(lambda hb, ei, ci: hb[ei, ci])
    out = jnp.zeros((g, s, d), dtype=x.dtype)
    for j in range(k):
        vals = gather(hbuf, topi[..., j], slot_pos[j])   # (g, s, d)
        w = (topw[..., j] * slot_keep[j]).astype(x.dtype)
        out = out + w[..., None] * vals

    # shared experts: fused dense SwiGLU of width num_shared * moe_d_ff
    if cfg.num_shared_experts:
        sg = activation(q8_einsum(x, p["sh_gate"], policy=cfg.kernels),
                        cfg.act)
        su = q8_einsum(x, p["sh_up"], policy=cfg.kernels)
        out = out + q8_einsum(sg * su, p["sh_down"], policy=cfg.kernels)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                     # (e,)
    assigned = jnp.zeros((e,), jnp.float32)
    for j in range(k):
        assigned = assigned + jnp.sum(
            jax.nn.one_hot(topi[..., j], e, dtype=jnp.float32), axis=(0, 1))
    fe = assigned / (g * s * k)
    aux = e * jnp.sum(fe * me)
    return out, aux
