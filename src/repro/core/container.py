"""Serialized bitstream container for DeepCABAC-coded pytrees.

Layout (little-endian):

    magic 'DCBC' | version u16 | num_records u32
    per record:
      name: u16 len + utf8
      encoding: u8         (0 = raw bytes, 1 = cabac levels,
                            2 = huffman levels, 3 = int8 levels + scales,
                            4 = cabac levels + lane metadata,
                            5 = temporal-context cabac level residuals)
      dtype str: u8 len + ascii   (original array dtype)
      ndim u8, dims u32[ndim]
      if encoding == 1:
        step f64 | num_gr u8 | chunk_size u32 | num_chunks u32
        chunk_byte_lens u32[num_chunks]
      if encoding == 2:
        step f64             (payload: self-describing table + bitstream)
      if encoding == 3:
        scale_ndim u8, scale_dims u32[scale_ndim]
                             (payload: f32 scales then int8 levels)
      if encoding == 4 or encoding == 5:
        step f64 | num_gr u8 | chunk_size u32 | total_count u64
        num_chunks u32 | chunk_byte_lens u32[num_chunks]
        chunk_counts u32[num_chunks]
      payload_len u64 | payload

Version 1 containers hold only raw/cabac records; version 2 adds the
huffman and q8 encodings; version 3 adds the lane-scheduled cabac record
(encoding 4), whose bitstream chunks are byte-identical to encoding 1 —
only the header grows per-chunk value counts and the total count, so a
reader can schedule all chunks of a tensor (or of a whole state dict)
into one lane-parallel decode batch without deriving counts from shapes
(repro.core.cabac_vec).  Version 4 adds the temporal-context delta
record (encoding 5): its header layout is identical to encoding 4, but
the levels are *residuals* against a base frame named outside the
container (the delta chain manifest, ``repro.checkpoint.delta``), and
the bitstream uses the temporal-context CABAC mode — each value's
context bank is selected by the class of its co-located base-frame level
(``cabac.temporal_classes``), so a delta record is undecodable without
its base.  The writer emits the lowest version that covers the records
present, so pre-existing readers and blobs stay byte-compatible on the
common path, and older readers reject newer blobs with a versioned error
instead of misparsing them.

Chunks are independently decodable (fresh context state per chunk) so a
multi-host restore can fan decode out across hosts/processes — or across
SIMD lanes in one process; the rate cost of chunking is measured in
benchmarks (<1% for 64Ki chunks).

Records are also independently *addressable*: :meth:`ContainerWriter.
record_spans` reports each record's (offset, length) in the serialized
container, and :func:`read_record_at` parses exactly one record from a
byte-range read — no container header, no whole-file mmap.  This is the
random-access contract the sharded-checkpoint manifest
(``repro.checkpoint.sharded``) builds on.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"DCBC"
VERSION = 1
VERSION_V2 = 2
VERSION_V3 = 3
VERSION_V4 = 4
SUPPORTED_VERSIONS = (VERSION, VERSION_V2, VERSION_V3, VERSION_V4)
HEADER_LEN = 10          # magic + version u16 + num_records u32
ENC_RAW = 0
ENC_CABAC = 1
ENC_HUFF = 2
ENC_Q8 = 3
ENC_CABAC_V3 = 4
ENC_CABAC_DELTA = 5


@dataclass
class RecordHeader:
    name: str
    encoding: int
    dtype: str
    shape: tuple[int, ...]
    step: float = 0.0
    num_gr: int = 0
    chunk_size: int = 0
    chunk_lens: tuple[int, ...] = ()
    scale_shape: tuple[int, ...] = ()
    chunk_counts: tuple[int, ...] = ()   # v3 lane metadata
    total_count: int = 0                 # v3: sum(chunk_counts), validated


def _pack_str(s: str, lenfmt: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack(lenfmt, len(b)) + b


class ContainerWriter:
    def __init__(self):
        self._records: list[bytes] = []
        self._needs_v2 = False
        self._needs_v3 = False
        self._needs_v4 = False

    def add_raw(self, name: str, arr: np.ndarray) -> None:
        payload = np.ascontiguousarray(arr).tobytes()
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_RAW)
               + _pack_str(str(arr.dtype), "<B")
               + struct.pack("<B", arr.ndim)
               + struct.pack(f"<{arr.ndim}I", *arr.shape))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)

    def add_cabac(self, name: str, dtype: str, shape: tuple[int, ...],
                  step: float, num_gr: int, chunk_size: int,
                  chunk_payloads: list[bytes]) -> None:
        payload = b"".join(chunk_payloads)
        ndim = len(shape)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_CABAC)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<dBII", step, num_gr, chunk_size,
                             len(chunk_payloads))
               + struct.pack(f"<{len(chunk_payloads)}I",
                             *[len(c) for c in chunk_payloads]))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)

    def add_cabac_v3(self, name: str, dtype: str, shape: tuple[int, ...],
                     step: float, num_gr: int, chunk_size: int,
                     chunk_payloads: list[bytes],
                     chunk_counts: list[int]) -> None:
        """CABAC chunks with lane metadata: per-chunk value counts and the
        total count travel in the header, so a reader can schedule every
        chunk straight into a vectorized decode batch.  The chunk
        bitstreams themselves are byte-identical to :meth:`add_cabac`."""
        if len(chunk_counts) != len(chunk_payloads):
            raise ValueError(
                f"{len(chunk_counts)} chunk counts for "
                f"{len(chunk_payloads)} chunk payloads")
        total = sum(int(c) for c in chunk_counts)
        payload = b"".join(chunk_payloads)
        ndim = len(shape)
        nch = len(chunk_payloads)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_CABAC_V3)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<dBIQI", step, num_gr, chunk_size, total, nch)
               + struct.pack(f"<{nch}I", *[len(c) for c in chunk_payloads])
               + struct.pack(f"<{nch}I", *chunk_counts))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v3 = True

    def add_cabac_delta(self, name: str, dtype: str, shape: tuple[int, ...],
                        step: float, num_gr: int, chunk_size: int,
                        chunk_payloads: list[bytes],
                        chunk_counts: list[int]) -> None:
        """Temporal-context-coded level *residuals* against a base frame.

        Header layout is identical to :meth:`add_cabac_v3`; the chunk
        bitstreams differ (temporal-context banks, cabac_vec
        ``encode_lanes_tc``) and can only be decoded next to the base
        frame's levels — the chain linkage lives in the delta manifest
        (``repro.checkpoint.delta``), not in the container."""
        if len(chunk_counts) != len(chunk_payloads):
            raise ValueError(
                f"{len(chunk_counts)} chunk counts for "
                f"{len(chunk_payloads)} chunk payloads")
        total = sum(int(c) for c in chunk_counts)
        payload = b"".join(chunk_payloads)
        ndim = len(shape)
        nch = len(chunk_payloads)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_CABAC_DELTA)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<dBIQI", step, num_gr, chunk_size, total, nch)
               + struct.pack(f"<{nch}I", *[len(c) for c in chunk_payloads])
               + struct.pack(f"<{nch}I", *chunk_counts))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v4 = True

    def add_huffman(self, name: str, dtype: str, shape: tuple[int, ...],
                    step: float, payload: bytes) -> None:
        """Canonical-Huffman-coded levels; the payload carries its own
        two-part code table (symbols + lengths) ahead of the bitstream."""
        ndim = len(shape)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_HUFF)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<d", step))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v2 = True

    def add_q8(self, name: str, dtype: str, levels: np.ndarray,
               scale: np.ndarray) -> None:
        """Raw int8 levels with per-channel f32 scales (fixed-point serving)."""
        levels = np.ascontiguousarray(levels)
        if levels.dtype != np.int8:
            raise TypeError(f"q8 levels must be int8, got {levels.dtype}")
        scale = np.ascontiguousarray(scale, dtype="<f4")   # explicit LE,
        # matching the reader and the container's documented layout
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_Q8)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", levels.ndim)
               + struct.pack(f"<{levels.ndim}I", *levels.shape)
               + struct.pack("<B", scale.ndim)
               + struct.pack(f"<{scale.ndim}I", *scale.shape))
        payload = scale.tobytes() + levels.tobytes()
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v2 = True

    def tobytes(self) -> bytes:
        version = (VERSION_V4 if self._needs_v4
                   else VERSION_V3 if self._needs_v3
                   else VERSION_V2 if self._needs_v2 else VERSION)
        head = MAGIC + struct.pack("<HI", version, len(self._records))
        return head + b"".join(self._records)

    def record_spans(self) -> list[tuple[int, int]]:
        """(byte offset, byte length) of each record in the container
        :meth:`tobytes` serializes, in add order.  Offsets include the
        container header, so a reader can pread one record straight out
        of the file and hand it to :func:`read_record_at` — the
        sharded-checkpoint manifest persists exactly these spans."""
        spans, off = [], HEADER_LEN
        for rec in self._records:
            spans.append((off, len(rec)))
            off += len(rec)
        return spans


def _parse_record(data, view, off: int, label: str
                  ) -> tuple[RecordHeader, memoryview, int]:
    """Parse one record at ``off``; returns (header, payload, next offset).

    ``label`` names the record in truncation errors ("record 3 of 9" for
    the whole-container iterator, "byte-range record" for pread paths).
    The payload is a zero-copy memoryview slice of ``view``.
    """
    try:
        (nlen,) = struct.unpack_from("<H", data, off); off += 2
        name = bytes(data[off:off + nlen]).decode("utf-8"); off += nlen
        (enc,) = struct.unpack_from("<B", data, off); off += 1
        (dlen,) = struct.unpack_from("<B", data, off); off += 1
        dtype = bytes(data[off:off + dlen]).decode("ascii"); off += dlen
        (ndim,) = struct.unpack_from("<B", data, off); off += 1
        shape = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        step, num_gr, chunk_size, nchunks = 0.0, 0, 0, 0
        total = 0
        chunk_lens: tuple[int, ...] = ()
        chunk_counts: tuple[int, ...] = ()
        scale_shape: tuple[int, ...] = ()
        if enc == ENC_CABAC:
            step, num_gr, chunk_size, nchunks = struct.unpack_from(
                "<dBII", data, off)
            off += 17
            chunk_lens = struct.unpack_from(f"<{nchunks}I", data, off)
            off += 4 * nchunks
        elif enc in (ENC_CABAC_V3, ENC_CABAC_DELTA):
            step, num_gr, chunk_size, total, nchunks = \
                struct.unpack_from("<dBIQI", data, off)
            off += 25
            chunk_lens = struct.unpack_from(f"<{nchunks}I", data, off)
            off += 4 * nchunks
            chunk_counts = struct.unpack_from(f"<{nchunks}I", data, off)
            off += 4 * nchunks
        elif enc == ENC_HUFF:
            (step,) = struct.unpack_from("<d", data, off)
            off += 8
        elif enc == ENC_Q8:
            (sndim,) = struct.unpack_from("<B", data, off); off += 1
            scale_shape = struct.unpack_from(f"<{sndim}I", data, off)
            off += 4 * sndim
        (plen,) = struct.unpack_from("<Q", data, off); off += 8
    except (struct.error, UnicodeDecodeError) as e:
        # UnicodeDecodeError: a mis-aligned byte-range read lands the
        # name/dtype fields on arbitrary bytes — same failure class as a
        # short read, same descriptive error
        raise ValueError(
            f"truncated DCBC record header ({label})") from e
    if off + plen > len(data):
        raise ValueError(
            f"truncated DCBC record payload: {label} ({name!r}) wants "
            f"{plen} bytes, {len(data) - off} remain")
    payload = view[off:off + plen]
    hdr = RecordHeader(name, enc, dtype, tuple(shape), step, num_gr,
                       chunk_size, chunk_lens, tuple(scale_shape),
                       chunk_counts, total)
    return hdr, payload, off + plen


def read_record_at(data, offset: int = 0
                   ) -> tuple[RecordHeader, memoryview]:
    """Parse exactly one record from ``data`` starting at ``offset``.

    ``data`` is a *byte-range read* of one record — no container header,
    no surrounding records required — so a manifest-driven restore can
    ``seek(offset); read(length)`` a single shard record out of a large
    shard file instead of mapping the whole file
    (``ContainerWriter.record_spans`` is where the spans come from).
    Truncated inputs raise a descriptive ``ValueError`` like the
    whole-container reader."""
    view = memoryview(data)
    hdr, payload, _ = _parse_record(data, view, offset, "byte-range record")
    return hdr, payload


class ContainerReader:
    def __init__(self, data: bytes, max_version: int = VERSION_V4):
        """``max_version`` emulates an older reader generation (compat
        tests); production callers keep the default."""
        if len(data) < HEADER_LEN:
            raise ValueError(
                f"truncated DCBC container: {len(data)} bytes, need at "
                f"least the {HEADER_LEN}-byte header")
        if data[:4] != MAGIC:
            raise ValueError("not a DCBC container (bad magic)")
        version, self.num_records = struct.unpack_from("<HI", data, 4)
        if version not in SUPPORTED_VERSIONS or version > max_version:
            raise ValueError(
                f"unsupported container version {version} "
                f"(this reader handles <= {max_version})")
        self.version = version
        self._data = data
        self._offset = HEADER_LEN

    def __iter__(self):
        data = self._data
        # payloads are yielded as zero-copy memoryview slices: a streaming
        # consumer (serve weight backends) then pays one decoded-tensor
        # copy per record, not an extra per-record payload copy
        view = memoryview(data)
        off = self._offset
        for rec in range(self.num_records):
            hdr, payload, off = _parse_record(
                data, view, off, f"record {rec} of {self.num_records}")
            yield hdr, payload
