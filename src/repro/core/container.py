"""Serialized bitstream container for DeepCABAC-coded pytrees.

Layout (little-endian):

    magic 'DCBC' | version u16 | num_records u32
    per record:
      name: u16 len + utf8
      encoding: u8         (0 = raw bytes, 1 = cabac levels,
                            2 = huffman levels, 3 = int8 levels + scales)
      dtype str: u8 len + ascii   (original array dtype)
      ndim u8, dims u32[ndim]
      if encoding == 1:
        step f64 | num_gr u8 | chunk_size u32 | num_chunks u32
        chunk_byte_lens u32[num_chunks]
      if encoding == 2:
        step f64             (payload: self-describing table + bitstream)
      if encoding == 3:
        scale_ndim u8, scale_dims u32[scale_ndim]
                             (payload: f32 scales then int8 levels)
      payload_len u64 | payload

Version 1 containers hold only raw/cabac records; version 2 adds the
huffman and q8 encodings.  The writer emits version 1 whenever no v2
record type is present, so pre-existing readers and blobs stay
byte-compatible on the common path.

Chunks are independently decodable (fresh context state per chunk) so a
multi-host restore can fan decode out across hosts/processes; the rate cost
of chunking is measured in benchmarks (<1% for 64Ki chunks).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"DCBC"
VERSION = 1
VERSION_V2 = 2
ENC_RAW = 0
ENC_CABAC = 1
ENC_HUFF = 2
ENC_Q8 = 3


@dataclass
class RecordHeader:
    name: str
    encoding: int
    dtype: str
    shape: tuple[int, ...]
    step: float = 0.0
    num_gr: int = 0
    chunk_size: int = 0
    chunk_lens: tuple[int, ...] = ()
    scale_shape: tuple[int, ...] = ()


def _pack_str(s: str, lenfmt: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack(lenfmt, len(b)) + b


class ContainerWriter:
    def __init__(self):
        self._records: list[bytes] = []
        self._needs_v2 = False

    def add_raw(self, name: str, arr: np.ndarray) -> None:
        payload = np.ascontiguousarray(arr).tobytes()
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_RAW)
               + _pack_str(str(arr.dtype), "<B")
               + struct.pack("<B", arr.ndim)
               + struct.pack(f"<{arr.ndim}I", *arr.shape))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)

    def add_cabac(self, name: str, dtype: str, shape: tuple[int, ...],
                  step: float, num_gr: int, chunk_size: int,
                  chunk_payloads: list[bytes]) -> None:
        payload = b"".join(chunk_payloads)
        ndim = len(shape)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_CABAC)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<dBII", step, num_gr, chunk_size,
                             len(chunk_payloads))
               + struct.pack(f"<{len(chunk_payloads)}I",
                             *[len(c) for c in chunk_payloads]))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)

    def add_huffman(self, name: str, dtype: str, shape: tuple[int, ...],
                    step: float, payload: bytes) -> None:
        """Canonical-Huffman-coded levels; the payload carries its own
        two-part code table (symbols + lengths) ahead of the bitstream."""
        ndim = len(shape)
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_HUFF)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", ndim) + struct.pack(f"<{ndim}I", *shape)
               + struct.pack("<d", step))
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v2 = True

    def add_q8(self, name: str, dtype: str, levels: np.ndarray,
               scale: np.ndarray) -> None:
        """Raw int8 levels with per-channel f32 scales (fixed-point serving)."""
        levels = np.ascontiguousarray(levels)
        if levels.dtype != np.int8:
            raise TypeError(f"q8 levels must be int8, got {levels.dtype}")
        scale = np.ascontiguousarray(scale, dtype="<f4")   # explicit LE,
        # matching the reader and the container's documented layout
        hdr = (_pack_str(name, "<H") + struct.pack("<B", ENC_Q8)
               + _pack_str(dtype, "<B")
               + struct.pack("<B", levels.ndim)
               + struct.pack(f"<{levels.ndim}I", *levels.shape)
               + struct.pack("<B", scale.ndim)
               + struct.pack(f"<{scale.ndim}I", *scale.shape))
        payload = scale.tobytes() + levels.tobytes()
        self._records.append(hdr + struct.pack("<Q", len(payload)) + payload)
        self._needs_v2 = True

    def tobytes(self) -> bytes:
        version = VERSION_V2 if self._needs_v2 else VERSION
        head = MAGIC + struct.pack("<HI", version, len(self._records))
        return head + b"".join(self._records)


class ContainerReader:
    def __init__(self, data: bytes):
        if data[:4] != MAGIC:
            raise ValueError("not a DCBC container")
        version, self.num_records = struct.unpack_from("<HI", data, 4)
        if version not in (VERSION, VERSION_V2):
            raise ValueError(f"unsupported container version {version}")
        self._data = data
        self._offset = 10

    def __iter__(self):
        data = self._data
        # payloads are yielded as zero-copy memoryview slices: a streaming
        # consumer (serve weight backends) then pays one decoded-tensor
        # copy per record, not an extra per-record payload copy
        view = memoryview(data)
        off = self._offset
        for _ in range(self.num_records):
            (nlen,) = struct.unpack_from("<H", data, off); off += 2
            name = data[off:off + nlen].decode("utf-8"); off += nlen
            (enc,) = struct.unpack_from("<B", data, off); off += 1
            (dlen,) = struct.unpack_from("<B", data, off); off += 1
            dtype = data[off:off + dlen].decode("ascii"); off += dlen
            (ndim,) = struct.unpack_from("<B", data, off); off += 1
            shape = struct.unpack_from(f"<{ndim}I", data, off); off += 4 * ndim
            step, num_gr, chunk_size, nchunks = 0.0, 0, 0, 0
            chunk_lens: tuple[int, ...] = ()
            scale_shape: tuple[int, ...] = ()
            if enc == ENC_CABAC:
                step, num_gr, chunk_size, nchunks = struct.unpack_from(
                    "<dBII", data, off)
                off += 17
                chunk_lens = struct.unpack_from(f"<{nchunks}I", data, off)
                off += 4 * nchunks
            elif enc == ENC_HUFF:
                (step,) = struct.unpack_from("<d", data, off)
                off += 8
            elif enc == ENC_Q8:
                (sndim,) = struct.unpack_from("<B", data, off); off += 1
                scale_shape = struct.unpack_from(f"<{sndim}I", data, off)
                off += 4 * sndim
            (plen,) = struct.unpack_from("<Q", data, off); off += 8
            payload = view[off:off + plen]; off += plen
            yield RecordHeader(name, enc, dtype, tuple(shape), step, num_gr,
                               chunk_size, chunk_lens, tuple(scale_shape)), \
                payload
