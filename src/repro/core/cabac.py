"""Adaptive binary range coder with context models (the CABAC engine).

This is the lossless entropy-coding engine of DeepCABAC (paper §II-B, §III-B).
It is an *exact* binary arithmetic coder: ``decode(encode(bits)) == bits``
always, for any adaptation trajectory.

Design notes
------------
* H.264/AVC CABAC proper uses the table-driven, multiplication-free M-coder
  for hardware friendliness.  On a host CPU we use the multiplicative range
  coder (LZMA-style 64-bit low / 32-bit range with carry propagation), which
  is rate-equivalent to within a fraction of a percent and much simpler to
  verify.  The *context modelling* — the part that matters for compression —
  follows CABAC: per-bin adaptive binary probability states with exponential
  decay updates, plus uncontexted "bypass" bins for near-uniform bits.
* Probabilities are 12-bit (``PROB_BITS``); adaptation shift 5 gives a decay
  rate close to CABAC's 0.95 alpha.
* The coder is host-side by design: the bin-by-bin interval subdivision is
  inherently sequential (see DESIGN.md §3.1).  Parallelism comes from
  chunking at the container layer (codec.py), never from inside a stream.
"""

from __future__ import annotations

import math

import numpy as np

PROB_BITS = 12
PROB_ONE = 1 << PROB_BITS          # 4096
PROB_HALF = PROB_ONE >> 1          # 2048
PROB_MIN = 16                      # keep contexts away from 0/1 (stability)
PROB_MAX = PROB_ONE - PROB_MIN
ADAPT_SHIFT = 5                    # CABAC-like adaptation speed
TOP = 1 << 24
MASK32 = 0xFFFFFFFF
MASK40 = 0xFFFFFFFFFF


class ContextSet:
    """A bank of adaptive binary probability models.

    ``probs[i]`` is P(bin == 1) for context ``i``, scaled to ``PROB_ONE``.
    Encoder and decoder construct identical banks and update them identically
    (backward adaptation — nothing is transmitted).
    """

    __slots__ = ("probs",)

    def __init__(self, num_contexts: int):
        self.probs = [PROB_HALF] * num_contexts

    def reset(self) -> None:
        for i in range(len(self.probs)):
            self.probs[i] = PROB_HALF

    def snapshot(self) -> np.ndarray:
        return np.asarray(self.probs, dtype=np.int32)


class RangeEncoder:
    """LZMA-style binary range encoder with carry propagation."""

    def __init__(self, contexts: ContextSet):
        self.ctx = contexts
        self.low = 0                  # up to 40 bits before shift_low
        self.range = MASK32
        self.cache = 0
        self.cache_size = 1           # first shift_low emits a leading 0 byte
        self.out = bytearray()
        self.bins_coded = 0

    # -- internals ---------------------------------------------------------
    def _shift_low(self) -> None:
        low = self.low
        if low < 0xFF000000 or low > MASK32:
            carry = low >> 32
            out = self.out
            out.append((self.cache + carry) & 0xFF)
            filler = (0xFF + carry) & 0xFF
            for _ in range(self.cache_size - 1):
                out.append(filler)
            self.cache_size = 0
            self.cache = (low >> 24) & 0xFF
        self.cache_size += 1
        self.low = (low << 8) & MASK32

    # -- public API --------------------------------------------------------
    def encode_bin(self, ctx_idx: int, bit: int) -> None:
        probs = self.ctx.probs
        p1 = probs[ctx_idx]
        bound = (self.range >> PROB_BITS) * p1
        if bit:
            self.range = bound
            p1 += (PROB_ONE - p1) >> ADAPT_SHIFT
            if p1 > PROB_MAX:
                p1 = PROB_MAX
        else:
            self.low += bound
            self.range -= bound
            p1 -= p1 >> ADAPT_SHIFT
            if p1 < PROB_MIN:
                p1 = PROB_MIN
        probs[ctx_idx] = p1
        if self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self._shift_low()
        self.bins_coded += 1

    def encode_bypass(self, bit: int) -> None:
        self.range >>= 1
        if bit:
            self.low += self.range
        if self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self._shift_low()
        self.bins_coded += 1

    def encode_bypass_bits(self, value: int, nbits: int) -> None:
        for shift in range(nbits - 1, -1, -1):
            self.encode_bypass((value >> shift) & 1)

    def finish(self) -> bytes:
        for _ in range(5):
            self._shift_low()
        # Drop the leading dummy zero byte emitted by the first shift_low.
        return bytes(self.out[1:])


class RangeDecoder:
    """Mirror of :class:`RangeEncoder`."""

    def __init__(self, data: bytes, contexts: ContextSet):
        self.ctx = contexts
        self.data = data
        self.pos = 0
        self.range = MASK32
        code = 0
        for _ in range(4):
            code = ((code << 8) | self._next_byte()) & MASK32
        self.code = code

    def _next_byte(self) -> int:
        d = self.data
        if self.pos < len(d):
            b = d[self.pos]
            self.pos += 1
            return b
        return 0  # zero-padding past the end is safe for range coders

    def decode_bin(self, ctx_idx: int) -> int:
        probs = self.ctx.probs
        p1 = probs[ctx_idx]
        bound = (self.range >> PROB_BITS) * p1
        if self.code < bound:
            bit = 1
            self.range = bound
            p1 += (PROB_ONE - p1) >> ADAPT_SHIFT
            if p1 > PROB_MAX:
                p1 = PROB_MAX
        else:
            bit = 0
            self.code -= bound
            self.range -= bound
            p1 -= p1 >> ADAPT_SHIFT
            if p1 < PROB_MIN:
                p1 = PROB_MIN
        probs[ctx_idx] = p1
        if self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self.code = ((self.code << 8) | self._next_byte()) & MASK32
        return bit

    def decode_bypass(self) -> int:
        self.range >>= 1
        if self.code >= self.range:
            self.code -= self.range
            bit = 1
        else:
            bit = 0
        if self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self.code = ((self.code << 8) | self._next_byte()) & MASK32
        return bit

    def decode_bypass_bits(self, nbits: int) -> int:
        v = 0
        for _ in range(nbits):
            v = (v << 1) | self.decode_bypass()
        return v


# ---------------------------------------------------------------------------
# Temporal context classes (delta / "P-frame" coding)
# ---------------------------------------------------------------------------

# Residuals between two checkpoints are coded with a *temporal-context*
# CABAC mode: every element selects one of TEMPORAL_CLASSES context banks
# by the significance of its co-located previous-frame level — the
# inter-frame analogue of the sigFlag's previous-weight conditioning.
# Class 0: prev level was zero; class 1: small (|prev| <= TC_SMALL_MAX);
# class 2: large.  The thresholds are part of the wire format (both sides
# derive classes from the shared base frame; nothing is transmitted), so
# changing them is a container-version event.
TEMPORAL_CLASSES = 3
TC_SMALL_MAX = 2


def temporal_classes(prev_levels) -> np.ndarray:
    """Per-element context-bank class of a delta stream, derived from the
    co-located base-frame levels.  Encoder and decoder call this on the
    *same* base levels, so the class arrays — and therefore every context
    index — agree bit-for-bit across the scalar/numpy/C engines."""
    a = np.abs(np.asarray(prev_levels, dtype=np.int64).ravel())
    return (a > 0).astype(np.int64) + (a > TC_SMALL_MAX).astype(np.int64)


# ---------------------------------------------------------------------------
# Rate bookkeeping helpers (used by analysis & the RD rate model)
# ---------------------------------------------------------------------------

def bin_cost_bits(p1: float, bit: int) -> float:
    """Ideal code length of one bin under P(1)=p1."""
    p = p1 if bit else (1.0 - p1)
    return -math.log2(max(p, 1e-12))


def adaptive_stream_bits(bits: np.ndarray, ctx_ids: np.ndarray,
                         num_contexts: int) -> float:
    """Exact ideal bit count of an (adaptively coded) bin stream.

    Runs the same probability adaptation as the coder but accumulates
    -log2(p) instead of producing bytes.  Bypass bins are flagged with
    ``ctx_ids == -1`` and cost exactly 1 bit.
    """
    probs = [PROB_HALF] * num_contexts
    total = 0.0
    for bit, c in zip(bits.tolist(), ctx_ids.tolist()):
        if c < 0:
            total += 1.0
            continue
        p1 = probs[c]
        if bit:
            total += -math.log2(p1 / PROB_ONE)
            p1 += (PROB_ONE - p1) >> ADAPT_SHIFT
            probs[c] = min(p1, PROB_MAX)
        else:
            total += -math.log2(1.0 - p1 / PROB_ONE)
            p1 -= p1 >> ADAPT_SHIFT
            probs[c] = max(p1, PROB_MIN)
    return total
