"""Scalar Huffman coding baseline (paper algs. 1–3, §IV-B-2).

Canonical Huffman codes with an explicitly accounted two-part header
(the paper's point: unlike backward-adaptive CABAC, Huffman must transmit
its probability model).  Used by benchmarks for Tables I & III.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np


@dataclass
class HuffmanCode:
    symbols: np.ndarray          # unique symbol values (sorted)
    lengths: np.ndarray          # code length per symbol
    codes: dict[int, tuple[int, int]]  # symbol -> (bits, length)

    @property
    def table_bits(self) -> int:
        """Two-part-code header: symbol values (32b each) + lengths (8b)."""
        return int(self.symbols.size * (32 + 8))


def canonical_codes(vals: np.ndarray,
                    lengths: np.ndarray) -> dict[int, tuple[int, int]]:
    """Canonical code assignment from (symbol, length) pairs — the part of
    the two-part code a decoder rebuilds from the transmitted header."""
    order = np.lexsort((vals, lengths))
    codes: dict[int, tuple[int, int]] = {}
    code, prev_len = 0, 0
    for idx in order:
        ln = int(lengths[idx])
        code <<= (ln - prev_len)
        codes[int(vals[idx])] = (code, ln)
        code += 1
        prev_len = ln
    return codes


def build_huffman(values: np.ndarray) -> HuffmanCode:
    vals, counts = np.unique(np.asarray(values).ravel(), return_counts=True)
    if vals.size == 0:
        lengths = np.zeros(0, dtype=np.int64)
    elif vals.size == 1:
        lengths = np.array([1])
    else:
        # heap of (count, tiebreak, node); node = symbol index or [l, r]
        heap: list = [(int(c), i, i) for i, c in enumerate(counts)]
        heapq.heapify(heap)
        tie = len(heap)
        while len(heap) > 1:
            c1, _, n1 = heapq.heappop(heap)
            c2, _, n2 = heapq.heappop(heap)
            heapq.heappush(heap, (c1 + c2, tie, [n1, n2]))
            tie += 1
        lengths = np.zeros(vals.size, dtype=np.int64)

        def walk(node, depth):
            if isinstance(node, list):
                walk(node[0], depth + 1)
                walk(node[1], depth + 1)
            else:
                lengths[node] = max(depth, 1)
        walk(heap[0][2], 0)

    return HuffmanCode(symbols=vals, lengths=lengths,
                       codes=canonical_codes(vals, lengths))


def huffman_payload_bits(values: np.ndarray, code: HuffmanCode) -> int:
    vals, counts = np.unique(np.asarray(values).ravel(), return_counts=True)
    total = 0
    for v, c in zip(vals.tolist(), counts.tolist()):
        total += code.codes[int(v)][1] * c
    return total


def huffman_encode(values: np.ndarray, code: HuffmanCode) -> bytes:
    out = bytearray()
    acc, nbits = 0, 0
    for v in np.asarray(values).ravel().tolist():
        bits, ln = code.codes[int(v)]
        acc = (acc << ln) | bits
        nbits += ln
        while nbits >= 8:
            nbits -= 8
            out.append((acc >> nbits) & 0xFF)
            acc &= (1 << nbits) - 1
    if nbits:
        out.append((acc << (8 - nbits)) & 0xFF)
    return bytes(out)


def huffman_decode(data: bytes, count: int, code: HuffmanCode) -> np.ndarray:
    # decode via a (code, length) -> symbol map; canonical codes are prefix-free
    rev = {(bits, ln): sym for sym, (bits, ln) in code.codes.items()}
    out = np.empty(count, dtype=np.int64)
    acc, ln, pos = 0, 0, 0
    it = iter(data)
    bitpos = 0
    byte = 0
    for i in range(count):
        while True:
            if bitpos == 0:
                byte = next(it, None)
                if byte is None:
                    raise ValueError(
                        f"huffman bitstream truncated: decoded {i} of "
                        f"{count} values")
                bitpos = 8
            bitpos -= 1
            acc = (acc << 1) | ((byte >> bitpos) & 1)
            ln += 1
            sym = rev.get((acc, ln))
            if sym is not None:
                out[i] = sym
                acc, ln = 0, 0
                break
    return out


PAYLOAD_HEADER = "<I"   # u32 nsym | i32 symbols | u8 lengths | bitstream


def pack_payload(values: np.ndarray, code: HuffmanCode) -> bytes:
    """Serialize the two-part code (table in-band) + canonical bitstream.
    The single source of truth for the ENC_HUFF container wire format."""
    import struct
    if code.symbols.size:
        if (code.symbols.max() > np.iinfo(np.int32).max
                or code.symbols.min() < np.iinfo(np.int32).min):
            raise ValueError("huffman symbols exceed the i32 range")
        if code.lengths.max() > 255:
            raise ValueError("huffman code depth exceeds u8")
    return (struct.pack(PAYLOAD_HEADER, code.symbols.size)
            + code.symbols.astype("<i4").tobytes()
            + code.lengths.astype("<u1").tobytes()
            + huffman_encode(values, code))


def unpack_payload(payload: bytes, count: int) -> np.ndarray:
    """Inverse of :func:`pack_payload`: rebuild the canonical code from the
    in-band table and decode ``count`` values."""
    import struct
    (nsym,) = struct.unpack_from(PAYLOAD_HEADER, payload, 0)
    off = struct.calcsize(PAYLOAD_HEADER)
    symbols = np.frombuffer(payload, dtype="<i4", count=nsym,
                            offset=off).astype(np.int64)
    off += 4 * nsym
    lengths = np.frombuffer(payload, dtype="<u1", count=nsym,
                            offset=off).astype(np.int64)
    off += nsym
    code = HuffmanCode(symbols=symbols, lengths=lengths,
                       codes=canonical_codes(symbols, lengths))
    return huffman_decode(payload[off:], count, code)


def scalar_huffman_size_bits(values: np.ndarray,
                             include_table: bool = True) -> int:
    code = build_huffman(values)
    bits = huffman_payload_bits(values, code)
    return bits + (code.table_bits if include_table else 0)


def epmd_entropy_bits(values: np.ndarray) -> float:
    """i.i.d. entropy of the empirical PMF, in bits *total* (n * H)."""
    _, counts = np.unique(np.asarray(values).ravel(), return_counts=True)
    p = counts / counts.sum()
    return float(-np.sum(p * np.log2(p)) * np.asarray(values).size)
