"""Vectorized CABAC code-length model for RD quantization (paper eq. 11).

The RD assignment needs L_ik — the number of bits CABAC would spend on coding
level k at position i.  Running the sequential coder inside the quantizer
would serialize the whole operation, so DeepCABAC-style systems estimate the
rate from *static per-context probabilities* gathered in a vectorized first
pass (a provisional nearest-neighbour quantization), optionally iterating
assignment → statistics → assignment.

Everything here is pure numpy and O(n); the resulting rate tables are what
``kernels/rd_quant`` consumes on TPU.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .binarization import DEFAULT_NUM_GR, EG_CTXS

_EPS_P = 1.0 / 4096.0


@dataclass
class BinProbs:
    """Static per-context P(bin == 1) estimates."""

    p_sig: np.ndarray    # shape (2,): P(sig==1 | prev_sig)
    p_sign: float        # P(negative | significant)
    p_gr: np.ndarray     # shape (num_gr,): P(AbsGr(j)==1 | emitted), j=1..n
    p_eg: np.ndarray     # shape (EG_CTXS,): P(unary bit==1 | emitted)
    num_gr: int


def _smooth(ones: np.ndarray | float, total: np.ndarray | float) -> np.ndarray:
    p = (np.asarray(ones, dtype=np.float64) + 0.5) / (
        np.asarray(total, dtype=np.float64) + 1.0)
    return np.clip(p, _EPS_P, 1.0 - _EPS_P)


def estimate_bin_probs(levels: np.ndarray,
                       num_gr: int = DEFAULT_NUM_GR) -> BinProbs:
    """Gather per-context statistics from a provisional level assignment."""
    v = np.asarray(levels).astype(np.int64).ravel()
    sig = v != 0
    prev_sig = np.concatenate([[False], sig[:-1]])

    sig_tot = np.array([np.sum(~prev_sig), np.sum(prev_sig)], dtype=np.float64)
    sig_one = np.array([np.sum(sig & ~prev_sig), np.sum(sig & prev_sig)],
                       dtype=np.float64)
    p_sig = _smooth(sig_one, sig_tot)

    a = np.abs(v[sig])
    p_sign = float(_smooth(np.sum(v < 0), a.size))

    js = np.arange(1, num_gr + 1)[:, None]
    emitted = a[None, :] >= js               # flag j emitted iff a >= j
    ones = a[None, :] > js
    p_gr = _smooth(ones.sum(axis=1), emitted.sum(axis=1))

    rem = a[a > num_gr] - num_gr             # i >= 1
    if rem.size:
        k = np.floor(np.log2(rem)).astype(np.int64)
        pos = np.arange(EG_CTXS)[:, None]
        kk = np.minimum(k, EG_CTXS - 1)      # cap positions at the last ctx
        emitted_eg = kk[None, :] >= pos
        ones_eg = kk[None, :] > pos
        p_eg = _smooth(ones_eg.sum(axis=1), emitted_eg.sum(axis=1))
    else:
        p_eg = np.full(EG_CTXS, 0.5)
    return BinProbs(p_sig=p_sig, p_sign=p_sign, p_gr=np.asarray(p_gr),
                    p_eg=np.asarray(p_eg), num_gr=num_gr)


def level_rates(vs: np.ndarray, probs: BinProbs, prev_sig: int) -> np.ndarray:
    """Bits to code each (signed integer) level in ``vs`` — fully vectorized.

    Closed-form decomposition of the binarization using cumulative context
    cost tables; O(1) per element.
    """
    v = np.asarray(vs, dtype=np.int64)
    num_gr = probs.num_gr
    l1_sig = -np.log2(probs.p_sig[prev_sig])
    l0_sig = -np.log2(1.0 - probs.p_sig[prev_sig])
    l_neg = -np.log2(probs.p_sign)
    l_pos = -np.log2(1.0 - probs.p_sign)

    cum_gr1 = np.concatenate([[0.0], np.cumsum(-np.log2(probs.p_gr))])
    l0_gr = -np.log2(1.0 - probs.p_gr)
    cum_eg1 = np.concatenate([[0.0], np.cumsum(-np.log2(probs.p_eg))])
    l0_eg = -np.log2(1.0 - probs.p_eg)

    out = np.empty(v.shape, dtype=np.float64)
    zero = v == 0
    out[zero] = l0_sig

    nz = ~zero
    a = np.abs(v[nz])
    r = np.full(a.shape, l1_sig)
    r += np.where(v[nz] < 0, l_neg, l_pos)

    small = a <= num_gr
    a_s = a[small]
    r_small = cum_gr1[a_s - 1] + l0_gr[a_s - 1]
    big = ~small
    a_b = a[big]
    i = a_b - num_gr
    k = np.floor(np.log2(i)).astype(np.int64)
    kk = np.minimum(k, EG_CTXS - 1)
    r_big = cum_gr1[num_gr] + cum_eg1[kk] + (k - kk) * (-np.log2(
        probs.p_eg[-1])) + l0_eg[kk] + k  # + k bypass bits
    tmp = np.empty(a.shape, dtype=np.float64)
    tmp[small] = r_small
    tmp[big] = r_big
    out[nz] = r + tmp
    return out


@dataclass
class RateTable:
    """Rate lookup L[prev_sig, level + max_level] in bits."""

    bits: np.ndarray      # (2, 2*max_level+1) float32
    max_level: int

    def lookup(self, levels: np.ndarray, prev_sig: np.ndarray) -> np.ndarray:
        idx = np.clip(levels, -self.max_level, self.max_level) + self.max_level
        return self.bits[prev_sig.astype(np.int64), idx.astype(np.int64)]


def build_rate_table(probs: BinProbs, max_level: int) -> RateTable:
    vs = np.arange(-max_level, max_level + 1)
    bits = np.stack([level_rates(vs, probs, 0), level_rates(vs, probs, 1)])
    return RateTable(bits=bits.astype(np.float32), max_level=max_level)


def rate_table_from_levels(levels: np.ndarray, max_level: int,
                           num_gr: int = DEFAULT_NUM_GR) -> RateTable:
    return build_rate_table(estimate_bin_probs(levels, num_gr), max_level)


def estimate_level_bits(levels: np.ndarray,
                        num_gr: int = DEFAULT_NUM_GR) -> float:
    """Total bits the static-context model assigns to its own assignment.

    Self-entropy of ``levels`` under per-context probabilities estimated
    from those same levels, with the true per-element prev_sig context —
    the scan-free rate proxy the RD search uses to score per-tensor
    operating points without running the sequential coder.  Tracks the
    actual CABAC stream to within the adaptation overhead (small for the
    >= thousands-of-values tensors the search touches).
    """
    v = np.asarray(levels).astype(np.int64).ravel()
    if v.size == 0:
        return 0.0
    probs = estimate_bin_probs(v, num_gr)
    sig = v != 0
    prev = np.concatenate([[False], sig[:-1]])
    r0 = level_rates(v, probs, 0)
    r1 = level_rates(v, probs, 1)
    return float(np.where(prev, r1, r0).sum())
