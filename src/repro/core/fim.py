"""FIM-diagonal estimation for DC-v1 (paper §III-C-3, appendix B).

Two routes:
* :func:`empirical_fisher_diag` — mean squared gradients (cheap, the
  Hessian-diagonal proxy of [45]).
* :func:`variational_fim` — the paper's route [26]: fully-factorized Gaussian
  posterior (mu, sigma) trained with the variational-dropout KL approximation
  (eq. 13/14); returns sigma with F_i = 1 / sigma_i^2, and mu as the new
  weight value.  Also provides the paper's pruning rule alpha^-1 < e^-3.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

K1, K2, K3 = 0.63576, 1.87320, 1.48695


def empirical_fisher_diag(loss_fn: Callable, params, batches: Iterable,
                          max_batches: int = 16):
    """Mean of squared gradients over batches — diag-Fisher proxy."""
    grad_fn = jax.jit(jax.grad(loss_fn))
    acc = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    n = 0
    for batch in batches:
        g = grad_fn(params, batch)
        acc = jax.tree.map(lambda a, gi: a + jnp.square(gi), acc, g)
        n += 1
        if n >= max_batches:
            break
    return jax.tree.map(lambda a: a / max(n, 1), acc)


def vd_neg_kl(log_alpha: jnp.ndarray) -> jnp.ndarray:
    """Molchanov et al. approximation of -D_KL per parameter (paper eq. 14)."""
    return (K1 * jax.nn.sigmoid(K2 + K3 * log_alpha)
            - 0.5 * jnp.log1p(jnp.exp(-log_alpha)) - K1)


@dataclass
class VariationalResult:
    mu: dict
    sigma: dict
    log_alpha: dict


def variational_fim(loss_fn: Callable, params, batches: Iterable,
                    steps: int = 200, beta: float = 1e-4, lr: float = 1e-3,
                    seed: int = 0) -> VariationalResult:
    """Minimize E_q[L] + beta * KL(q || log-uniform prior) over (mu, rho).

    ``loss_fn(params, batch)`` must be the task loss.  sigma is parametrized
    as exp(rho) and initialized to ~10% of |w|.
    """
    mu0 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    rho0 = jax.tree.map(
        lambda p: jnp.log(0.1 * jnp.abs(p.astype(jnp.float32)) + 1e-8), params)
    var_params = {"mu": mu0, "rho": rho0}

    def objective(vp, batch, key):
        leaves, treedef = jax.tree.flatten(vp["mu"])
        keys = jax.random.split(key, len(leaves))
        keys = jax.tree.unflatten(treedef, list(keys))
        sampled = jax.tree.map(
            lambda m, r, k: m + jnp.exp(r) * jax.random.normal(k, m.shape),
            vp["mu"], vp["rho"], keys)
        task = loss_fn(sampled, batch)
        log_alpha = jax.tree.map(
            lambda r, m: 2.0 * r - jnp.log(jnp.square(m) + 1e-12),
            vp["rho"], vp["mu"])
        kl = sum(jnp.sum(-vd_neg_kl(la)) for la in jax.tree.leaves(log_alpha))
        return task + beta * kl

    cfg = AdamWConfig(lr=lr, weight_decay=0.0, grad_clip=10.0)
    state = adamw_init(var_params, cfg)
    step_fn = jax.jit(
        lambda vp, st, batch, key: adamw_update(
            jax.grad(objective)(vp, batch, key), st, vp, cfg))

    key = jax.random.PRNGKey(seed)
    batch_list = list(batches)
    for i in range(steps):
        key, sub = jax.random.split(key)
        var_params, state = step_fn(var_params, state,
                                    batch_list[i % len(batch_list)], sub)

    sigma = jax.tree.map(jnp.exp, var_params["rho"])
    log_alpha = jax.tree.map(
        lambda s, m: jnp.log(jnp.square(s) / (jnp.square(m) + 1e-12) + 1e-12),
        sigma, var_params["mu"])
    return VariationalResult(mu=var_params["mu"], sigma=sigma,
                             log_alpha=log_alpha)


def vd_sparsify(result: VariationalResult, threshold: float = np.exp(-3)
                ) -> dict:
    """Paper appendix A pruning rule: zero params with alpha^-1 < e^-3."""
    def prune(m, la):
        snr = jnp.exp(-la)          # alpha^-1 = mu^2 / sigma^2
        return jnp.where(snr < threshold, 0.0, m)
    return jax.tree.map(prune, result.mu, result.log_alpha)
