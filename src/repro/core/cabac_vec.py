"""Lane-parallel vectorized CABAC: N independent chunk streams in lockstep.

The interval subdivision of a range coder is inherently sequential *within*
a stream (DESIGN note in cabac.py), but the chunk split the container emits
makes streams independent — so one numpy program can advance many streams
("lanes") one bin per step: vectorized context banks ``probs[lane, ctx]``,
vectorized bypass bins, per-lane carry/renorm with masked updates.  Every
lane is bit-exact with the scalar :class:`~repro.core.cabac.RangeEncoder` /
:class:`~repro.core.cabac.RangeDecoder` — the two engines are
interchangeable per stream, which is what lets a v3 reader schedule all
chunks of a tensor (or a whole state dict) into one decode batch.

Two backends hide behind one API:

* ``numpy`` — the portable lockstep engine in this file.  One step decodes
  (or encodes) one bin in every live lane; lanes that finish early park in
  a DONE state that only touches scratch storage, so ragged chunk counts
  need no compaction.
* ``c`` — ``_cabac_lanes.c`` (the same scalar coder transliterated to C,
  run per lane) compiled on demand with the host ``cc`` into a cached
  shared object and called through ctypes.  Entirely optional: any
  failure (no compiler, read-only cache, bad toolchain) falls back to
  numpy with a one-time warning.  This is what makes container cold-start
  decode fast enough to serve from (see benchmarks/cold_start_bench.py).

``backend="auto"`` picks C when available, else numpy.  Differential tests
(tests/test_cabac_vec.py) pin all backends to the scalar coder bin-for-bin.
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import shutil
import subprocess
import tempfile
import warnings

import numpy as np

from . import binarization as B
from .cabac import (ADAPT_SHIFT, MASK32, PROB_BITS, PROB_HALF, PROB_MAX,
                    PROB_MIN, PROB_ONE, TOP)

__all__ = [
    "available_backends", "resolve_backend",
    "encode_lanes", "decode_lanes",
    "encode_lanes_tc", "decode_lanes_tc",
    "VecRangeEncoder", "VecRangeDecoder",
]

_I64 = np.int64

# Levels beyond this magnitude would overflow the int64 Exp-Golomb
# accumulators; the scalar coder (arbitrary-precision Python ints) remains
# the path of record for such streams.  Far beyond any quantizer output.
MAX_ABS_LEVEL = (1 << 61) - 1


# ---------------------------------------------------------------------------
# Lockstep bin coder (the numpy backend's core)
# ---------------------------------------------------------------------------

class VecRangeDecoder:
    """Lockstep mirror of ``RangeDecoder`` over ``n_lanes`` streams.

    Each lane has its own payload, 32-bit range/code registers and context
    bank row; :meth:`decode_bins` advances every selected lane by exactly
    one bin.  Context index ``num_contexts`` is a scratch slot: bypass bins
    (and parked lanes) read/write it so the bank update needs no masking.
    """

    def __init__(self, payloads: list[bytes], num_contexts: int,
                 pad: int = 64):
        n = len(payloads)
        self.n_lanes = n
        self.num_contexts = num_contexts
        self._row = num_contexts + 1          # bank row incl. scratch slot
        self.probs = np.full(n * self._row, PROB_HALF, dtype=_I64)
        self._lane_off = np.arange(n, dtype=_I64) * self._row
        self.lens = np.asarray([len(p) for p in payloads], dtype=_I64)
        width = int(self.lens.max(initial=0)) + pad
        data = np.zeros((n, width), dtype=np.uint8)
        for i, p in enumerate(payloads):
            data[i, :len(p)] = np.frombuffer(p, dtype=np.uint8)
        self._data = data.reshape(-1).astype(_I64)
        self._width = width
        self._dbase = np.arange(n, dtype=_I64) * width
        self.rng = np.full(n, MASK32, dtype=_I64)
        self.code = np.zeros(n, dtype=_I64)
        self.pos = np.zeros(n, dtype=_I64)
        for _ in range(4):
            self.code = ((self.code << 8)
                         | self._data[self._dbase + self.pos]) & MASK32
            self.pos += 1

    def decode_bins(self, ctx: np.ndarray, is_byp: np.ndarray) -> np.ndarray:
        """One bin per lane; ``ctx`` is ignored where ``is_byp``.  Returns
        the decoded bits as an int64 0/1 vector."""
        cidx = self._lane_off + np.where(is_byp, self.num_contexts, ctx)
        p1 = self.probs[cidx]
        bound = np.where(is_byp, self.rng >> 1, (self.rng >> PROB_BITS) * p1)
        ge = self.code >= bound
        bit = np.where(is_byp, ge, ~ge)
        self.code = self.code - np.where(ge, bound, 0)
        self.rng = np.where(bit | is_byp, bound, self.rng - bound)
        up = np.minimum(p1 + ((PROB_ONE - p1) >> ADAPT_SHIFT), PROB_MAX)
        dn = np.maximum(p1 - (p1 >> ADAPT_SHIFT), PROB_MIN)
        newp = np.where(is_byp, p1, np.where(bit, up, dn))
        self.probs[cidx] = newp
        need = self.rng < TOP
        self.rng = np.where(need, (self.rng << 8) & MASK32, self.rng)
        byte = self._data[self._dbase + np.minimum(self.pos, self._width - 1)]
        self.code = np.where(need, ((self.code << 8) | byte) & MASK32,
                             self.code)
        self.pos = self.pos + need
        return bit.astype(_I64)

    def bank_snapshot(self) -> np.ndarray:
        """(n_lanes, num_contexts) context probabilities — for the
        adaptation-trajectory differential tests."""
        return self.probs.reshape(self.n_lanes,
                                  self._row)[:, :self.num_contexts].copy()


class VecRangeEncoder:
    """Lockstep mirror of ``RangeEncoder``: per-lane 40-bit low with carry
    propagation and cache/filler runs, vectorized with masked updates."""

    def __init__(self, n_lanes: int, num_contexts: int, out_capacity: int):
        self.n_lanes = n_lanes
        self.num_contexts = num_contexts
        self._row = num_contexts + 1
        self.probs = np.full(n_lanes * self._row, PROB_HALF, dtype=_I64)
        self._lane_off = np.arange(n_lanes, dtype=_I64) * self._row
        self.low = np.zeros(n_lanes, dtype=_I64)
        self.rng = np.full(n_lanes, MASK32, dtype=_I64)
        self.cache = np.zeros(n_lanes, dtype=_I64)
        self.cache_size = np.ones(n_lanes, dtype=_I64)
        self.out = np.zeros((n_lanes, out_capacity), dtype=np.uint8)
        self.opos = np.zeros(n_lanes, dtype=_I64)
        self._iota = np.arange(n_lanes)

    def _shift_low(self, mask: np.ndarray) -> None:
        low = self.low
        cond = mask & ((low < 0xFF000000) | (low > MASK32))
        if cond.any():
            carry = low >> 32
            byte = (self.cache + carry) & 0xFF
            rows = self._iota[cond]
            self.out[rows, self.opos[cond]] = byte[cond]
            self.opos = self.opos + cond
            filler = (0xFF + carry) & 0xFF
            fcount = np.where(cond, self.cache_size - 1, 0)
            while True:
                m = fcount > 0
                if not m.any():
                    break
                rows = self._iota[m]
                self.out[rows, self.opos[m]] = filler[m]
                self.opos = self.opos + m
                fcount = fcount - m
            self.cache = np.where(cond, (low >> 24) & 0xFF, self.cache)
            self.cache_size = np.where(cond, 0, self.cache_size)
        self.cache_size = self.cache_size + mask
        self.low = np.where(mask, (low << 8) & MASK32, low)

    def encode_bins(self, ctx: np.ndarray, bits: np.ndarray,
                    is_byp: np.ndarray, active: np.ndarray) -> None:
        """One bin per active lane; inactive lanes are untouched."""
        byp = is_byp & active
        cidx = self._lane_off + np.where(active & ~byp, ctx,
                                         self.num_contexts)
        p1 = self.probs[cidx]
        bound = (self.rng >> PROB_BITS) * p1
        half = self.rng >> 1
        bit1 = bits.astype(bool)
        rng_new = np.where(byp, half, np.where(bit1, bound, self.rng - bound))
        add = np.where(byp, np.where(bit1, half, 0),
                       np.where(bit1, 0, bound))
        self.low = self.low + np.where(active, add, 0)
        self.rng = np.where(active, rng_new, self.rng)
        up = np.minimum(p1 + ((PROB_ONE - p1) >> ADAPT_SHIFT), PROB_MAX)
        dn = np.maximum(p1 - (p1 >> ADAPT_SHIFT), PROB_MIN)
        ctx_upd = active & ~byp
        newp = np.where(ctx_upd, np.where(bit1, up, dn), p1)
        self.probs[cidx] = newp
        need = active & (self.rng < TOP)
        self.rng = np.where(need, (self.rng << 8) & MASK32, self.rng)
        self._shift_low(need)

    def finish(self) -> list[bytes]:
        all_lanes = np.ones(self.n_lanes, dtype=bool)
        for _ in range(5):
            self._shift_low(all_lanes)
        # Drop the leading dummy zero byte, like RangeEncoder.finish().
        return [self.out[i, 1:self.opos[i]].tobytes()
                for i in range(self.n_lanes)]


# ---------------------------------------------------------------------------
# Level-stream state machine on top of the lockstep bin coder
# ---------------------------------------------------------------------------

# Binarization automaton phases (one value = sig | sign | AbsGr flags |
# Exp-Golomb exponent | bypass remainder, per binarization.py).
_P_SIG, _P_SIGN, _P_GR, _P_EGE, _P_BYP, _P_DONE = range(6)


def _decode_lanes_numpy(payloads: list[bytes], counts: np.ndarray,
                        num_gr: int,
                        cls_arrays: list[np.ndarray] | None = None
                        ) -> list[np.ndarray]:
    n = len(payloads)
    counts = np.asarray(counts, dtype=_I64)
    base_nctx = B.num_contexts(num_gr)
    nctx = B.num_contexts_tc(num_gr) if cls_arrays is not None else base_nctx
    eg_base = B.ctx_eg_base(num_gr)
    eg_last = eg_base + B.EG_CTXS - 1
    dec = VecRangeDecoder(payloads, nctx)

    phase = np.where(counts > 0, _P_SIG, _P_DONE).astype(_I64)
    jj = np.zeros(n, dtype=_I64)          # GR j / EGE k / BYP bits-left
    kk = np.zeros(n, dtype=_I64)          # saved Exp-Golomb exponent
    neg = np.zeros(n, dtype=bool)
    acc = np.zeros(n, dtype=_I64)
    prev_sig = np.zeros(n, dtype=_I64)
    out_idx = np.zeros(n, dtype=_I64)
    maxc = int(counts.max(initial=0))
    out = np.zeros((n, maxc + 1), dtype=_I64)   # +1 slack: parked lanes
    iota = np.arange(n)                         # keep writing to out[:, c]
    sign = np.ones(n, dtype=_I64)

    # Temporal-context mode: per-lane class of the value currently being
    # decoded, gathered by out_idx (classes are known up front — they come
    # from the shared base frame, not from the stream).
    cls_pad = None
    if cls_arrays is not None:
        cls_pad = np.zeros((n, maxc + 1), dtype=_I64)
        for i, c in enumerate(cls_arrays):
            c = np.asarray(c, dtype=_I64).ravel()
            cls_pad[i, :c.size] = c

    one = np.ones(n, dtype=_I64)
    while not bool((phase == _P_DONE).all()):
        # ctx of the bin each lane decodes this step (selected by phase);
        # bypass-remainder and parked lanes take the uncontexted path.
        ctx = np.where(phase == _P_SIG, prev_sig,
              np.where(phase == _P_SIGN, B.CTX_SIGN,
              np.where(phase == _P_GR, B.CTX_GR_BASE + jj - 1,
                       np.minimum(eg_base + jj, eg_last))))
        if cls_pad is not None:
            ctx = ctx + cls_pad[iota, out_idx] * base_nctx
        is_byp = phase >= _P_BYP
        bit = dec.decode_bins(ctx, is_byp)
        b1 = bit.astype(bool)

        emit = np.zeros(n, dtype=bool)
        val = np.zeros(n, dtype=_I64)

        # Transitions apply to the phase each lane was in at step start;
        # the was_* masks keep just-arrived lanes out of the next block.
        was_sig = phase == _P_SIG
        emit |= was_sig & ~b1                            # v == 0
        prev_sig = np.where(was_sig, bit, prev_sig)
        phase = np.where(was_sig & b1, _P_SIGN, phase)

        was_sign = (phase == _P_SIGN) & ~was_sig
        neg = np.where(was_sign, b1, neg)
        sign = np.where(neg, -one, one)
        jj = np.where(was_sign, 1, jj)
        phase = np.where(was_sign, _P_GR, phase)

        was_gr = (phase == _P_GR) & ~was_sign
        term = was_gr & ~b1
        emit |= term
        val = np.where(term, sign * jj, val)
        phase = np.where(term, _P_SIG, phase)
        grow = was_gr & b1
        jj = np.where(grow, jj + 1, jj)
        to_eg = grow & (jj > num_gr)
        phase = np.where(to_eg, _P_EGE, phase)
        jj = np.where(to_eg, 0, jj)

        was_ege = (phase == _P_EGE) & ~to_eg
        jj = np.where(was_ege & b1, jj + 1, jj)
        if bool((was_ege & (jj > 60)).any()):
            # Exp-Golomb exponent beyond the |level| <= 2^61 - 1 lane
            # range (legal for the arbitrary-precision scalar coder) —
            # refuse rather than wrap int64; callers fall back to scalar.
            raise OverflowError(
                "cabac_vec decode hit a level beyond 2**61 - 1; the "
                "stream needs the scalar decoder")
        done_k = was_ege & ~b1
        k0 = done_k & (jj == 0)
        emit |= k0
        val = np.where(k0, sign * (num_gr + 1), val)
        phase = np.where(k0, _P_SIG, phase)
        to_byp = done_k & (jj > 0)
        kk = np.where(to_byp, jj, kk)
        acc = np.where(to_byp, 0, acc)
        phase = np.where(to_byp, _P_BYP, phase)

        was_byp = (phase == _P_BYP) & ~to_byp
        acc = np.where(was_byp, (acc << 1) | bit, acc)
        jj = np.where(was_byp, jj - 1, jj)
        fin = was_byp & (jj == 0)
        emit |= fin
        val = np.where(fin, sign * (num_gr + (one << kk) + acc), val)
        phase = np.where(fin, _P_SIG, phase)

        out[iota, out_idx] = np.where(emit, val, out[iota, out_idx])
        out_idx = out_idx + emit
        phase = np.where(out_idx >= counts, _P_DONE, phase)
    return [out[i, :counts[i]] for i in range(n)]


def _encode_lanes_numpy(level_arrays: list[np.ndarray], num_gr: int,
                        cls_arrays: list[np.ndarray] | None = None
                        ) -> list[bytes]:
    n = len(level_arrays)
    if cls_arrays is not None:
        nctx = B.num_contexts_tc(num_gr)
        expanded = [B.expand_bins_tc(np.asarray(lv).ravel(), cls, num_gr)
                    for lv, cls in zip(level_arrays, cls_arrays)]
    else:
        nctx = B.num_contexts(num_gr)
        expanded = [B.expand_bins(np.asarray(lv).ravel(), num_gr)
                    for lv in level_arrays]
    nbins = np.asarray([len(b) for b, _ in expanded], dtype=_I64)
    tmax = int(nbins.max(initial=0))
    bits = np.zeros((n, tmax), dtype=_I64)
    ctxs = np.zeros((n, tmax), dtype=_I64)
    for i, (b, c) in enumerate(expanded):
        bits[i, :len(b)] = b
        ctxs[i, :len(c)] = c
    enc = VecRangeEncoder(n, nctx, tmax + 16)
    for t in range(tmax):
        active = t < nbins
        ctx = ctxs[:, t]
        enc.encode_bins(np.maximum(ctx, 0), bits[:, t], ctx < 0, active)
    return enc.finish()


# ---------------------------------------------------------------------------
# Compiled per-lane kernel (optional fast backend)
# ---------------------------------------------------------------------------

_KERNEL = None        # ctypes lib, False after a failed attempt
_KERNEL_SRC = os.path.join(os.path.dirname(__file__), "_cabac_lanes.c")


def _kernel_cache_dir() -> str:
    base = os.environ.get("REPRO_CABAC_KERNEL_CACHE")
    if base:
        return base
    xdg = os.environ.get("XDG_CACHE_HOME",
                         os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(xdg, "repro")


def _build_kernel():
    with open(_KERNEL_SRC, "rb") as f:
        src = f.read()
    tag = hashlib.sha1(src).hexdigest()[:12]
    cache = _kernel_cache_dir()
    so_path = os.path.join(cache, f"cabac_lanes_{tag}.so")
    if not os.path.exists(so_path):
        cc = (os.environ.get("CC") or shutil.which("cc")
              or shutil.which("gcc") or shutil.which("clang"))
        if cc is None:
            raise RuntimeError("no C compiler on PATH")
        os.makedirs(cache, exist_ok=True)
        fd, tmp = tempfile.mkstemp(suffix=".so", dir=cache)
        os.close(fd)
        try:
            subprocess.run(
                [cc, "-O3", "-shared", "-fPIC", "-o", tmp, _KERNEL_SRC],
                check=True, capture_output=True, timeout=120)
            os.replace(tmp, so_path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    lib = ctypes.CDLL(so_path)
    p = ctypes.POINTER
    lib.cabac_decode_lanes.argtypes = [
        p(ctypes.c_uint8), p(ctypes.c_int64), p(ctypes.c_int64),
        p(ctypes.c_int64), ctypes.c_int32, ctypes.c_int32]
    lib.cabac_decode_lanes.restype = ctypes.c_int32
    lib.cabac_encode_lanes.argtypes = [
        p(ctypes.c_int64), p(ctypes.c_int64), p(ctypes.c_uint8),
        ctypes.c_int64, p(ctypes.c_int64), ctypes.c_int32, ctypes.c_int32]
    lib.cabac_encode_lanes.restype = None
    lib.cabac_decode_lanes_tc.argtypes = [
        p(ctypes.c_uint8), p(ctypes.c_int64), p(ctypes.c_int64),
        p(ctypes.c_int64), p(ctypes.c_int64), ctypes.c_int32,
        ctypes.c_int32]
    lib.cabac_decode_lanes_tc.restype = ctypes.c_int32
    lib.cabac_encode_lanes_tc.argtypes = [
        p(ctypes.c_int64), p(ctypes.c_int64), p(ctypes.c_int64),
        p(ctypes.c_uint8), ctypes.c_int64, p(ctypes.c_int64),
        ctypes.c_int32, ctypes.c_int32]
    lib.cabac_encode_lanes_tc.restype = None
    return lib


def _get_kernel():
    global _KERNEL
    if _KERNEL is None:
        try:
            _KERNEL = _build_kernel()
        except Exception as e:  # no cc, sandboxed cache, bad toolchain, ...
            _KERNEL = False
            warnings.warn(
                f"cabac_vec: C lane kernel unavailable ({e}); "
                f"falling back to the numpy lockstep engine", stacklevel=2)
    return _KERNEL or None


def _ptr(arr, ctype):
    return arr.ctypes.data_as(ctypes.POINTER(ctype))


def _decode_lanes_c(payloads: list[bytes], counts: np.ndarray,
                    num_gr: int, lib,
                    cls_arrays: list[np.ndarray] | None = None
                    ) -> list[np.ndarray]:
    n = len(payloads)
    counts = np.asarray(counts, dtype=_I64)
    data = np.frombuffer(b"".join(payloads), dtype=np.uint8)
    if data.size == 0:
        data = np.zeros(1, dtype=np.uint8)
    doff = np.zeros(n + 1, dtype=_I64)
    np.cumsum([len(p) for p in payloads], out=doff[1:])
    ooff = np.zeros(n + 1, dtype=_I64)
    np.cumsum(counts, out=ooff[1:])
    out = np.empty(max(int(ooff[-1]), 1), dtype=_I64)
    if cls_arrays is not None:
        cls = (np.concatenate([np.asarray(c, dtype=_I64).ravel()
                               for c in cls_arrays])
               if int(ooff[-1]) else np.zeros(1, dtype=_I64))
        cls = np.ascontiguousarray(cls, dtype=_I64)
        ret = lib.cabac_decode_lanes_tc(_ptr(data, ctypes.c_uint8),
                                        _ptr(doff, ctypes.c_int64),
                                        _ptr(cls, ctypes.c_int64),
                                        _ptr(out, ctypes.c_int64),
                                        _ptr(ooff, ctypes.c_int64),
                                        np.int32(n), np.int32(num_gr))
    else:
        ret = lib.cabac_decode_lanes(_ptr(data, ctypes.c_uint8),
                                     _ptr(doff, ctypes.c_int64),
                                     _ptr(out, ctypes.c_int64),
                                     _ptr(ooff, ctypes.c_int64),
                                     np.int32(n), np.int32(num_gr))
    if ret:
        raise OverflowError(
            "cabac_vec decode hit a level beyond 2**61 - 1; the stream "
            "needs the scalar decoder")
    return [out[ooff[i]:ooff[i + 1]] for i in range(n)]


def _encode_lanes_c(level_arrays: list[np.ndarray], num_gr: int, lib,
                    cls_arrays: list[np.ndarray] | None = None
                    ) -> list[bytes]:
    n = len(level_arrays)
    flats = [np.ascontiguousarray(np.asarray(lv).ravel(), dtype=_I64)
             for lv in level_arrays]
    loff = np.zeros(n + 1, dtype=_I64)
    np.cumsum([f.size for f in flats], out=loff[1:])
    levels = (np.concatenate(flats) if int(loff[-1])
              else np.zeros(1, dtype=_I64))
    maxc = max((f.size for f in flats), default=0)
    # Worst case ~ (2 + num_gr + 2*63 + 1) bits/value plus flush bytes.
    stride = (maxc * (num_gr + 130)) // 8 + 32
    out = np.empty((n, stride), dtype=np.uint8)
    out_lens = np.zeros(n, dtype=_I64)
    if cls_arrays is not None:
        cls = (np.concatenate([np.asarray(c, dtype=_I64).ravel()
                               for c in cls_arrays])
               if int(loff[-1]) else np.zeros(1, dtype=_I64))
        cls = np.ascontiguousarray(cls, dtype=_I64)
        lib.cabac_encode_lanes_tc(_ptr(levels, ctypes.c_int64),
                                  _ptr(cls, ctypes.c_int64),
                                  _ptr(loff, ctypes.c_int64),
                                  _ptr(out, ctypes.c_uint8),
                                  np.int64(stride),
                                  _ptr(out_lens, ctypes.c_int64),
                                  np.int32(n), np.int32(num_gr))
    else:
        lib.cabac_encode_lanes(_ptr(levels, ctypes.c_int64),
                               _ptr(loff, ctypes.c_int64),
                               _ptr(out, ctypes.c_uint8),
                               np.int64(stride),
                               _ptr(out_lens, ctypes.c_int64),
                               np.int32(n), np.int32(num_gr))
    # Drop the leading dummy zero byte, like RangeEncoder.finish().
    return [out[i, 1:out_lens[i]].tobytes() for i in range(n)]


# ---------------------------------------------------------------------------
# Public batched API
# ---------------------------------------------------------------------------

def available_backends() -> list[str]:
    out = ["numpy"]
    if _get_kernel() is not None:
        out.insert(0, "c")
    return out


def resolve_backend(backend: str = "auto") -> str:
    if backend == "auto":
        return "c" if _get_kernel() is not None else "numpy"
    if backend == "c" and _get_kernel() is None:
        raise RuntimeError("cabac_vec C kernel requested but unavailable")
    if backend not in ("c", "numpy"):
        raise ValueError(f"unknown cabac_vec backend {backend!r}")
    return backend


def decode_lanes(payloads: list[bytes], counts,
                 num_gr: int = B.DEFAULT_NUM_GR,
                 backend: str = "auto") -> list[np.ndarray]:
    """Decode N independent chunk streams; lane ``i`` yields ``counts[i]``
    int64 levels, bit-exact with ``RangeDecoder`` + ``decode_levels``.

    Raises ``OverflowError`` (never silently wraps) when a stream carries
    a level beyond ``MAX_ABS_LEVEL`` — possible only for streams the
    arbitrary-precision scalar coder wrote; callers fall back to it."""
    if not payloads:
        return []
    if resolve_backend(backend) == "c":
        return _decode_lanes_c(payloads, counts, num_gr, _get_kernel())
    return _decode_lanes_numpy(payloads, counts, num_gr)


def encode_lanes(level_arrays: list[np.ndarray],
                 num_gr: int = B.DEFAULT_NUM_GR,
                 backend: str = "auto") -> list[bytes]:
    """Encode N level arrays as independent streams; byte-exact with
    ``RangeEncoder`` + ``encode_levels`` per lane."""
    if not level_arrays:
        return []
    for lv in level_arrays:
        a = np.asarray(lv)
        if a.size and int(np.abs(a).max()) > MAX_ABS_LEVEL:
            raise OverflowError(
                "cabac_vec lanes code |level| <= 2**61 - 1; use the scalar "
                "coder for wider values")
    if resolve_backend(backend) == "c":
        return _encode_lanes_c(level_arrays, num_gr, _get_kernel())
    return _encode_lanes_numpy(level_arrays, num_gr)


# ---------------------------------------------------------------------------
# Temporal-context ("P-frame") lanes
# ---------------------------------------------------------------------------

def _check_classes(cls_arrays, sizes) -> None:
    from .cabac import TEMPORAL_CLASSES
    if len(cls_arrays) != len(sizes):
        raise ValueError("one class array per lane is required")
    for cls, size in zip(cls_arrays, sizes):
        c = np.asarray(cls)
        if c.size != size:
            raise ValueError(
                f"class array of {c.size} values for a lane of {size}")
        if c.size and (int(c.min()) < 0
                       or int(c.max()) >= TEMPORAL_CLASSES):
            raise ValueError("temporal class ids must be in "
                             f"[0, {TEMPORAL_CLASSES})")


def decode_lanes_tc(payloads: list[bytes], cls_arrays: list[np.ndarray],
                    num_gr: int = B.DEFAULT_NUM_GR,
                    backend: str = "auto") -> list[np.ndarray]:
    """Temporal-context decode: lane ``i`` yields ``len(cls_arrays[i])``
    levels, each coded in the context bank named by its class id (derived
    from the co-located base-frame level via ``cabac.temporal_classes``).
    Bit-exact with ``RangeDecoder`` + ``decode_levels_tc`` per lane; the
    ``OverflowError`` contract matches :func:`decode_lanes`."""
    if not payloads:
        return []
    counts = np.asarray([np.asarray(c).size for c in cls_arrays],
                        dtype=_I64)
    _check_classes(cls_arrays, counts.tolist())
    if resolve_backend(backend) == "c":
        return _decode_lanes_c(payloads, counts, num_gr, _get_kernel(),
                               cls_arrays=cls_arrays)
    return _decode_lanes_numpy(payloads, counts, num_gr,
                               cls_arrays=cls_arrays)


def encode_lanes_tc(level_arrays: list[np.ndarray],
                    cls_arrays: list[np.ndarray],
                    num_gr: int = B.DEFAULT_NUM_GR,
                    backend: str = "auto") -> list[bytes]:
    """Temporal-context encode; byte-exact with ``RangeEncoder`` +
    ``encode_levels_tc`` per lane."""
    if not level_arrays:
        return []
    sizes = []
    for lv in level_arrays:
        a = np.asarray(lv)
        sizes.append(a.size)
        if a.size and int(np.abs(a).max()) > MAX_ABS_LEVEL:
            raise OverflowError(
                "cabac_vec lanes code |level| <= 2**61 - 1; use the scalar "
                "coder for wider values")
    _check_classes(cls_arrays, sizes)
    if resolve_backend(backend) == "c":
        return _encode_lanes_c(level_arrays, num_gr, _get_kernel(),
                               cls_arrays=cls_arrays)
    return _encode_lanes_numpy(level_arrays, num_gr,
                               cls_arrays=cls_arrays)
