/* Per-lane CABAC kernel: the scalar range coder from cabac.py/binarization.py
 * transliterated to C, applied lane-by-lane over a batch of independent chunk
 * streams.  Compiled on demand by repro.core.cabac_vec (cc -O3 -shared) and
 * called through ctypes; the numpy lockstep engine in cabac_vec.py is the
 * portable reference with identical semantics.
 *
 * Bit-exactness contract: every arithmetic step below mirrors the Python
 * scalar coder exactly (LZMA-style 64-bit low / 32-bit range, carry
 * propagation, 12-bit probabilities, adaptation shift 5, zero bytes past the
 * end of a stream).  tests/test_cabac_vec.py cross-checks all three
 * implementations per lane.
 */
#include <stdint.h>
#include <stddef.h>

#define PROB_BITS 12
#define PROB_ONE (1u << PROB_BITS)
#define PROB_HALF (PROB_ONE >> 1)
#define PROB_MIN 16u
#define PROB_MAX (PROB_ONE - PROB_MIN)
#define ADAPT_SHIFT 5
#define TOP (1u << 24)
#define MASK32 0xFFFFFFFFull

#define CTX_SIGN 2
#define CTX_GR_BASE 3
#define EG_CTXS 24
#define TEMPORAL_CLASSES 3
/* sized for the temporal-context mode: 3 * (3 + 255 + 24) = 846 contexts
 * at the u8 maximum of num_gr */
#define MAX_CTX 1024

/* ------------------------------------------------------------------ decode */

typedef struct {
    const uint8_t *data;
    size_t len, pos;
    uint32_t range, code;
    uint16_t *probs;
} Dec;

static inline uint8_t dec_next_byte(Dec *d) {
    return d->pos < d->len ? d->data[d->pos++] : 0;
}

static inline int dec_bin(Dec *d, int ctx) {
    uint32_t p1 = d->probs[ctx];
    uint32_t bound = (d->range >> PROB_BITS) * p1;
    int bit;
    if (d->code < bound) {
        bit = 1;
        d->range = bound;
        p1 += (PROB_ONE - p1) >> ADAPT_SHIFT;
        if (p1 > PROB_MAX) p1 = PROB_MAX;
    } else {
        bit = 0;
        d->code -= bound;
        d->range -= bound;
        p1 -= p1 >> ADAPT_SHIFT;
        if (p1 < PROB_MIN) p1 = PROB_MIN;
    }
    d->probs[ctx] = (uint16_t)p1;
    if (d->range < TOP) {
        d->range <<= 8;
        d->code = (d->code << 8) | dec_next_byte(d);
    }
    return bit;
}

static inline int dec_bypass(Dec *d) {
    d->range >>= 1;
    int bit = 0;
    if (d->code >= d->range) {
        d->code -= d->range;
        bit = 1;
    }
    if (d->range < TOP) {
        d->range <<= 8;
        d->code = (d->code << 8) | dec_next_byte(d);
    }
    return bit;
}

/* Decode n_lanes independent level streams.
 * data:    concatenated chunk payloads
 * doff:    [n_lanes + 1] byte offsets into data
 * out:     concatenated int64 outputs
 * ooff:    [n_lanes + 1] value offsets into out (count of lane l is
 *          ooff[l+1] - ooff[l])
 * Returns 0 on success, 1 when a stream carries an Exp-Golomb exponent
 * beyond the lane engines' |level| <= 2^61 - 1 range (the arbitrary-
 * precision scalar coder can produce these) — the caller falls back to
 * the scalar path instead of wrapping int64.
 */
int32_t cabac_decode_lanes(const uint8_t *data, const int64_t *doff,
                           int64_t *out, const int64_t *ooff,
                           int32_t n_lanes, int32_t num_gr) {
    int eg_base = CTX_GR_BASE + num_gr;
    int eg_last = eg_base + EG_CTXS - 1;
    int nctx = eg_base + EG_CTXS;
    uint16_t probs[MAX_CTX];
    if (nctx > MAX_CTX) return 2; /* unreachable: num_gr is a u8 */
    for (int32_t l = 0; l < n_lanes; l++) {
        Dec d;
        d.data = data + doff[l];
        d.len = (size_t)(doff[l + 1] - doff[l]);
        d.pos = 0;
        d.range = 0xFFFFFFFFu;
        d.code = 0;
        d.probs = probs;
        for (int i = 0; i < nctx; i++) probs[i] = PROB_HALF;
        for (int i = 0; i < 4; i++) d.code = (d.code << 8) | dec_next_byte(&d);
        int64_t count = ooff[l + 1] - ooff[l];
        int64_t *o = out + ooff[l];
        int prev_sig = 0;
        for (int64_t idx = 0; idx < count; idx++) {
            if (!dec_bin(&d, prev_sig)) {
                o[idx] = 0;
                prev_sig = 0;
                continue;
            }
            prev_sig = 1;
            int neg = dec_bin(&d, CTX_SIGN);
            int64_t a = 1;
            int j = 1;
            while (j <= num_gr) {
                if (dec_bin(&d, CTX_GR_BASE + j - 1)) {
                    a = j + 1;
                    j += 1;
                } else {
                    a = j;
                    break;
                }
            }
            if (j > num_gr) {
                int k = 0;
                for (;;) {
                    int c = eg_base + k;
                    if (c > eg_last) c = eg_last;
                    if (!dec_bin(&d, c)) break;
                    k += 1;
                    if (k > 60) return 1; /* level would exceed 2^61 - 1 */
                }
                uint64_t i2 = (uint64_t)1 << k;
                for (int b = 0; b < k; b++)
                    i2 |= (uint64_t)dec_bypass(&d) << (k - 1 - b);
                a = (int64_t)((uint64_t)num_gr + i2);
            }
            o[idx] = neg ? -a : a;
        }
    }
    return 0;
}

/* Temporal-context ("P-frame") variant of cabac_decode_lanes.
 * cls: concatenated per-value class ids (same layout/offsets as out via
 * ooff); each value's context indices are offset by cls * nctx_intra into
 * one of TEMPORAL_CLASSES banks.  Classes are computed host-side from the
 * shared base frame, so encoder/decoder agreement is structural. */
int32_t cabac_decode_lanes_tc(const uint8_t *data, const int64_t *doff,
                              const int64_t *cls, int64_t *out,
                              const int64_t *ooff, int32_t n_lanes,
                              int32_t num_gr) {
    int eg_base = CTX_GR_BASE + num_gr;
    int eg_last = eg_base + EG_CTXS - 1;
    int nctx1 = eg_base + EG_CTXS;
    int nctx = TEMPORAL_CLASSES * nctx1;
    uint16_t probs[MAX_CTX];
    if (nctx > MAX_CTX) return 2; /* unreachable: num_gr is a u8 */
    for (int32_t l = 0; l < n_lanes; l++) {
        Dec d;
        d.data = data + doff[l];
        d.len = (size_t)(doff[l + 1] - doff[l]);
        d.pos = 0;
        d.range = 0xFFFFFFFFu;
        d.code = 0;
        d.probs = probs;
        for (int i = 0; i < nctx; i++) probs[i] = PROB_HALF;
        for (int i = 0; i < 4; i++) d.code = (d.code << 8) | dec_next_byte(&d);
        int64_t count = ooff[l + 1] - ooff[l];
        int64_t *o = out + ooff[l];
        const int64_t *cl = cls + ooff[l];
        int prev_sig = 0;
        for (int64_t idx = 0; idx < count; idx++) {
            int off = (int)cl[idx] * nctx1;
            if (!dec_bin(&d, off + prev_sig)) {
                o[idx] = 0;
                prev_sig = 0;
                continue;
            }
            prev_sig = 1;
            int neg = dec_bin(&d, off + CTX_SIGN);
            int64_t a = 1;
            int j = 1;
            while (j <= num_gr) {
                if (dec_bin(&d, off + CTX_GR_BASE + j - 1)) {
                    a = j + 1;
                    j += 1;
                } else {
                    a = j;
                    break;
                }
            }
            if (j > num_gr) {
                int k = 0;
                for (;;) {
                    int c = eg_base + k;
                    if (c > eg_last) c = eg_last;
                    if (!dec_bin(&d, off + c)) break;
                    k += 1;
                    if (k > 60) return 1; /* level would exceed 2^61 - 1 */
                }
                uint64_t i2 = (uint64_t)1 << k;
                for (int b = 0; b < k; b++)
                    i2 |= (uint64_t)dec_bypass(&d) << (k - 1 - b);
                a = (int64_t)((uint64_t)num_gr + i2);
            }
            o[idx] = neg ? -a : a;
        }
    }
    return 0;
}

/* ------------------------------------------------------------------ encode */

typedef struct {
    uint8_t *out;
    int64_t n;
    uint64_t low;
    uint32_t range;
    uint32_t cache;
    int64_t cache_size;
    uint16_t *probs;
} Enc;

static inline void enc_shift_low(Enc *e) {
    if (e->low < 0xFF000000u || e->low > MASK32) {
        uint32_t carry = (uint32_t)(e->low >> 32);
        e->out[e->n++] = (uint8_t)(e->cache + carry);
        uint8_t filler = (uint8_t)(0xFFu + carry);
        for (int64_t i = 0; i < e->cache_size - 1; i++) e->out[e->n++] = filler;
        e->cache_size = 0;
        e->cache = (uint8_t)(e->low >> 24);
    }
    e->cache_size += 1;
    e->low = (e->low << 8) & MASK32;
}

static inline void enc_bin(Enc *e, int ctx, int bit) {
    uint32_t p1 = e->probs[ctx];
    uint32_t bound = (e->range >> PROB_BITS) * p1;
    if (bit) {
        e->range = bound;
        p1 += (PROB_ONE - p1) >> ADAPT_SHIFT;
        if (p1 > PROB_MAX) p1 = PROB_MAX;
    } else {
        e->low += bound;
        e->range -= bound;
        p1 -= p1 >> ADAPT_SHIFT;
        if (p1 < PROB_MIN) p1 = PROB_MIN;
    }
    e->probs[ctx] = (uint16_t)p1;
    if (e->range < TOP) {
        e->range <<= 8;
        enc_shift_low(e);
    }
}

static inline void enc_bypass(Enc *e, int bit) {
    e->range >>= 1;
    if (bit) e->low += e->range;
    if (e->range < TOP) {
        e->range <<= 8;
        enc_shift_low(e);
    }
}

/* Encode n_lanes level streams.
 * levels:  concatenated int64 inputs, loff: [n_lanes + 1] value offsets
 * out:     one buffer per lane at out + l * out_stride (caller sizes
 *          out_stride for the worst case); out_lens[l] receives the byte
 *          count INCLUDING the leading dummy zero byte the range coder
 *          emits (the caller drops out[l*stride], matching
 *          RangeEncoder.finish()).
 */
void cabac_encode_lanes(const int64_t *levels, const int64_t *loff,
                        uint8_t *out, int64_t out_stride, int64_t *out_lens,
                        int32_t n_lanes, int32_t num_gr) {
    int eg_base = CTX_GR_BASE + num_gr;
    int eg_last = eg_base + EG_CTXS - 1;
    int nctx = eg_base + EG_CTXS;
    uint16_t probs[MAX_CTX];
    if (nctx > MAX_CTX) return;
    for (int32_t l = 0; l < n_lanes; l++) {
        Enc e;
        e.out = out + (int64_t)l * out_stride;
        e.n = 0;
        e.low = 0;
        e.range = 0xFFFFFFFFu;
        e.cache = 0;
        e.cache_size = 1;
        e.probs = probs;
        for (int i = 0; i < nctx; i++) probs[i] = PROB_HALF;
        const int64_t *lv = levels + loff[l];
        int64_t count = loff[l + 1] - loff[l];
        int prev_sig = 0;
        for (int64_t idx = 0; idx < count; idx++) {
            int64_t v = lv[idx];
            if (v == 0) {
                enc_bin(&e, prev_sig, 0);
                prev_sig = 0;
                continue;
            }
            enc_bin(&e, prev_sig, 1);
            prev_sig = 1;
            enc_bin(&e, CTX_SIGN, v < 0 ? 1 : 0);
            uint64_t a = (uint64_t)(v < 0 ? -v : v);
            uint64_t j = 1;
            while (j <= (uint64_t)num_gr) {
                int gr = a > j ? 1 : 0;
                enc_bin(&e, CTX_GR_BASE + (int)j - 1, gr);
                if (!gr) break;
                j += 1;
            }
            if (a > (uint64_t)num_gr) {
                uint64_t i2 = a - (uint64_t)num_gr; /* >= 1 */
                int k = 63;
                while (!(i2 >> k)) k -= 1; /* floor(log2 i2) */
                for (int p = 0; p < k; p++) {
                    int c = eg_base + p;
                    if (c > eg_last) c = eg_last;
                    enc_bin(&e, c, 1);
                }
                int c = eg_base + k;
                if (c > eg_last) c = eg_last;
                enc_bin(&e, c, 0);
                uint64_t r = i2 - ((uint64_t)1 << k);
                for (int s = k - 1; s >= 0; s--) enc_bypass(&e, (int)((r >> s) & 1));
            }
        }
        for (int i = 0; i < 5; i++) enc_shift_low(&e);
        out_lens[l] = e.n;
    }
}

/* Temporal-context variant of cabac_encode_lanes; cls shares loff with
 * levels. */
void cabac_encode_lanes_tc(const int64_t *levels, const int64_t *cls,
                           const int64_t *loff, uint8_t *out,
                           int64_t out_stride, int64_t *out_lens,
                           int32_t n_lanes, int32_t num_gr) {
    int eg_base = CTX_GR_BASE + num_gr;
    int eg_last = eg_base + EG_CTXS - 1;
    int nctx1 = eg_base + EG_CTXS;
    int nctx = TEMPORAL_CLASSES * nctx1;
    uint16_t probs[MAX_CTX];
    if (nctx > MAX_CTX) return;
    for (int32_t l = 0; l < n_lanes; l++) {
        Enc e;
        e.out = out + (int64_t)l * out_stride;
        e.n = 0;
        e.low = 0;
        e.range = 0xFFFFFFFFu;
        e.cache = 0;
        e.cache_size = 1;
        e.probs = probs;
        for (int i = 0; i < nctx; i++) probs[i] = PROB_HALF;
        const int64_t *lv = levels + loff[l];
        const int64_t *cl = cls + loff[l];
        int64_t count = loff[l + 1] - loff[l];
        int prev_sig = 0;
        for (int64_t idx = 0; idx < count; idx++) {
            int off = (int)cl[idx] * nctx1;
            int64_t v = lv[idx];
            if (v == 0) {
                enc_bin(&e, off + prev_sig, 0);
                prev_sig = 0;
                continue;
            }
            enc_bin(&e, off + prev_sig, 1);
            prev_sig = 1;
            enc_bin(&e, off + CTX_SIGN, v < 0 ? 1 : 0);
            uint64_t a = (uint64_t)(v < 0 ? -v : v);
            uint64_t j = 1;
            while (j <= (uint64_t)num_gr) {
                int gr = a > j ? 1 : 0;
                enc_bin(&e, off + CTX_GR_BASE + (int)j - 1, gr);
                if (!gr) break;
                j += 1;
            }
            if (a > (uint64_t)num_gr) {
                uint64_t i2 = a - (uint64_t)num_gr; /* >= 1 */
                int k = 63;
                while (!(i2 >> k)) k -= 1; /* floor(log2 i2) */
                for (int p = 0; p < k; p++) {
                    int c = eg_base + p;
                    if (c > eg_last) c = eg_last;
                    enc_bin(&e, off + c, 1);
                }
                int c = eg_base + k;
                if (c > eg_last) c = eg_last;
                enc_bin(&e, off + c, 0);
                uint64_t r = i2 - ((uint64_t)1 << k);
                for (int s = k - 1; s >= 0; s--) enc_bypass(&e, (int)((r >> s) & 1));
            }
        }
        for (int i = 0; i < 5; i++) enc_shift_low(&e);
        out_lens[l] = e.n;
    }
}
