"""CSR-Huffman baseline ([38] Deep Compression, paper §IV-B-3) + bzip2.

CSR-Huffman stores a sparse matrix as (row_ptr, col-index deltas, values) and
Huffman-codes the delta and value streams.  As in Deep Compression, column
deltas are capped at ``2**delta_bits - 1`` with zero-valued padding symbols
for longer runs.
"""

from __future__ import annotations

import bz2

import numpy as np

from .huffman import build_huffman, huffman_payload_bits


def csr_streams(levels2d: np.ndarray, delta_cap: int = 255
                ) -> tuple[np.ndarray, np.ndarray, int]:
    """Return (delta_stream, value_stream, num_rows) with padding symbols."""
    m = np.asarray(levels2d)
    if m.ndim == 1:
        m = m[None, :]
    elif m.ndim > 2:
        m = m.reshape(m.shape[0], -1)
    deltas: list[int] = []
    values: list[int] = []
    for row in m:
        (nz,) = np.nonzero(row)
        prev = -1
        for c in nz.tolist():
            d = c - prev
            while d > delta_cap:          # padding: emit zero value
                deltas.append(delta_cap)
                values.append(0)
                d -= delta_cap
            deltas.append(d)
            values.append(int(row[c]))
            prev = c
    return (np.asarray(deltas, dtype=np.int64),
            np.asarray(values, dtype=np.int64), m.shape[0])


def csr_huffman_size_bits(levels2d: np.ndarray, delta_cap: int = 255) -> int:
    deltas, values, nrows = csr_streams(levels2d, delta_cap)
    bits = 32 * (nrows + 1)               # row_ptr
    if deltas.size:
        dc = build_huffman(deltas)
        vc = build_huffman(values)
        bits += huffman_payload_bits(deltas, dc) + dc.table_bits
        bits += huffman_payload_bits(values, vc) + vc.table_bits
    return bits


def _min_int_dtype(levels: np.ndarray) -> np.dtype:
    a = np.asarray(levels)
    amax = int(np.abs(a).max()) if a.size else 0
    if amax < 128:
        return np.dtype(np.int8)
    if amax < (1 << 15):
        return np.dtype(np.int16)
    return np.dtype(np.int32)


def bzip2_size_bits(levels: np.ndarray) -> int:
    """bzip2 over the narrowest integer packing of the level array."""
    a = np.asarray(levels).astype(_min_int_dtype(levels))
    return 8 * len(bz2.compress(a.tobytes(), 9))
