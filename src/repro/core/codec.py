"""Tensor / pytree encode-decode API on top of the CABAC engine.

This is the public surface used by checkpointing, the serving loader and the
examples: quantized integer levels <-> chunk-parallel CABAC bitstreams packed
into a DCBC container.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import binarization as B
from .cabac import RangeDecoder, RangeEncoder
from .container import ENC_CABAC, ENC_RAW, ContainerReader, ContainerWriter

DEFAULT_CHUNK = 1 << 16


def resolve_dtype(name: str) -> np.dtype:
    """np.dtype that also understands ml_dtypes names (bfloat16, ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclass
class QuantizedTensor:
    """A tensor on the equidistant grid q = step * level."""

    levels: np.ndarray            # int64, original shape
    step: float
    dtype: str = "float32"        # reconstruction dtype

    def dequantize(self) -> np.ndarray:
        return (self.levels.astype(np.float64) * self.step).astype(
            resolve_dtype(self.dtype))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.levels.shape)


def encode_level_chunks(levels: np.ndarray, num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> list[bytes]:
    """Encode a flat level array as independently-decodable chunks."""
    flat = np.asarray(levels).ravel()
    chunks = []
    for s in range(0, max(flat.size, 1), chunk_size):
        blk = flat[s:s + chunk_size]
        enc = RangeEncoder(B.make_contexts(num_gr))
        B.encode_levels(enc, blk, num_gr)
        chunks.append(enc.finish())
    return chunks


def decode_level_chunks(chunk_payloads: list[bytes], count: int,
                        num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> np.ndarray:
    out = np.empty(count, dtype=np.int64)
    pos = 0
    for payload in chunk_payloads:
        n = min(chunk_size, count - pos)
        dec = RangeDecoder(payload, B.make_contexts(num_gr))
        out[pos:pos + n] = B.decode_levels(dec, n, num_gr)
        pos += n
    assert pos == count, f"decoded {pos} of {count} values"
    return out


def encode_state_dict(entries: dict[str, QuantizedTensor | np.ndarray],
                      num_gr: int = B.DEFAULT_NUM_GR,
                      chunk_size: int = DEFAULT_CHUNK) -> bytes:
    """Quantized tensors are CABAC-coded; raw ndarrays pass through verbatim
    (biases / norm scales / step tables the pipeline chose not to quantize)."""
    w = ContainerWriter()
    for name, entry in entries.items():
        if isinstance(entry, QuantizedTensor):
            chunks = encode_level_chunks(entry.levels, num_gr, chunk_size)
            w.add_cabac(name, entry.dtype, entry.shape, entry.step,
                        num_gr, chunk_size, chunks)
        else:
            w.add_raw(name, np.asarray(entry))
    return w.tobytes()


def decode_state_dict(data: bytes, dequantize: bool = True
                      ) -> dict[str, np.ndarray | QuantizedTensor]:
    out: dict[str, np.ndarray | QuantizedTensor] = {}
    for hdr, payload in ContainerReader(data):
        if hdr.encoding == ENC_RAW:
            out[hdr.name] = np.frombuffer(
                payload, dtype=resolve_dtype(hdr.dtype)).reshape(
                    hdr.shape).copy()
        elif hdr.encoding == ENC_CABAC:
            count = int(np.prod(hdr.shape)) if hdr.shape else 1
            offs, chunks = 0, []
            for ln in hdr.chunk_lens:
                chunks.append(payload[offs:offs + ln])
                offs += ln
            levels = decode_level_chunks(
                chunks, count, hdr.num_gr, hdr.chunk_size).reshape(hdr.shape)
            qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
            out[hdr.name] = qt.dequantize() if dequantize else qt
        else:
            raise ValueError(f"unknown encoding {hdr.encoding}")
    return out


def compressed_size_report(entries: dict[str, QuantizedTensor | np.ndarray],
                           blob: bytes) -> dict[str, float]:
    """Bits/param + ratio vs. the fp32 footprint (paper's 'Org. size')."""
    n_params = 0
    for e in entries.values():
        n_params += int(np.prod(e.levels.shape if isinstance(
            e, QuantizedTensor) else np.asarray(e).shape))
    orig_bytes = 4 * n_params
    return {
        "params": float(n_params),
        "orig_mb": orig_bytes / 2**20,
        "compressed_mb": len(blob) / 2**20,
        "ratio_pct": 100.0 * len(blob) / orig_bytes,
        "bits_per_param": 8.0 * len(blob) / max(n_params, 1),
    }
