"""Tensor / pytree encode-decode API on top of the CABAC engine.

This is the low-level surface the ``repro.compression`` Codec API builds
on: quantized integer levels <-> entropy-coded bitstreams packed into a
DCBC container.  Decoding is codec-independent — the container records
are self-describing, so :func:`decode_state_dict` restores any blob a
registered codec produced (CABAC, Huffman, raw int8 + scales, raw).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import binarization as B
from .cabac import RangeDecoder, RangeEncoder
from .container import (ENC_CABAC, ENC_HUFF, ENC_Q8, ENC_RAW,
                        ContainerReader, ContainerWriter)

DEFAULT_CHUNK = 1 << 16


def resolve_dtype(name: str) -> np.dtype:
    """np.dtype that also understands ml_dtypes names (bfloat16, ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclass
class QuantizedTensor:
    """A tensor on the equidistant grid q = step * level."""

    levels: np.ndarray            # int64, original shape
    step: float
    dtype: str = "float32"        # reconstruction dtype

    def dequantize(self) -> np.ndarray:
        return (self.levels.astype(np.float64) * self.step).astype(
            resolve_dtype(self.dtype))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.levels.shape)


@dataclass
class Q8Tensor:
    """int8 levels with per-channel scales q = scale[..., c] * level.

    The fixed-point serving representation: ``scale`` is per-out-channel
    (last dim); stacked (L, ..., out) tensors carry an (L, out) scale so a
    layer scan can slice levels and scales together.
    """

    levels: np.ndarray            # int8, original shape
    scale: np.ndarray             # float32, (out,) or (L, out)
    dtype: str = "float32"        # reconstruction dtype

    def dequantize(self) -> np.ndarray:
        s = np.asarray(self.scale, dtype=np.float32)
        lv = self.levels
        if lv.ndim >= 3 and s.ndim == 2:
            s = s.reshape(s.shape[0], *([1] * (lv.ndim - 2)), s.shape[-1])
        return (lv.astype(np.float32) * s).astype(resolve_dtype(self.dtype))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.levels.shape)


def encode_level_chunks(levels: np.ndarray, num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> list[bytes]:
    """Encode a flat level array as independently-decodable chunks."""
    flat = np.asarray(levels).ravel()
    chunks = []
    for s in range(0, max(flat.size, 1), chunk_size):
        blk = flat[s:s + chunk_size]
        enc = RangeEncoder(B.make_contexts(num_gr))
        B.encode_levels(enc, blk, num_gr)
        chunks.append(enc.finish())
    return chunks


def decode_level_chunks(chunk_payloads: list[bytes], count: int,
                        num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> np.ndarray:
    out = np.empty(count, dtype=np.int64)
    pos = 0
    for payload in chunk_payloads:
        n = min(chunk_size, count - pos)
        dec = RangeDecoder(payload, B.make_contexts(num_gr))
        out[pos:pos + n] = B.decode_levels(dec, n, num_gr)
        pos += n
    assert pos == count, f"decoded {pos} of {count} values"
    return out


def encode_state_dict(entries: dict[str, QuantizedTensor | np.ndarray],
                      num_gr: int = B.DEFAULT_NUM_GR,
                      chunk_size: int = DEFAULT_CHUNK) -> bytes:
    """Quantized tensors are CABAC-coded; raw ndarrays pass through verbatim
    (biases / norm scales / step tables the pipeline chose not to quantize)."""
    w = ContainerWriter()
    for name, entry in entries.items():
        if isinstance(entry, QuantizedTensor):
            chunks = encode_level_chunks(entry.levels, num_gr, chunk_size)
            w.add_cabac(name, entry.dtype, entry.shape, entry.step,
                        num_gr, chunk_size, chunks)
        elif isinstance(entry, Q8Tensor):
            w.add_q8(name, entry.dtype, entry.levels, entry.scale)
        else:
            w.add_raw(name, np.asarray(entry))
    return w.tobytes()


def decode_record(hdr, payload: bytes, dequantize: bool = True
                  ) -> np.ndarray | QuantizedTensor | Q8Tensor:
    """Decode one container record (header + payload) to its tensor."""
    if hdr.encoding == ENC_RAW:
        return np.frombuffer(
            payload, dtype=resolve_dtype(hdr.dtype)).reshape(
                hdr.shape).copy()
    if hdr.encoding == ENC_CABAC:
        count = int(np.prod(hdr.shape)) if hdr.shape else 1
        offs, chunks = 0, []
        for ln in hdr.chunk_lens:
            chunks.append(payload[offs:offs + ln])
            offs += ln
        levels = decode_level_chunks(
            chunks, count, hdr.num_gr, hdr.chunk_size).reshape(hdr.shape)
        qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
        return qt.dequantize() if dequantize else qt
    if hdr.encoding == ENC_HUFF:
        from .huffman import unpack_payload
        count = int(np.prod(hdr.shape)) if hdr.shape else 1
        levels = unpack_payload(payload, count).reshape(hdr.shape)
        qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
        return qt.dequantize() if dequantize else qt
    if hdr.encoding == ENC_Q8:
        sc_count = int(np.prod(hdr.scale_shape)) if hdr.scale_shape else 1
        scale = np.frombuffer(payload, dtype="<f4",
                              count=sc_count).reshape(
                                  hdr.scale_shape).copy()
        levels = np.frombuffer(payload, dtype=np.int8,
                               offset=4 * sc_count).reshape(
                                   hdr.shape).copy()
        q8 = Q8Tensor(levels=levels, scale=scale, dtype=hdr.dtype)
        return q8.dequantize() if dequantize else q8
    raise ValueError(f"unknown encoding {hdr.encoding}")


def iter_decode_state_dict(data: bytes, dequantize: bool = True):
    """Per-tensor streaming decode: yields ``(name, tensor)`` record by
    record, so a consumer that converts/discards each tensor before pulling
    the next keeps peak decoded host memory bounded by the largest single
    tensor, not the model (the container backend's load path)."""
    for hdr, payload in ContainerReader(data):
        yield hdr.name, decode_record(hdr, payload, dequantize)


def decode_state_dict(data: bytes, dequantize: bool = True
                      ) -> dict[str, np.ndarray | QuantizedTensor | Q8Tensor]:
    return dict(iter_decode_state_dict(data, dequantize))


def compressed_size_report(entries: dict, blob: bytes) -> dict[str, float]:
    """Bits/param + ratio vs. the *original-dtype* footprint (the paper's
    'Org. size'; bf16/fp16 state dicts count 2 bytes/param, not 4)."""
    n_params = 0
    orig_bytes = 0
    for e in entries.values():
        if hasattr(e, "levels"):           # QuantizedTensor | Q8Tensor
            n = int(np.prod(e.levels.shape))
            nb = n * resolve_dtype(e.dtype).itemsize
        else:
            arr = np.asarray(e)
            n, nb = arr.size, arr.nbytes
        n_params += n
        orig_bytes += nb
    return {
        "params": float(n_params),
        "orig_mb": orig_bytes / 2**20,
        "compressed_mb": len(blob) / 2**20,
        "ratio_pct": 100.0 * len(blob) / max(orig_bytes, 1),
        "bits_per_param": 8.0 * len(blob) / max(n_params, 1),
    }
