"""Tensor / pytree encode-decode API on top of the CABAC engine.

This is the low-level surface the ``repro.compression`` Codec API builds
on: quantized integer levels <-> entropy-coded bitstreams packed into a
DCBC container.  Decoding is codec-independent — the container records
are self-describing, so :func:`decode_state_dict` restores any blob a
registered codec produced (CABAC, Huffman, raw int8 + scales, raw).
"""

from __future__ import annotations

import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import binarization as B
from . import cabac_vec
from .cabac import RangeDecoder, RangeEncoder, temporal_classes
from .container import (ENC_CABAC, ENC_CABAC_DELTA, ENC_CABAC_V3, ENC_HUFF,
                        ENC_Q8, ENC_RAW, ContainerReader, ContainerWriter)

DEFAULT_CHUNK = 1 << 16


def default_lanes() -> int:
    """Read at every ``DecodeOptions()`` construction, so setting
    ``REPRO_CABAC_LANES`` after import still takes effect."""
    return int(os.environ.get("REPRO_CABAC_LANES", "64"))


def default_backend() -> str:
    """``REPRO_CABAC_BACKEND`` pins the decode engine process-wide
    (``c``/``numpy``/``scalar``; default ``auto``).  CI uses ``c`` to
    *fail loudly* when the compiled lane kernel is unavailable instead of
    silently benchmarking the numpy fallback."""
    return os.environ.get("REPRO_CABAC_BACKEND", "auto")


@dataclass
class DecodeOptions:
    """How CABAC records are entropy-decoded.

    ``backend`` picks the lane engine (``auto``/``c``/``numpy`` from
    :mod:`repro.core.cabac_vec`) or ``scalar`` for the serial per-chunk
    loop; ``lanes`` is how many chunk streams one vectorized batch
    advances in lockstep.  ``workers``/``pool`` parallelize the scalar
    path over a thread or process pool — it runs when
    ``backend="scalar"`` is chosen explicitly, or as the automatic
    fallback for lane batches the vector engines refuse (levels beyond
    ``cabac_vec.MAX_ABS_LEVEL``, which only the arbitrary-precision
    scalar coder can have written).
    """

    lanes: int = field(default_factory=default_lanes)
    backend: str = field(default_factory=default_backend)
    # auto | c | numpy | scalar (default REPRO_CABAC_BACKEND or "auto")
    workers: int = 0          # 0 => in-line serial scalar path
    pool: str = "thread"      # thread | process


def resolve_dtype(name: str) -> np.dtype:
    """np.dtype that also understands ml_dtypes names (bfloat16, ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclass
class QuantizedTensor:
    """A tensor on the equidistant grid q = step * level."""

    levels: np.ndarray            # int64, original shape
    step: float
    dtype: str = "float32"        # reconstruction dtype

    def dequantize(self) -> np.ndarray:
        return (self.levels.astype(np.float64) * self.step).astype(
            resolve_dtype(self.dtype))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.levels.shape)


@dataclass
class Q8Tensor:
    """int8 levels with per-channel scales q = scale[..., c] * level.

    The fixed-point serving representation: ``scale`` is per-out-channel
    (last dim); stacked (L, ..., out) tensors carry an (L, out) scale so a
    layer scan can slice levels and scales together.
    """

    levels: np.ndarray            # int8, original shape
    scale: np.ndarray             # float32, (out,) or (L, out)
    dtype: str = "float32"        # reconstruction dtype

    def dequantize(self) -> np.ndarray:
        s = np.asarray(self.scale, dtype=np.float32)
        lv = self.levels
        if lv.ndim >= 3 and s.ndim == 2:
            s = s.reshape(s.shape[0], *([1] * (lv.ndim - 2)), s.shape[-1])
        return (lv.astype(np.float32) * s).astype(resolve_dtype(self.dtype))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.levels.shape)


def encode_level_chunks(levels: np.ndarray, num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> list[bytes]:
    """Encode a flat level array as independently-decodable chunks."""
    flat = np.asarray(levels).ravel()
    chunks = []
    for s in range(0, max(flat.size, 1), chunk_size):
        blk = flat[s:s + chunk_size]
        enc = RangeEncoder(B.make_contexts(num_gr))
        B.encode_levels(enc, blk, num_gr)
        chunks.append(enc.finish())
    return chunks


def decode_level_chunks(chunk_payloads: list[bytes], count: int,
                        num_gr: int = B.DEFAULT_NUM_GR,
                        chunk_size: int = DEFAULT_CHUNK) -> np.ndarray:
    out = np.empty(count, dtype=np.int64)
    pos = 0
    for payload in chunk_payloads:
        n = min(chunk_size, count - pos)
        dec = RangeDecoder(payload, B.make_contexts(num_gr))
        out[pos:pos + n] = B.decode_levels(dec, n, num_gr)
        pos += n
    assert pos == count, f"decoded {pos} of {count} values"
    return out


def encode_level_chunks_batched(levels: np.ndarray,
                                num_gr: int = B.DEFAULT_NUM_GR,
                                chunk_size: int = DEFAULT_CHUNK,
                                backend: str = "auto"
                                ) -> tuple[list[bytes], list[int]]:
    """Chunk a flat level array and encode all chunks as one lane batch.

    Returns ``(payloads, counts)`` — the per-chunk value counts are the
    lane metadata a v3 container record stores so readers can schedule
    decode batches without re-deriving them.  Byte-identical to
    :func:`encode_level_chunks` per chunk.
    """
    flat = np.asarray(levels).ravel()
    blocks = [flat[s:s + chunk_size]
              for s in range(0, max(flat.size, 1), chunk_size)]
    payloads = cabac_vec.encode_lanes(blocks, num_gr, backend=backend)
    return payloads, [b.size for b in blocks]


def _decode_one_chunk(args):
    payload, n, num_gr = args
    dec = RangeDecoder(payload, B.make_contexts(num_gr))
    return B.decode_levels(dec, n, num_gr)


def _decode_chunks_scalar(chunk_payloads, counts, num_gr, workers=0,
                          pool="thread"):
    jobs = [(bytes(p), n, num_gr) for p, n in zip(chunk_payloads, counts)]
    if workers and len(jobs) > 1:
        if pool == "process":
            # spawn: fork is unsafe once jax's thread pools exist
            ex = ProcessPoolExecutor(
                max_workers=workers,
                mp_context=multiprocessing.get_context("spawn"))
        else:
            ex = ThreadPoolExecutor(max_workers=workers)
        with ex:
            return list(ex.map(_decode_one_chunk, jobs))
    return [_decode_one_chunk(j) for j in jobs]


def decode_level_chunks_batched(chunk_payloads: list[bytes],
                                chunk_counts: list[int],
                                num_gr: int = B.DEFAULT_NUM_GR,
                                opts: DecodeOptions | None = None
                                ) -> np.ndarray:
    """Decode independently-coded chunks as lane batches (or the scalar
    residual path) and concatenate the levels in chunk order."""
    opts = opts or DecodeOptions()
    if not chunk_payloads:
        return np.empty(0, dtype=np.int64)
    if opts.backend == "scalar":
        parts = _decode_chunks_scalar(chunk_payloads, chunk_counts, num_gr,
                                      opts.workers, opts.pool)
    else:
        parts = []
        lanes = max(int(opts.lanes), 1)
        for s in range(0, len(chunk_payloads), lanes):
            batch = [bytes(p) for p in chunk_payloads[s:s + lanes]]
            counts = chunk_counts[s:s + lanes]
            try:
                parts.extend(cabac_vec.decode_lanes(
                    batch, counts, num_gr, backend=opts.backend))
            except OverflowError:
                # residual scalar path: a stream in this batch carries
                # levels beyond the lane engines' int64-safe range (only
                # the arbitrary-precision scalar coder writes those)
                parts.extend(_decode_chunks_scalar(
                    batch, counts, num_gr, opts.workers, opts.pool))
    out = (np.concatenate(parts) if parts else np.empty(0, dtype=np.int64))
    total = int(sum(chunk_counts))
    assert out.size == total, f"decoded {out.size} of {total} values"
    return out


# ---------------------------------------------------------------------------
# Temporal-context delta ("P-frame") chunk coding
# ---------------------------------------------------------------------------

@dataclass
class DeltaTensor:
    """An integer-level residual against a base frame's levels.

    ``resid = new_levels - base_levels`` elementwise on the *same*
    quantization grid (the base frame's ``step``), so base + every chained
    residual reconstructs the direct encoding bit-for-bit — zero drift.
    ``base`` rides along because the entropy coder conditions each
    residual's context bank on the co-located base level
    (``cabac.temporal_classes``).
    """

    resid: np.ndarray             # int64, original shape
    base: np.ndarray              # int64, same shape (context source)
    step: float
    dtype: str = "float32"        # reconstruction dtype

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.resid.shape)

    def new_levels(self) -> np.ndarray:
        return (self.base.astype(np.int64)
                + self.resid.astype(np.int64))


def encode_delta_chunks_batched(resid: np.ndarray, base_levels: np.ndarray,
                                num_gr: int = B.DEFAULT_NUM_GR,
                                chunk_size: int = DEFAULT_CHUNK,
                                backend: str = "auto"
                                ) -> tuple[list[bytes], list[int]]:
    """Chunk a flat residual array and temporal-context-encode all chunks
    as one lane batch; classes come from the co-located ``base_levels``.
    Returns ``(payloads, counts)`` like the v3 encoder."""
    flat = np.asarray(resid).ravel()
    cls = temporal_classes(base_levels)
    if cls.size != flat.size:
        raise ValueError(
            f"delta of {flat.size} values against a base of {cls.size}")
    blocks = [flat[s:s + chunk_size]
              for s in range(0, max(flat.size, 1), chunk_size)]
    cblocks = [cls[s:s + chunk_size]
               for s in range(0, max(flat.size, 1), chunk_size)]
    payloads = cabac_vec.encode_lanes_tc(blocks, cblocks, num_gr,
                                         backend=backend)
    return payloads, [b.size for b in blocks]


def _decode_one_chunk_tc(args):
    payload, cls, num_gr = args
    dec = RangeDecoder(payload, B.make_contexts_tc(num_gr))
    return B.decode_levels_tc(dec, cls, num_gr)


def _decode_chunks_scalar_tc(chunk_payloads, cls_blocks, num_gr, workers=0,
                             pool="thread"):
    jobs = [(bytes(p), c, num_gr)
            for p, c in zip(chunk_payloads, cls_blocks)]
    if workers and len(jobs) > 1:
        if pool == "process":
            ex = ProcessPoolExecutor(
                max_workers=workers,
                mp_context=multiprocessing.get_context("spawn"))
        else:
            ex = ThreadPoolExecutor(max_workers=workers)
        with ex:
            return list(ex.map(_decode_one_chunk_tc, jobs))
    return [_decode_one_chunk_tc(j) for j in jobs]


def decode_delta_chunks_batched(chunk_payloads: list[bytes],
                                chunk_counts: list[int],
                                base_levels: np.ndarray,
                                num_gr: int = B.DEFAULT_NUM_GR,
                                opts: DecodeOptions | None = None
                                ) -> np.ndarray:
    """Decode temporal-context residual chunks; ``base_levels`` supplies
    the per-element context classes and must cover ``sum(chunk_counts)``
    values.  Returns the flat residual (not base + resid)."""
    opts = opts or DecodeOptions()
    cls = temporal_classes(base_levels)
    total = int(sum(chunk_counts))
    if cls.size != total:
        raise ValueError(
            f"delta record of {total} values against a base of {cls.size}")
    if not chunk_payloads:
        return np.empty(0, dtype=np.int64)
    offs = np.zeros(len(chunk_counts) + 1, dtype=np.int64)
    np.cumsum(chunk_counts, out=offs[1:])
    cls_blocks = [cls[offs[i]:offs[i + 1]]
                  for i in range(len(chunk_counts))]
    if opts.backend == "scalar":
        parts = _decode_chunks_scalar_tc(chunk_payloads, cls_blocks, num_gr,
                                         opts.workers, opts.pool)
    else:
        parts = []
        lanes = max(int(opts.lanes), 1)
        for s in range(0, len(chunk_payloads), lanes):
            batch = [bytes(p) for p in chunk_payloads[s:s + lanes]]
            cbatch = cls_blocks[s:s + lanes]
            try:
                parts.extend(cabac_vec.decode_lanes_tc(
                    batch, cbatch, num_gr, backend=opts.backend))
            except OverflowError:
                parts.extend(_decode_chunks_scalar_tc(
                    batch, cbatch, num_gr, opts.workers, opts.pool))
    out = (np.concatenate(parts) if parts else np.empty(0, dtype=np.int64))
    assert out.size == total, f"decoded {out.size} of {total} values"
    return out


def decode_delta_record(hdr, payload: bytes, base_levels: np.ndarray,
                        dequantize: bool = False,
                        opts: DecodeOptions | None = None
                        ) -> np.ndarray | QuantizedTensor:
    """Decode one ENC_CABAC_DELTA record next to its base frame's levels
    and return the reconstructed *new-frame* tensor (base + residual) —
    as a :class:`QuantizedTensor` by default, so chained deltas can feed
    the next link's base."""
    if hdr.encoding != ENC_CABAC_DELTA:
        raise ValueError(
            f"{hdr.name}: not a delta record (encoding {hdr.encoding})")
    base = np.asarray(base_levels, dtype=np.int64)
    count = int(np.prod(hdr.shape)) if hdr.shape else 1
    if base.size != count:
        raise ValueError(
            f"{hdr.name}: delta record of shape {hdr.shape} against a "
            f"base of {base.size} values")
    counts = _v3_chunk_counts(hdr)
    chunks = _split_chunks(payload, hdr.chunk_lens)
    resid = decode_delta_chunks_batched(chunks, counts, base, hdr.num_gr,
                                        opts)
    levels = (base.ravel() + resid).reshape(hdr.shape)
    qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
    return qt.dequantize() if dequantize else qt


def encode_state_dict(entries: dict[str, QuantizedTensor | np.ndarray],
                      num_gr: int = B.DEFAULT_NUM_GR,
                      chunk_size: int = DEFAULT_CHUNK) -> bytes:
    """Quantized tensors are CABAC-coded; raw ndarrays pass through verbatim
    (biases / norm scales / step tables the pipeline chose not to quantize)."""
    w = ContainerWriter()
    for name, entry in entries.items():
        if isinstance(entry, QuantizedTensor):
            chunks = encode_level_chunks(entry.levels, num_gr, chunk_size)
            w.add_cabac(name, entry.dtype, entry.shape, entry.step,
                        num_gr, chunk_size, chunks)
        elif isinstance(entry, Q8Tensor):
            w.add_q8(name, entry.dtype, entry.levels, entry.scale)
        else:
            w.add_raw(name, np.asarray(entry))
    return w.tobytes()


def _split_chunks(payload, chunk_lens):
    offs, chunks = 0, []
    for ln in chunk_lens:
        chunks.append(payload[offs:offs + ln])
        offs += ln
    return chunks


def _v3_chunk_counts(hdr) -> list[int]:
    """Validated per-chunk lane metadata of an ENC_CABAC_V3 record."""
    count = int(np.prod(hdr.shape)) if hdr.shape else 1
    counts = [int(c) for c in hdr.chunk_counts]
    if sum(counts) != hdr.total_count or hdr.total_count != count:
        raise ValueError(
            f"{hdr.name}: lane metadata disagrees — chunk counts sum to "
            f"{sum(counts)}, header total {hdr.total_count}, shape wants "
            f"{count}")
    return counts


def decode_record(hdr, payload: bytes, dequantize: bool = True,
                  opts: DecodeOptions | None = None
                  ) -> np.ndarray | QuantizedTensor | Q8Tensor:
    """Decode one container record (header + payload) to its tensor."""
    if hdr.encoding == ENC_RAW:
        return np.frombuffer(
            payload, dtype=resolve_dtype(hdr.dtype)).reshape(
                hdr.shape).copy()
    if hdr.encoding == ENC_CABAC:
        count = int(np.prod(hdr.shape)) if hdr.shape else 1
        chunks = _split_chunks(payload, hdr.chunk_lens)
        levels = decode_level_chunks(
            chunks, count, hdr.num_gr, hdr.chunk_size).reshape(hdr.shape)
        qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
        return qt.dequantize() if dequantize else qt
    if hdr.encoding == ENC_CABAC_V3:
        counts = _v3_chunk_counts(hdr)
        chunks = _split_chunks(payload, hdr.chunk_lens)
        # all chunks of the tensor go through the lane engine as one batch
        levels = decode_level_chunks_batched(
            chunks, counts, hdr.num_gr, opts).reshape(hdr.shape)
        qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
        return qt.dequantize() if dequantize else qt
    if hdr.encoding == ENC_HUFF:
        from .huffman import unpack_payload
        count = int(np.prod(hdr.shape)) if hdr.shape else 1
        levels = unpack_payload(payload, count).reshape(hdr.shape)
        qt = QuantizedTensor(levels=levels, step=hdr.step, dtype=hdr.dtype)
        return qt.dequantize() if dequantize else qt
    if hdr.encoding == ENC_CABAC_DELTA:
        raise ValueError(
            f"{hdr.name}: ENC_CABAC_DELTA records are residuals against a "
            "base frame and cannot be decoded standalone — resolve the "
            "delta chain (repro.checkpoint.delta.resolve_chain) and decode "
            "through decode_delta_record with the base frame's levels")
    if hdr.encoding == ENC_Q8:
        sc_count = int(np.prod(hdr.scale_shape)) if hdr.scale_shape else 1
        scale = np.frombuffer(payload, dtype="<f4",
                              count=sc_count).reshape(
                                  hdr.scale_shape).copy()
        levels = np.frombuffer(payload, dtype=np.int8,
                               offset=4 * sc_count).reshape(
                                   hdr.shape).copy()
        q8 = Q8Tensor(levels=levels, scale=scale, dtype=hdr.dtype)
        return q8.dequantize() if dequantize else q8
    raise ValueError(f"unknown encoding {hdr.encoding}")


def iter_decode_state_dict(data: bytes, dequantize: bool = True,
                           opts: DecodeOptions | None = None):
    """Per-tensor streaming decode: yields ``(name, tensor)`` record by
    record, so a consumer that converts/discards each tensor before pulling
    the next keeps peak decoded host memory bounded by the largest single
    tensor, not the model (the container backend's load path).  v3 cabac
    records batch all of a tensor's chunks into one lane decode, so
    streaming consumers still get lane-parallel entropy decode."""
    for hdr, payload in ContainerReader(data):
        yield hdr.name, decode_record(hdr, payload, dequantize, opts)


def decode_state_dict(data: bytes, dequantize: bool = True,
                      opts: DecodeOptions | None = None
                      ) -> dict[str, np.ndarray | QuantizedTensor | Q8Tensor]:
    return dict(iter_decode_state_dict(data, dequantize, opts))


def decode_state_dict_batched(data: bytes, dequantize: bool = True,
                              opts: DecodeOptions | None = None
                              ) -> dict:
    """Whole-container lane scheduling: every CABAC chunk of every record
    (v1 records derive their counts from shape/chunk_size; v3 records carry
    them) joins one global decode batch, so lanes stay full even when
    tensors are smaller than ``opts.lanes`` chunks.  Peak decoded host
    memory is model-bound — this is the cold-start path (checkpoint
    restore, offline eval), not the streaming serve path."""
    opts = opts or DecodeOptions()
    records = list(ContainerReader(data))
    # One batch per num_gr (context-bank size is a per-record knob):
    # num_gr -> (chunks, counts, [(record idx, first chunk, nchunks)])
    groups: dict[int, tuple[list, list, list]] = {}
    for i, (hdr, payload) in enumerate(records):
        if hdr.encoding not in (ENC_CABAC, ENC_CABAC_V3):
            continue
        chunks = _split_chunks(payload, hdr.chunk_lens)
        if hdr.encoding == ENC_CABAC_V3:
            counts = _v3_chunk_counts(hdr)
        else:
            total = int(np.prod(hdr.shape)) if hdr.shape else 1
            csz = hdr.chunk_size or total or 1
            counts = [min(csz, total - s)
                      for s in range(0, max(total, 1), csz)]
        gch, gct, gspan = groups.setdefault(hdr.num_gr, ([], [], []))
        gspan.append((i, len(gch), len(chunks)))
        gch.extend(chunks)
        gct.extend(counts)
    decoded: dict[int, QuantizedTensor] = {}
    for num_gr, (gch, gct, gspan) in groups.items():
        flat = decode_level_chunks_batched(gch, gct, num_gr, opts)
        offsets = np.zeros(len(gct) + 1, dtype=np.int64)
        np.cumsum(gct, out=offsets[1:])
        for i, first, nch in gspan:
            hdr = records[i][0]
            levels = flat[offsets[first]:offsets[first + nch]].reshape(
                hdr.shape)
            decoded[i] = QuantizedTensor(levels=levels, step=hdr.step,
                                         dtype=hdr.dtype)
    out: dict = {}
    for i, (hdr, payload) in enumerate(records):
        if i in decoded:
            qt = decoded[i]
            out[hdr.name] = qt.dequantize() if dequantize else qt
        else:
            out[hdr.name] = decode_record(hdr, payload, dequantize, opts)
    return out


def compressed_size_report(entries: dict, blob: bytes) -> dict[str, float]:
    """Bits/param + ratio vs. the *original-dtype* footprint (the paper's
    'Org. size'; bf16/fp16 state dicts count 2 bytes/param, not 4)."""
    n_params = 0
    orig_bytes = 0
    for e in entries.values():
        if hasattr(e, "levels"):           # QuantizedTensor | Q8Tensor
            n = int(np.prod(e.levels.shape))
            nb = n * resolve_dtype(e.dtype).itemsize
        else:
            arr = np.asarray(e)
            n, nb = arr.size, arr.nbytes
        n_params += n
        orig_bytes += nb
    return {
        "params": float(n_params),
        "orig_mb": orig_bytes / 2**20,
        "compressed_mb": len(blob) / 2**20,
        "ratio_pct": 100.0 * len(blob) / max(orig_bytes, 1),
        "bits_per_param": 8.0 * len(blob) / max(n_params, 1),
    }
