"""DeepCABAC top-level pipelines: DC-v1 and DC-v2 (paper §III, Fig. 5).

Pipeline per Fig. 5:  scan weights layer-by-layer (row-major) -> pick a
hyperparameter beta = (Delta, lambda) -> RD-quantize (eq. 11) -> CABAC-code ->
reconstruct & evaluate -> repeat over the hyperparameter grid until the
desired accuracy-vs-size trade-off.

DC-v1 (eq. 12): per-layer step size from sigma_min and w_max with global
coarseness S; importance F_i = 1/sigma_i^2.
DC-v2: global Delta grid (bracketed by a nearest-neighbour screening round),
F_i = 1.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from . import binarization as B
from ..compression.artifact import Artifact
from .codec import QuantizedTensor
from .quant import nearest_level, rd_assign
from .rate_model import build_rate_table, estimate_bin_probs

QUANT_MIN_NDIM = 2   # 1-D tensors (biases/norms) stay raw, as in the paper


def dc_v1_step_size(w_max: float, sigma_min: float, s: float) -> float:
    """Paper eq. (12): Delta = 2|w_max| / (2|w_max|/sigma_min + S)."""
    w_max = abs(float(w_max))
    if w_max == 0.0:
        return 1.0
    return 2.0 * w_max / (2.0 * w_max / max(sigma_min, 1e-12) + s)


def quantize_tensor_rd(w: np.ndarray, step: float, lam: float,
                       importance: np.ndarray | None = None,
                       num_gr: int = B.DEFAULT_NUM_GR, window: int = 4,
                       passes: int = 2,
                       table_refinements: int = 1) -> QuantizedTensor:
    """NN seed -> context statistics -> rate table -> RD assignment.

    ``table_refinements``: after each RD pass the context statistics (and
    hence the rate table) are re-estimated from the *assigned* levels —
    at large lambda the assignment shifts the level distribution far from
    the nearest-neighbour statistics the first table was built from, and
    a stale table can make the actual coded rate non-monotone in lambda
    (observed: +11 % bits at lambda=1e-2; one refinement removes it).
    """
    flat = np.asarray(w, dtype=np.float64).ravel()
    nn = nearest_level(flat, step)
    max_level = int(np.abs(nn).max()) + window + 1
    fl = None if importance is None else np.asarray(importance).ravel()
    levels = nn
    for _ in range(1 + max(table_refinements, 0)):
        table = build_rate_table(estimate_bin_probs(levels, num_gr),
                                 max_level)
        levels = rd_assign(flat, fl, step, lam, table, window=window,
                           max_level=max_level, passes=passes)
    return QuantizedTensor(levels=levels.reshape(np.asarray(w).shape),
                           step=step, dtype=str(np.asarray(w).dtype))


class CompressionResult(Artifact):
    """DC-v1/v2 result — the shared :class:`repro.compression.Artifact`
    under its historical name (blob + report + quantized entries)."""


def compress_dc_v2(params, delta: float, lam: float,
                   num_gr: int = B.DEFAULT_NUM_GR) -> CompressionResult:
    """One (Delta, lambda) point of DC-v2 (F_i = 1, global step)."""
    from ..compression import get
    art = get("deepcabac-v2", delta=delta, lam=lam, num_gr=num_gr,
              min_ndim=QUANT_MIN_NDIM).compress(params)
    return CompressionResult(
        blob=art.blob, report=art.report,
        hyperparams={"method": "dc-v2", "delta": delta, "lam": lam,
                     "codec": "deepcabac-v2"},
        quantized=art.quantized)


def compress_dc_v1(params, sigma, s: float, lam: float,
                   num_gr: int = B.DEFAULT_NUM_GR) -> CompressionResult:
    """One (S, lambda) point of DC-v1: per-layer Delta via eq. 12,
    F_i = 1/sigma_i^2."""
    from ..compression import (Codec, CabacCoder, RDGridQuantizer,
                               flatten_tree, ndim_float_policy)
    flat_sigma = flatten_tree(sigma)

    def step_for(name, w):
        return dc_v1_step_size(np.abs(w).max(),
                               float(np.min(flat_sigma[name])), s)

    importance = {k: 1.0 / (np.asarray(v) ** 2 + 1e-24)
                  for k, v in flat_sigma.items()}
    codec = Codec("deepcabac-v1",
                  coder=CabacCoder(num_gr=num_gr),
                  quantizer=RDGridQuantizer(lam=lam, num_gr=num_gr,
                                            step_for=step_for,
                                            importance=importance),
                  policy=ndim_float_policy(QUANT_MIN_NDIM))
    art = codec.compress(params)
    return CompressionResult(
        blob=art.blob, report=art.report,
        hyperparams={"method": "dc-v1", "S": s, "lam": lam,
                     "codec": "deepcabac-v1"},
        quantized=art.quantized)


# ---------------------------------------------------------------------------
# Grid-search drivers (paper Fig. 5 step 6 + appendix D/E)
# ---------------------------------------------------------------------------

def default_lambda_grid(num: int = 12) -> np.ndarray:
    """Log-spaced lambdas as in appendix D (coarsened for practicality)."""
    return 1e-4 * 2.0 ** (np.log2(1e2) * np.arange(num) / num)


def default_s_grid() -> list[float]:
    return [0.0, 8.0, 16.0, 32.0, 64.0, 96.0, 128.0, 160.0, 192.0, 256.0]


def screen_deltas_nn(params: dict[str, np.ndarray], eval_fn: Callable,
                     acc_floor: float, deltas: np.ndarray) -> np.ndarray:
    """DC-v2 round 1: nearest-neighbour (lambda = 0) screening to find the
    usable step-size range (paper §III-C-4)."""
    keep = []
    for d in deltas:
        entries = {}
        for name, w in params.items():
            w = np.asarray(w)
            if w.ndim < QUANT_MIN_NDIM:
                entries[name] = w
            else:
                lv = nearest_level(w.ravel(), d).reshape(w.shape)
                entries[name] = QuantizedTensor(lv, d, str(w.dtype))
        rec = {k: (v.dequantize() if isinstance(v, QuantizedTensor) else v)
               for k, v in entries.items()}
        if eval_fn(rec) >= acc_floor:
            keep.append(d)
    return np.asarray(keep if keep else [float(deltas[0])])


def search_dc_v2(params: dict[str, np.ndarray], eval_fn: Callable,
                 orig_metric: float, tol: float = 0.005,
                 deltas: np.ndarray | None = None,
                 lambdas: np.ndarray | None = None,
                 num_gr: int = B.DEFAULT_NUM_GR) -> CompressionResult:
    """Smallest blob whose eval metric stays within ``tol`` of the original.

    ``eval_fn(state_dict) -> metric`` (higher is better, e.g. accuracy).
    """
    if deltas is None:
        deltas = 0.001 * 2.0 ** (np.log2(0.15 / 0.001) * np.arange(12) / 12)
    if lambdas is None:
        lambdas = np.concatenate([[0.0], default_lambda_grid(6)])
    floor = orig_metric - tol
    usable = screen_deltas_nn(params, eval_fn, floor, deltas)
    best: CompressionResult | None = None
    # largest usable deltas compress most; search top few with all lambdas
    for d in sorted(usable.tolist(), reverse=True)[:4]:
        for lam in lambdas:
            res = compress_dc_v2(params, d, float(lam), num_gr)
            if eval_fn(res.reconstructed()) >= floor:
                if best is None or len(res.blob) < len(best.blob):
                    best = res
    if best is None:   # fall back to the finest screening point
        best = compress_dc_v2(params, float(np.min(deltas)), 0.0, num_gr)
    return best


def search_dc_v1(params: dict[str, np.ndarray], sigma: dict[str, np.ndarray],
                 eval_fn: Callable, orig_metric: float, tol: float = 0.005,
                 s_grid: list[float] | None = None,
                 lambdas: np.ndarray | None = None,
                 num_gr: int = B.DEFAULT_NUM_GR) -> CompressionResult:
    if s_grid is None:
        s_grid = default_s_grid()
    if lambdas is None:
        lambdas = np.concatenate([[0.0], default_lambda_grid(6)])
    floor = orig_metric - tol
    best: CompressionResult | None = None
    for s in s_grid:
        for lam in lambdas:
            res = compress_dc_v1(params, sigma, s, float(lam), num_gr)
            if eval_fn(res.reconstructed()) >= floor:
                if best is None or len(res.blob) < len(best.blob):
                    best = res
    if best is None:
        best = compress_dc_v1(params, sigma, s_grid[-1], 0.0, num_gr)
    return best
