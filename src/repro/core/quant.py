"""Quantizers: uniform (alg. 5), weighted Lloyd (alg. 4), RD assignment (eq. 11).

All operate on flat float arrays + optional per-parameter importance
(Fisher / 1/sigma^2) weights.  numpy implementations are the reference
oracles; ``kernels/rd_quant`` is the TPU Pallas version of :func:`rd_assign`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .rate_model import RateTable

# ---------------------------------------------------------------------------
# Equidistant-grid helpers (q_k = Delta * I_k, paper §III-C-1)
# ---------------------------------------------------------------------------

def nearest_level(w: np.ndarray, step: float,
                  max_level: int | None = None) -> np.ndarray:
    lv = np.rint(np.asarray(w, dtype=np.float64) / step).astype(np.int64)
    if max_level is not None:
        lv = np.clip(lv, -max_level, max_level)
    return lv


def dequantize(levels: np.ndarray, step: float) -> np.ndarray:
    return np.asarray(levels, dtype=np.float64) * step


# ---------------------------------------------------------------------------
# Uniform quantization (paper alg. 5 / §V "uniform")
# ---------------------------------------------------------------------------

def uniform_centers(w: np.ndarray, k: int) -> np.ndarray:
    """K centers uniformly spread over the value range, snapped so that an
    exact zero center exists (preserves sparsity of pruned models)."""
    lo, hi = float(np.min(w)), float(np.max(w))
    centers = np.linspace(lo, hi, k)
    centers[np.argmin(np.abs(centers))] = 0.0
    return centers


def assign_nearest(w: np.ndarray, centers: np.ndarray,
                   importance: np.ndarray | None = None,
                   chunk: int = 1 << 16) -> np.ndarray:
    """Nearest-centre assignment (importance does not change the argmin for
    a plain distance, it is accepted for API symmetry with Lloyd)."""
    w = np.asarray(w, dtype=np.float64).ravel()
    out = np.empty(w.shape, dtype=np.int64)
    for s in range(0, w.size, chunk):
        blk = w[s:s + chunk]
        out[s:s + chunk] = np.argmin(
            (blk[:, None] - centers[None, :]) ** 2, axis=1)
    return out


def uniform_quantize(w: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (assignments, centers)."""
    centers = uniform_centers(w, k)
    return assign_nearest(w, centers), centers


# ---------------------------------------------------------------------------
# Weighted Lloyd (paper alg. 4)
# ---------------------------------------------------------------------------

@dataclass
class LloydResult:
    assignments: np.ndarray
    centers: np.ndarray
    probs: np.ndarray
    objective: list[float] = field(default_factory=list)


def weighted_lloyd(w: np.ndarray, importance: np.ndarray | None, k: int,
                   lam: float, iters: int = 30, tol: float = 1e-7,
                   chunk: int = 1 << 15, ensure_zero: bool = True,
                   seed: int = 0) -> LloydResult:
    w = np.asarray(w, dtype=np.float64).ravel()
    n = w.size
    f = (np.ones(n) if importance is None
         else np.asarray(importance, dtype=np.float64).ravel())
    rng = np.random.default_rng(seed)
    # init: quantile-spread centers (robust to heavy tails), plus exact zero
    qs = np.linspace(0.0, 1.0, k)
    centers = np.quantile(w, qs) + rng.normal(0, 1e-12, k)
    if ensure_zero:
        centers[np.argmin(np.abs(centers))] = 0.0
    probs = np.full(k, 1.0 / k)
    assignments = np.zeros(n, dtype=np.int64)
    history: list[float] = []
    prev_obj = np.inf
    for _ in range(iters):
        rate_pen = -lam * np.log2(np.maximum(probs, 1e-12))
        obj = 0.0
        for s in range(0, n, chunk):
            blk_w = w[s:s + chunk]
            blk_f = f[s:s + chunk]
            cost = blk_f[:, None] * (blk_w[:, None] - centers[None, :]) ** 2 \
                + rate_pen[None, :]
            a = np.argmin(cost, axis=1)
            assignments[s:s + chunk] = a
            obj += float(cost[np.arange(a.size), a].sum())
        history.append(obj)
        # update step
        counts = np.bincount(assignments, minlength=k).astype(np.float64)
        fw = np.bincount(assignments, weights=f * w, minlength=k)
        fs = np.bincount(assignments, weights=f, minlength=k)
        nonempty = fs > 0
        centers = np.where(nonempty, fw / np.maximum(fs, 1e-30), centers)
        probs = np.maximum(counts, 1e-12) / n
        if ensure_zero:
            centers[np.argmin(counts)] = 0.0   # alg.4 lines 14–16
        if prev_obj - obj <= tol * max(abs(prev_obj), 1.0):
            break
        prev_obj = obj
    return LloydResult(assignments=assignments, centers=centers, probs=probs,
                       objective=history)


# ---------------------------------------------------------------------------
# RD assignment on the equidistant grid (paper eq. 11) — numpy oracle
# ---------------------------------------------------------------------------

def rd_assign(w: np.ndarray, importance: np.ndarray | None, step: float,
              lam: float, table: RateTable, window: int = 4,
              max_level: int | None = None, passes: int = 2) -> np.ndarray:
    """argmin_k F_i (w_i - Delta k)^2 + lam * L[prev_sig, k].

    Candidates are the nearest-neighbour level +- window PLUS level 0:
    at large lambda the optimum for big weights jumps straight to zero, far
    outside any local window — without the zero candidate the assignment
    saturates at the window edge and the rate-vs-lambda curve goes
    non-monotone (measured: window 4 needs 24.8 kbit where window 16 needs
    6.6 kbit at lambda=1e-3; the O(1) zero candidate recovers the effect).

    prev_sig (the significance of the previously *assigned* level) makes the
    exact problem sequential; we use the standard vectorized fixed-point
    iteration: seed prev_sig from the nearest-neighbour assignment, then
    re-derive it from each RD pass (``passes`` >= 1, 2 converges in practice).
    This is the oracle mirrored by kernels/rd_quant.
    """
    w = np.asarray(w, dtype=np.float64).ravel()
    n = w.size
    f = (np.ones(n) if importance is None
         else np.asarray(importance, dtype=np.float64).ravel())
    if max_level is None:
        max_level = table.max_level
    nn = nearest_level(w, step, max_level)
    offsets = np.arange(-window, window + 1)
    cand = np.clip(nn[:, None] + offsets[None, :], -max_level, max_level)
    cand = np.concatenate([cand, np.zeros((n, 1), dtype=cand.dtype)], axis=1)
    dist = f[:, None] * (w[:, None] - step * cand) ** 2

    levels = nn
    for _ in range(max(passes, 1)):
        sig = levels != 0
        prev_sig = np.concatenate([[False], sig[:-1]]).astype(np.int64)
        idx = cand + table.max_level
        rate = table.bits[prev_sig[:, None], idx]
        cost = dist + lam * rate
        levels = cand[np.arange(n), np.argmin(cost, axis=1)]
    return levels
