"""DeepCABAC binarization (paper §III-B, Figs. 6–7).

Each quantized integer weight ``v`` is coded as:

    sigFlag | signFlag | AbsGr(1..n)Flags | ExpGolomb(|v| - n)
                                            ^ unary part: context-coded
                                            ^ fixed-length part: bypass

* ``sigFlag``  — v != 0.  Context selected by the significance of the
  *previous* weight in scan order (2 contexts) → captures the local
  clustering of zeros that lets CABAC code below the i.i.d. entropy.
* ``signFlag`` — v < 0 (1 context).
* ``AbsGr(j)`` — |v| > j for j = 1..n, context per j, stop at first 0.
* Remainder i = |v| - n >= 1 coded Exp-Golomb style (paper footnote 4):
  k = floor(log2 i) coded unary (k ones + terminating zero, context per
  position), then the k low bits of i - 2^k as bypass bins.

Worked examples from the paper (n = 1):
    1  -> 1 0 0            (sig=1, sign=+, Gr1=0)
    -4 -> 1 1 1 1 0 1      (sig, sign=-, Gr1, EG: k=1 -> '10', r=1 -> '1')
    7  -> 1 0 1 1 1 0 1 0  (sig, sign=+, Gr1, EG: k=2 -> '110', r=2 -> '10')

These exact vectors are asserted in tests/test_binarization.py.
"""

from __future__ import annotations

import numpy as np

from .cabac import TEMPORAL_CLASSES, ContextSet, RangeDecoder, RangeEncoder

DEFAULT_NUM_GR = 10   # paper appendix: "we set the AbsGr(n)-Flag to 10"
EG_CTXS = 24          # unary exponent positions with dedicated contexts

# Context layout ------------------------------------------------------------
CTX_SIG0 = 0          # sigFlag, previous weight was zero
CTX_SIG1 = 1          # sigFlag, previous weight was significant
CTX_SIGN = 2
CTX_GR_BASE = 3       # CTX_GR_BASE + (j-1), j = 1..n


def ctx_eg_base(num_gr: int) -> int:
    return CTX_GR_BASE + num_gr


def num_contexts(num_gr: int = DEFAULT_NUM_GR) -> int:
    return CTX_GR_BASE + num_gr + EG_CTXS


def make_contexts(num_gr: int = DEFAULT_NUM_GR) -> ContextSet:
    return ContextSet(num_contexts(num_gr))


def num_contexts_tc(num_gr: int = DEFAULT_NUM_GR) -> int:
    """Context count of the temporal-context (delta) mode: one full intra
    bank per temporal significance class of the co-located base level."""
    return TEMPORAL_CLASSES * num_contexts(num_gr)


def make_contexts_tc(num_gr: int = DEFAULT_NUM_GR) -> ContextSet:
    return ContextSet(num_contexts_tc(num_gr))


# ---------------------------------------------------------------------------
# Stream coding of integer tensors
# ---------------------------------------------------------------------------

def encode_levels(enc: RangeEncoder, levels: np.ndarray,
                  num_gr: int = DEFAULT_NUM_GR) -> None:
    """Encode a flat int array in scan order with the DeepCABAC binarization."""
    eg_base = ctx_eg_base(num_gr)
    eg_last = eg_base + EG_CTXS - 1
    encode_bin = enc.encode_bin
    encode_bypass_bits = enc.encode_bypass_bits
    prev_sig = 0
    for v in levels.tolist():
        if v == 0:
            encode_bin(prev_sig, 0)   # ctx CTX_SIG0/CTX_SIG1 == prev_sig
            prev_sig = 0
            continue
        encode_bin(prev_sig, 1)
        prev_sig = 1
        encode_bin(CTX_SIGN, 1 if v < 0 else 0)
        a = -v if v < 0 else v
        j = 1
        while j <= num_gr:
            gr = 1 if a > j else 0
            encode_bin(CTX_GR_BASE + j - 1, gr)
            if not gr:
                break
            j += 1
        if a > num_gr:
            i = a - num_gr                       # >= 1
            k = i.bit_length() - 1               # floor(log2 i)
            for pos in range(k):
                c = eg_base + pos
                encode_bin(c if c <= eg_last else eg_last, 1)
            c = eg_base + k
            encode_bin(c if c <= eg_last else eg_last, 0)
            if k:
                encode_bypass_bits(i - (1 << k), k)


def decode_levels(dec: RangeDecoder, count: int,
                  num_gr: int = DEFAULT_NUM_GR) -> np.ndarray:
    """Decode ``count`` integers (mirror of :func:`encode_levels`)."""
    eg_base = ctx_eg_base(num_gr)
    eg_last = eg_base + EG_CTXS - 1
    decode_bin = dec.decode_bin
    decode_bypass_bits = dec.decode_bypass_bits
    out = np.empty(count, dtype=np.int64)
    prev_sig = 0
    for idx in range(count):
        if not decode_bin(prev_sig):
            out[idx] = 0
            prev_sig = 0
            continue
        prev_sig = 1
        neg = decode_bin(CTX_SIGN)
        a = 1
        j = 1
        while j <= num_gr:
            if decode_bin(CTX_GR_BASE + j - 1):
                a = j + 1
                j += 1
            else:
                a = j
                break
        else:
            # all num_gr flags were 1 -> remainder follows
            k = 0
            while True:
                c = eg_base + k
                if not decode_bin(c if c <= eg_last else eg_last):
                    break
                k += 1
            i = 1 << k
            if k:
                i += decode_bypass_bits(k)
            a = num_gr + i
        out[idx] = -a if neg else a
    return out


# ---------------------------------------------------------------------------
# Temporal-context ("P-frame") stream coding
# ---------------------------------------------------------------------------
#
# Delta residuals reuse the intra binarization verbatim, but every context
# index is offset into one of TEMPORAL_CLASSES banks selected by the class
# of the co-located base-frame level (cabac.temporal_classes).  Bypass bins
# stay bypass; the within-lane prev_sig conditioning of the sigFlag is kept
# inside each bank, so the mode strictly refines the intra model.

def encode_levels_tc(enc: RangeEncoder, levels: np.ndarray, cls: np.ndarray,
                     num_gr: int = DEFAULT_NUM_GR) -> None:
    """Encode a flat int array with per-value temporal-class context banks.

    ``cls[idx]`` in ``[0, TEMPORAL_CLASSES)`` selects the bank for value
    ``idx``; ``enc`` must have been built with :func:`make_contexts_tc`.
    """
    base_nctx = num_contexts(num_gr)
    eg_base = ctx_eg_base(num_gr)
    eg_last = eg_base + EG_CTXS - 1
    encode_bin = enc.encode_bin
    encode_bypass_bits = enc.encode_bypass_bits
    cls_list = np.asarray(cls, dtype=np.int64).tolist()
    prev_sig = 0
    for idx, v in enumerate(levels.tolist()):
        off = cls_list[idx] * base_nctx
        if v == 0:
            encode_bin(off + prev_sig, 0)
            prev_sig = 0
            continue
        encode_bin(off + prev_sig, 1)
        prev_sig = 1
        encode_bin(off + CTX_SIGN, 1 if v < 0 else 0)
        a = -v if v < 0 else v
        j = 1
        while j <= num_gr:
            gr = 1 if a > j else 0
            encode_bin(off + CTX_GR_BASE + j - 1, gr)
            if not gr:
                break
            j += 1
        if a > num_gr:
            i = a - num_gr
            k = i.bit_length() - 1
            for pos in range(k):
                c = eg_base + pos
                encode_bin(off + (c if c <= eg_last else eg_last), 1)
            c = eg_base + k
            encode_bin(off + (c if c <= eg_last else eg_last), 0)
            if k:
                encode_bypass_bits(i - (1 << k), k)


def decode_levels_tc(dec: RangeDecoder, cls: np.ndarray,
                     num_gr: int = DEFAULT_NUM_GR) -> np.ndarray:
    """Decode ``len(cls)`` integers (mirror of :func:`encode_levels_tc`)."""
    base_nctx = num_contexts(num_gr)
    eg_base = ctx_eg_base(num_gr)
    eg_last = eg_base + EG_CTXS - 1
    decode_bin = dec.decode_bin
    decode_bypass_bits = dec.decode_bypass_bits
    cls_list = np.asarray(cls, dtype=np.int64).tolist()
    count = len(cls_list)
    out = np.empty(count, dtype=np.int64)
    prev_sig = 0
    for idx in range(count):
        off = cls_list[idx] * base_nctx
        if not decode_bin(off + prev_sig):
            out[idx] = 0
            prev_sig = 0
            continue
        prev_sig = 1
        neg = decode_bin(off + CTX_SIGN)
        a = 1
        j = 1
        while j <= num_gr:
            if decode_bin(off + CTX_GR_BASE + j - 1):
                a = j + 1
                j += 1
            else:
                a = j
                break
        else:
            k = 0
            while True:
                c = eg_base + k
                if not decode_bin(off + (c if c <= eg_last else eg_last)):
                    break
                k += 1
            i = 1 << k
            if k:
                i += decode_bypass_bits(k)
            a = num_gr + i
        out[idx] = -a if neg else a
    return out


# ---------------------------------------------------------------------------
# Vectorized bin expansion (for the rate model & analysis — no coder state)
# ---------------------------------------------------------------------------

def binarize_value(v: int, num_gr: int = DEFAULT_NUM_GR,
                   prev_sig: int = 0) -> list[tuple[int, int]]:
    """Return the (ctx, bit) sequence for one value. ctx == -1 -> bypass."""
    eg_base = ctx_eg_base(num_gr)
    eg_last = eg_base + EG_CTXS - 1
    if v == 0:
        return [(prev_sig, 0)]
    bins = [(prev_sig, 1), (CTX_SIGN, 1 if v < 0 else 0)]
    a = abs(v)
    for j in range(1, num_gr + 1):
        gr = 1 if a > j else 0
        bins.append((CTX_GR_BASE + j - 1, gr))
        if not gr:
            return bins
    i = a - num_gr
    k = i.bit_length() - 1
    for pos in range(k):
        bins.append((min(eg_base + pos, eg_last), 1))
    bins.append((min(eg_base + k, eg_last), 0))
    r = i - (1 << k)
    for shift in range(k - 1, -1, -1):
        bins.append((-1, (r >> shift) & 1))
    return bins


def expand_bins(levels: np.ndarray, num_gr: int = DEFAULT_NUM_GR
                ) -> tuple[np.ndarray, np.ndarray]:
    """(bits, ctx_ids) for a whole scan — used by the exact rate accountant."""
    bits: list[int] = []
    ctxs: list[int] = []
    prev_sig = 0
    for v in levels.tolist():
        for c, b in binarize_value(int(v), num_gr, prev_sig):
            ctxs.append(c)
            bits.append(b)
        prev_sig = 0 if v == 0 else 1
    return np.asarray(bits, dtype=np.int8), np.asarray(ctxs, dtype=np.int32)


def expand_bins_tc(levels: np.ndarray, cls: np.ndarray,
                   num_gr: int = DEFAULT_NUM_GR
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(bits, ctx_ids) with temporal-class bank offsets applied to every
    context-coded bin (bypass bins keep ctx == -1).  Drives the lockstep
    numpy lane encoder of the delta mode."""
    base_nctx = num_contexts(num_gr)
    bits: list[int] = []
    ctxs: list[int] = []
    cls_list = np.asarray(cls, dtype=np.int64).tolist()
    prev_sig = 0
    for idx, v in enumerate(levels.tolist()):
        off = cls_list[idx] * base_nctx
        for c, b in binarize_value(int(v), num_gr, prev_sig):
            ctxs.append(c if c < 0 else c + off)
            bits.append(b)
        prev_sig = 0 if v == 0 else 1
    return np.asarray(bits, dtype=np.int8), np.asarray(ctxs, dtype=np.int32)
