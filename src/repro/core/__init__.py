# DeepCABAC core: CABAC engine, binarization, rate model, quantizers,
# DC-v1/DC-v2 pipelines, baselines, container/codec.
from .cabac import ContextSet, RangeDecoder, RangeEncoder  # noqa: F401
from .codec import (QuantizedTensor, decode_state_dict,  # noqa: F401
                    encode_state_dict)
from .deepcabac import (CompressionResult, compress_dc_v1,  # noqa: F401
                        compress_dc_v2, quantize_tensor_rd, search_dc_v1,
                        search_dc_v2)
from .quant import (nearest_level, rd_assign, uniform_quantize,  # noqa: F401
                    weighted_lloyd)
