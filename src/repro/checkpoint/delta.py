"""Temporal delta-coded ("P-frame") checkpoint steps.

A delta step stores checkpoint step N+1 as integer-level *residuals*
against the quantized levels of a base step N — the video-codec I/P-frame
idea applied to training checkpoints:

* the new frame is quantized on the **base tensor's grid** (step
  locking), so ``resid = new_levels - base_levels`` lives entirely in
  integer quantization-level space and base + a chain of residuals
  reconstructs each frame **bit-identically** to its direct (monolithic)
  encoding — zero drift at any chain depth;
* residuals are entropy-coded with **temporal-context CABAC**
  (``ENC_CABAC_DELTA``, container v4): each element's context bank is
  selected by the significance class of its co-located base-frame level
  (zero / small / large — ``repro.core.cabac.temporal_classes``);
* the chain linkage lives in a **version-2 dcbc-manifest**: a delta
  step's ``params.manifest.json`` carries a top-level ``"base"`` block
  naming the base step directory, its payload file and that file's
  SHA-256, so :func:`resolve_chain` can walk P-frames back to the
  keyframe and detect a missing or substituted base *before* decoding.

Directory layout (inside a ``CheckpointManager`` root)::

    step_00000010/params.manifest.json   v1 manifest  (keyframe, sharded)
                  shard_00000.dcbc ...
    step_00000011/params.manifest.json   v2 manifest, "base": step 10
                  delta_00000.dcbc       v4 container (ENC_CABAC_DELTA)
    step_00000012/params.manifest.json   v2 manifest, "base": step 11
                  delta_00000.dcbc

Keyframes may equally be monolithic (``params.dcbc``); the base
reference then pins that blob's hash.  Restore always resolves the whole
chain: :func:`restore_flat_delta` reconstructs full host arrays,
:func:`restore_on_mesh_delta` re-places them as mesh-sharded
``jax.Array``\\ s on any target mesh (the save/restore meshes need not
match — residuals are host-reconstructed against full base levels, then
elastically placed).

Keyframe cadence and chain-aware retention are the
``CheckpointManager``'s job (``CheckpointConfig.delta_every``); see
docs/compression_api.md ("Delta checkpoints & P-frame containers").
"""

from __future__ import annotations

import hashlib
import os
import re
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..core import binarization as B
from ..core.codec import (DEFAULT_CHUNK, DecodeOptions, DeltaTensor,
                          QuantizedTensor, decode_delta_record, decode_record,
                          decode_state_dict_batched,
                          encode_delta_chunks_batched,
                          encode_level_chunks_batched)
from ..core.container import ENC_CABAC_DELTA, ContainerReader, ContainerWriter
from ..distributed.sharding import logical_axes_for_path, spec_for
from .sharded import (MANIFEST_FORMAT, MANIFEST_NAME, MANIFEST_VERSION_DELTA,
                      load_manifest, restore_flat, verify_files)

DELTA_FILE = "delta_00000.dcbc"
PARAMS_FILE = "params.dcbc"            # monolithic keyframe payload
DEFAULT_MAX_DEPTH = 64

_STEP_RE = re.compile(r"^step_(\d+)$")


class DeltaBaseMissingError(FileNotFoundError):
    """A delta step's base frame is gone from disk — most likely retained
    away (``CheckpointConfig.keep``) by a manager that did not know about
    the chain, or deleted by hand.  The chain is unrecoverable."""


class DeltaChainError(ValueError):
    """The delta chain is structurally invalid: a base hash mismatch
    (substituted/rewritten base), a cycle, or a depth past ``max_depth``."""


# ---------------------------------------------------------------------------
# Step-directory naming
# ---------------------------------------------------------------------------

def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _root_and_step(directory: str, step: int | None) -> tuple[str, int]:
    """Accept either ``(checkpoint_root, step)`` or a step directory with
    ``step=None`` (the error-message-friendly spelling)."""
    if step is not None:
        return str(directory), int(step)
    base = os.path.basename(os.path.normpath(str(directory)))
    m = _STEP_RE.match(base)
    if not m:
        raise ValueError(
            f"{directory}: pass (checkpoint_root, step) or a "
            f"step_NNNNNNNN directory")
    return os.path.dirname(os.path.normpath(str(directory))), int(m.group(1))


def _payload_name(d: str) -> str:
    """The file a base reference pins: the manifest for sharded/delta
    steps, the monolithic container otherwise."""
    if os.path.exists(os.path.join(d, MANIFEST_NAME)):
        return MANIFEST_NAME
    return PARAMS_FILE


# Per-process memo of verified file hashes.  Zoo admission resolves K
# variant chains over the same keyframe; without this every resolve
# re-reads and re-hashes the (large) base payload.  Keyed by file
# *identity* — (device, inode, size, mtime_ns) — so hardlinked views of
# one content-addressed object share an entry, while a rewritten base
# (new inode, or same inode with changed size/mtime) misses the cache
# and is re-hashed, preserving the substituted-base detection in
# :func:`resolve_chain`.
_HASH_CACHE: dict[tuple[int, int, int, int], str] = {}
_HASH_STATS = {"hits": 0, "misses": 0}


def hash_cache_stats() -> dict:
    """Copy of the per-process sha256 memo counters (tests/benches)."""
    return dict(_HASH_STATS)


def clear_hash_cache() -> None:
    _HASH_CACHE.clear()
    _HASH_STATS["hits"] = 0
    _HASH_STATS["misses"] = 0


def _sha256_file(path: str) -> str:
    st = os.stat(path)
    key = (st.st_dev, st.st_ino, st.st_size, st.st_mtime_ns)
    cached = _HASH_CACHE.get(key)
    if cached is not None:
        _HASH_STATS["hits"] += 1
        return cached
    _HASH_STATS["misses"] += 1
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    digest = h.hexdigest()
    _HASH_CACHE[key] = digest
    return digest


def base_ref(root: str, step: int) -> dict:
    """Build the ``"base"`` block a delta manifest carries: the base step
    number, its directory name, which file inside it is the pinned
    payload, and that file's SHA-256 (for sharded/delta bases this is the
    manifest, whose own ``files`` hashes transitively pin every shard)."""
    d = step_dir(root, step)
    name = _payload_name(d)
    path = os.path.join(d, name)
    if not os.path.exists(path):
        raise DeltaBaseMissingError(
            f"cannot reference step {step} as a delta base: "
            f"{path} does not exist")
    return {"step": int(step),
            "dir": os.path.basename(d),
            "manifest": name,
            "sha256": _sha256_file(path)}


# ---------------------------------------------------------------------------
# Write: delta entries -> v4 container + v2 manifest
# ---------------------------------------------------------------------------

def write_delta(dentries: dict, *, codec_name: str, base: dict,
                num_gr: int = B.DEFAULT_NUM_GR,
                chunk_size: int = DEFAULT_CHUNK,
                encode_backend: str = "auto",
                workers: int = 0) -> tuple[dict[str, bytes], dict]:
    """Build a delta step's payload set from ``DeltaCodec.delta_entries``
    output (flat name -> ``DeltaTensor`` | ``QuantizedTensor`` | ndarray).

    Residual entries become ``ENC_CABAC_DELTA`` records (temporal-context
    CABAC, container v4); tensors without a compatible base are full
    intra ``cabac_v3`` records; the rest are raw.  Returns ``(payloads,
    manifest)`` exactly like ``sharded.write_sharded`` — payloads is
    ``{DELTA_FILE: blob}`` and the manifest is a version-2 dcbc-manifest
    whose ``"base"`` block is the caller-provided :func:`base_ref`.
    ``workers`` > 1 runs the per-tensor entropy encodes on a thread pool.
    """
    items = list(dentries.items())

    def encode(item):
        name, e = item
        if isinstance(e, DeltaTensor):
            return encode_delta_chunks_batched(
                e.resid, e.base, num_gr, chunk_size, backend=encode_backend)
        if isinstance(e, QuantizedTensor):
            return encode_level_chunks_batched(
                e.levels, num_gr, chunk_size, backend=encode_backend)
        return None

    if workers > 1 and len(items) > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            encoded = list(ex.map(encode, items))
    else:
        encoded = [encode(i) for i in items]

    writer = ContainerWriter()
    tensors: dict = {}
    for (name, e), enc in zip(items, encoded):
        if isinstance(e, DeltaTensor):
            chunks, counts = enc
            writer.add_cabac_delta(name, e.dtype, e.shape, e.step,
                                   num_gr, chunk_size, chunks, counts)
            encoding = "cabac_delta"
            shape, dtype, step = e.shape, e.dtype, float(e.step)
        elif isinstance(e, QuantizedTensor):
            chunks, counts = enc
            writer.add_cabac_v3(name, e.dtype, e.shape, e.step,
                                num_gr, chunk_size, chunks, counts)
            encoding = "cabac_v3"
            shape, dtype, step = e.shape, e.dtype, float(e.step)
        elif isinstance(e, np.ndarray):
            writer.add_raw(name, e)
            encoding = "raw"
            shape, dtype, step, counts = tuple(e.shape), str(e.dtype), None, None
        else:                                   # Q8Tensor
            writer.add_q8(name, e.dtype, e.levels, e.scale)
            encoding = "q8"
            shape, dtype, step, counts = e.shape, e.dtype, None, None
        tinfo = {
            "shape": list(shape),
            "dtype": dtype,
            "encoding": encoding,
            "spec": [[] for _ in shape],
            "grid": [1] * len(shape),
            "shards": [],
        }
        if step is not None:
            tinfo["step"] = step
        tensors[name] = (tinfo, counts)

    blob = writer.tobytes()
    for ((name, _e), _enc), (off, length) in zip(
            zip(items, encoded), writer.record_spans()):
        tinfo, counts = tensors[name]
        shape = tinfo["shape"]
        shard = {"index": [0] * len(shape), "start": [0] * len(shape),
                 "stop": list(shape), "file": DELTA_FILE, "record": name,
                 "offset": off, "length": length}
        if counts is not None:
            shard["chunk_counts"] = [int(c) for c in counts]
        tinfo["shards"].append(shard)

    manifest = {
        "format": MANIFEST_FORMAT,
        "manifest_version": MANIFEST_VERSION_DELTA,
        "codec": codec_name,
        "mesh": {"axes": ["data"], "shape": [1]},
        "num_gr": int(num_gr),
        "chunk_size": int(chunk_size),
        "base": dict(base),
        "tensors": {name: tinfo for name, (tinfo, _c) in tensors.items()},
        "files": {DELTA_FILE: {"bytes": len(blob),
                               "sha256": hashlib.sha256(blob).hexdigest()}},
    }
    return {DELTA_FILE: blob}, manifest


# ---------------------------------------------------------------------------
# Chain resolution
# ---------------------------------------------------------------------------

def _manifest_or_none(d: str) -> dict | None:
    if os.path.exists(os.path.join(d, MANIFEST_NAME)):
        return load_manifest(d)
    return None


def base_step_of(directory: str, step: int | None = None) -> int | None:
    """The step a delta step chains to, or ``None`` for a keyframe."""
    root, step = _root_and_step(directory, step)
    manifest = _manifest_or_none(step_dir(root, step))
    if manifest is None or manifest.get("base") is None:
        return None
    return int(manifest["base"]["step"])


def resolve_chain(directory: str, step: int | None = None,
                  max_depth: int = DEFAULT_MAX_DEPTH) -> list[dict]:
    """Walk a step's base chain back to its keyframe, validating every
    link, and return it **base-first**: a list of
    ``{"step", "dir", "kind" ("keyframe"|"delta"), "manifest" (or None)}``.

    Raises :class:`DeltaBaseMissingError` when a referenced base step (or
    its pinned payload file) is gone — the descriptive version of the
    bare ``FileNotFoundError`` a naive restore would hit — and
    :class:`DeltaChainError` on a base-hash mismatch, a chain longer than
    ``max_depth`` links, or a cycle."""
    root, step = _root_and_step(directory, step)
    chain: list[dict] = []
    seen: set[int] = set()
    cur: int | None = step
    expect: dict | None = None          # the base block that led us here
    while True:
        d = step_dir(root, cur)
        if not os.path.isdir(d):
            raise DeltaBaseMissingError(
                f"delta chain for step {step} is broken: base step {cur} "
                f"({d}) does not exist — it was likely removed by "
                f"retention that predates chain-aware GC, or deleted by "
                f"hand; the P-frames above it cannot be reconstructed")
        name = _payload_name(d)
        path = os.path.join(d, name)
        if not os.path.exists(path):
            raise DeltaBaseMissingError(
                f"delta chain for step {step} is broken: step {cur} has "
                f"no payload ({path} missing)")
        if expect is not None:
            digest = _sha256_file(path)
            if digest != expect.get("sha256"):
                raise DeltaChainError(
                    f"delta chain for step {step}: step {cur}'s {name} "
                    f"hash {digest[:12]}... does not match the "
                    f"{expect['sha256'][:12]}... its dependent P-frame "
                    f"pinned — the base was rewritten after the delta "
                    f"was saved")
        if cur in seen:
            raise DeltaChainError(
                f"delta chain for step {step} revisits step {cur} — "
                f"cyclic base references")
        seen.add(cur)
        manifest = _manifest_or_none(d)
        base = manifest.get("base") if manifest else None
        chain.append({"step": cur, "dir": d,
                      "kind": "delta" if base is not None else "keyframe",
                      "manifest": manifest})
        if base is None:
            break
        if len(chain) > max_depth:
            raise DeltaChainError(
                f"delta chain for step {step} exceeds max_depth="
                f"{max_depth} P-frames without reaching a keyframe")
        expect = base
        cur = int(base["step"])
    chain.reverse()
    return chain


def chain_files(directory: str, step: int | None = None,
                max_depth: int = DEFAULT_MAX_DEPTH) -> list[dict]:
    """Per-link payload inventory of a step's base chain, base-first.

    Each entry extends :func:`resolve_chain`'s link dict with a
    ``"files"`` map: every file the link's step directory contributes —
    manifest ``files`` entries (shards or the delta container) with
    their recorded bytes/sha256, plus the manifest itself (hashed here)
    or, for monolithic keyframes, the bare ``params.dcbc``.  This is the
    unit a content-addressed store ingests: the sha256 values are the
    object keys, so two variants chaining to one keyframe list identical
    hashes for the shared shard files."""
    chain = resolve_chain(directory, step, max_depth=max_depth)
    out = []
    for link in chain:
        d = link["dir"]
        manifest = link["manifest"]
        files: dict[str, dict] = {}
        if manifest is not None:
            for fname, info in manifest.get("files", {}).items():
                files[fname] = {"bytes": int(info["bytes"]),
                                "sha256": str(info["sha256"])}
            mpath = os.path.join(d, MANIFEST_NAME)
            files[MANIFEST_NAME] = {"bytes": os.path.getsize(mpath),
                                    "sha256": _sha256_file(mpath)}
        else:
            ppath = os.path.join(d, PARAMS_FILE)
            files[PARAMS_FILE] = {"bytes": os.path.getsize(ppath),
                                  "sha256": _sha256_file(ppath)}
        out.append({**link, "files": files})
    return out


# ---------------------------------------------------------------------------
# Restore: chain -> levels -> arrays / mesh-sharded jax Arrays
# ---------------------------------------------------------------------------

def _apply_delta_file(entries: dict, d: str, opts: DecodeOptions | None,
                      step: int) -> dict:
    """Decode one delta step's container on top of ``entries`` (the
    reconstructed previous frame, quantized): residual records patch the
    co-named base entry, full records replace it."""
    path = os.path.join(d, DELTA_FILE)
    if not os.path.exists(path):
        raise DeltaBaseMissingError(
            f"delta step {step}: {path} missing (manifest present but "
            f"payload gone — partial delete?)")
    with open(path, "rb") as f:
        blob = f.read()
    for hdr, payload in ContainerReader(blob):
        if hdr.encoding == ENC_CABAC_DELTA:
            base = entries.get(hdr.name)
            if not isinstance(base, QuantizedTensor):
                raise DeltaChainError(
                    f"delta step {step}: record {hdr.name!r} is a "
                    f"residual but the reconstructed base frame has no "
                    f"quantized tensor of that name")
            entries[hdr.name] = decode_delta_record(
                hdr, payload, base.levels, dequantize=False, opts=opts)
        else:
            entries[hdr.name] = decode_record(hdr, payload,
                                              dequantize=False, opts=opts)
    return entries


def restore_levels(directory: str, step: int | None = None, *,
                   opts: DecodeOptions | None = None,
                   max_depth: int = DEFAULT_MAX_DEPTH,
                   workers: int = 0, verify: bool = False) -> dict:
    """Reconstruct a (possibly delta) step's flat quantized entries —
    name -> ``QuantizedTensor`` | ``Q8Tensor`` | raw ndarray — by
    resolving the chain, decoding the keyframe, and applying each
    P-frame's residuals in order.  Bit-identical to decoding a direct
    (monolithic) encode of the same step-locked frame."""
    root, step = _root_and_step(directory, step)
    chain = resolve_chain(root, step, max_depth=max_depth)
    key = chain[0]
    if key["manifest"] is not None:
        if verify:
            verify_files(key["dir"], key["manifest"])
        entries = restore_flat(key["dir"], opts=opts, dequantize=False,
                               workers=workers)
    else:
        with open(os.path.join(key["dir"], PARAMS_FILE), "rb") as f:
            entries = decode_state_dict_batched(f.read(), dequantize=False,
                                                opts=opts)
    for link in chain[1:]:
        if verify:
            verify_files(link["dir"], link["manifest"])
        entries = _apply_delta_file(entries, link["dir"], opts, link["step"])
    return entries


def _dequantized(entries: dict) -> dict:
    return {name: (e if isinstance(e, np.ndarray) else e.dequantize())
            for name, e in entries.items()}


def restore_flat_delta(directory: str, step: int | None = None, *,
                       opts: DecodeOptions | None = None,
                       max_depth: int = DEFAULT_MAX_DEPTH,
                       workers: int = 0, verify: bool = False) -> dict:
    """Full host-side restore of a delta step: resolve the chain and
    return dequantized ``{name: ndarray}`` — the delta-aware counterpart
    of ``sharded.restore_flat``.  Works on keyframes too."""
    return _dequantized(restore_levels(directory, step, opts=opts,
                                       max_depth=max_depth, workers=workers,
                                       verify=verify))


def restore_on_mesh_delta(directory: str, step: int | None, mesh, *,
                          rules=None, opts: DecodeOptions | None = None,
                          max_depth: int = DEFAULT_MAX_DEPTH,
                          workers: int = 0, verify: bool = False) -> dict:
    """Restore a delta step as mesh-sharded ``jax.Array``\\ s on any
    target mesh (elastic: the mesh need not match any save mesh in the
    chain).  Residual reconstruction is inherently full-frame — every
    P-frame element needs its co-located base level — so tensors are
    chain-reconstructed on the host, then placed with the target mesh's
    NamedShardings (the same rule table the training shardings use)."""
    import jax
    from jax.sharding import NamedSharding

    flat = restore_flat_delta(directory, step, opts=opts,
                              max_depth=max_depth, workers=workers,
                              verify=verify)
    out: dict = {}
    for name, arr in flat.items():
        spec = spec_for(arr.shape, logical_axes_for_path(name, arr.ndim),
                        mesh, rules)
        out[name] = jax.device_put(arr, NamedSharding(mesh, spec))
    return out
