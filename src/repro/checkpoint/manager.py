"""Fault-tolerant checkpointing with DeepCABAC-compressed parameters.

Responsibilities:
* atomic writes (tmp dir + fsync + rename) — a crash mid-save never corrupts
  the latest checkpoint;
* retention (keep last N);
* compression of the weight payload through the ``repro.compression``
  Codec registry (default ``ckpt-nearest``: per-tensor step size
  Delta = delta_rel * std(w); quantization is deterministic, so resumed
  runs are bit-reproducible given the same stream);
* elastic restore: arrays are saved unsharded and re-placed with the target
  mesh's NamedShardings, so the mesh shape may change between save and
  restore (scale up/down);
* async save: the host-side quantize+encode runs on a worker thread
  over a snapshot while the device keeps training (compute/IO overlap).

Sharded checkpoints (``CheckpointConfig.sharded=True``): instead of one
monolithic ``params.dcbc``, the save writes one DCBC container file per
owning device of the save mesh — tensor shards assigned by the
``distributed.sharding`` PartitionSpecs — plus ``params.manifest.json``
recording global shapes, the codec, every shard's (file, byte-range,
chunk counts) and per-file content hashes.  Restore is manifest-driven
and *elastic*: pass a different target ``mesh`` and only the shard files
(and v3 chunk ranges within them) covering each local device's slice are
read and lane-decoded, then assembled into mesh-sharded ``jax.Array``\\ s
— no full-model materialization on any host.  See
``repro.checkpoint.sharded`` and docs/compression_api.md ("Sharded
checkpoints").

Delta ("P-frame") checkpoints (``CheckpointConfig.delta_every=K`` with a
delta-capable codec, e.g. ``codec="deepcabac-delta"``): every K-th save
is a full keyframe (I-frame, honoring ``sharded``); the saves between
are P-frames — integer-level residuals against the previous save,
temporal-context CABAC coded into one container-v4 ``delta_00000.dcbc``
plus a version-2 manifest whose ``"base"`` block names (and SHA-256
pins) the base step.  Chained reconstruction is bit-identical to a
direct encode of the same step-locked frame, and retention never GCs a
base still referenced by a retained step's chain.  ``restore`` resolves
chains transparently (``repro.checkpoint.delta``); see
docs/compression_api.md ("Delta checkpoints & P-frame containers") and
docs/serving_api.md ("Live weight swap") for the serving-side consumer.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from dataclasses import dataclass

import jax
import numpy as np

from ..compression import decompress
from ..compression.tree import flatten_tree, unflatten_like  # noqa: F401
# flatten_tree/unflatten_like re-exported: they moved to compression.tree
# but this module remains their historical import path.
from . import delta as delta_mod
from . import sharded


@dataclass
class CheckpointConfig:
    directory: str
    keep: int = 3
    params_mode: str = "cabac"     # legacy alias: cabac | raw
    codec: str | None = None       # compression-registry name; overrides
                                   # params_mode when set (e.g. "serve-q8")
    delta_rel: float = 1e-3        # Delta = delta_rel * std(w)
    min_quant_ndim: int = 2        # 1-D tensors stored raw (paper protocol)
    async_save: bool = False
    sharded: bool = False          # per-shard container files + manifest
    shard_workers: int = 0         # thread pool for per-shard encode /
                                   # per-slice decode (0 = inline)
    delta_every: int = 0           # 0 = every save is a keyframe; K >= 1 =
                                   # I-frame every K saves, P-frames between
                                   # (needs a delta-capable codec, e.g.
                                   # "deepcabac-delta")
    policy_table: object | None = None  # TensorPolicy / dict / JSON path for
                                   # per-tensor mixed precision (pairs with
                                   # codec="deepcabac-rd"; see
                                   # compression.rd_search)


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._worker: threading.Thread | None = None
        # (step, quantized entries) of the last save — the next P-frame's
        # base without a disk round-trip; rebuilt via the chain on miss.
        # Populated only when delta_every > 0 (it holds model-sized
        # int64 levels).
        self._base_cache: tuple[int, dict] | None = None

    # -- discovery ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------
    def _codec(self):
        """Resolve the params codec from cfg (registry name or legacy
        params_mode alias).  This is a generic-config-at-any-codec
        forwarder, so it uses ``get(..., strict=False)``: delta_rel /
        min_quant_ndim / policy_table reach any codec whose factory
        accepts them; the rest drop them with the drop recorded in the
        codec's hyperparams (and hence in the checkpoint metadata)."""
        from ..compression import get
        name = self.cfg.codec
        if name is None:
            name = "ckpt-nearest" if self.cfg.params_mode == "cabac" else "raw"
        overrides = {"delta_rel": self.cfg.delta_rel,
                     "min_ndim": self.cfg.min_quant_ndim}
        if self.cfg.policy_table is not None:
            overrides["policy_table"] = self.cfg.policy_table
        return get(name, strict=False, **overrides)

    def _write(self, payloads: dict[str, bytes], meta: dict, step: int):
        final = os.path.join(self.cfg.directory, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp.",
                               dir=self.cfg.directory)
        try:
            for fname, blob in payloads.items():
                path = os.path.join(tmp, fname)
                with open(path, "wb") as f:
                    f.write(blob)
                    f.flush()
                    os.fsync(f.fileno())
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._retain()

    def _chain_depth(self, step: int) -> int:
        """P-frames above the keyframe at ``step`` (0 for a keyframe) —
        from meta.json when available, else by resolving the chain."""
        meta_path = os.path.join(self.cfg.directory, f"step_{step:08d}",
                                 "meta.json")
        try:
            with open(meta_path) as f:
                depth = json.load(f).get("chain_depth")
            if depth is not None:
                return int(depth)
        except (OSError, ValueError):
            pass
        return len(delta_mod.resolve_chain(self.cfg.directory, step)) - 1

    def _delta_base(self) -> int | None:
        """The step the next save should delta against, or None when a
        keyframe is due (no previous step, chain at the delta_every
        cadence, or an unreadable/broken chain — start fresh)."""
        latest = self.latest_step()
        if latest is None:
            return None
        try:
            depth = self._chain_depth(latest)
        except (OSError, ValueError):
            return None
        if depth + 1 >= self.cfg.delta_every:
            return None
        return latest

    def _base_entries(self, base_step: int) -> dict:
        """Quantized entries of the base frame: the last save's, cached
        in memory, or chain-reconstructed from disk on a cache miss (e.g.
        a manager restarted mid-chain)."""
        if self._base_cache is not None and self._base_cache[0] == base_step:
            return self._base_cache[1]
        return delta_mod.restore_levels(self.cfg.directory, base_step)

    def _base_step_of(self, step: int) -> int | None:
        """The step ``step`` chains to (delta manifests name it), or None
        for keyframes / unreadable steps."""
        try:
            return delta_mod.base_step_of(self.cfg.directory, step)
        except (OSError, ValueError):
            return None

    def _retain(self):
        """Keep the last ``keep`` steps plus the transitive closure of
        their base chains — a base referenced by a live P-frame chain is
        never GC'd, no matter how old it is."""
        steps = self.steps()
        live = set(steps[-self.cfg.keep:]) if self.cfg.keep else set(steps)
        frontier = list(live)
        while frontier:
            base = self._base_step_of(frontier.pop())
            if base is not None and base not in live:
                live.add(base)
                frontier.append(base)
        for s in steps:
            if s not in live:
                shutil.rmtree(os.path.join(self.cfg.directory,
                                           f"step_{s:08d}"),
                              ignore_errors=True)

    def save(self, state, step: int, extra_meta: dict | None = None,
             blocking: bool | None = None, mesh=None):
        """Snapshot to host, then encode+write (optionally off-thread).

        With ``cfg.sharded``, ``mesh`` (a jax Mesh, ``sharded.MeshSpec``
        or axis-size dict) is the save mesh whose PartitionSpecs assign
        tensor shards to per-device container files; omitting it writes a
        single-device (one-file) sharded checkpoint."""
        snapshot = jax.device_get(state)
        blocking = (not self.cfg.async_save) if blocking is None else blocking
        codec = self._codec()
        if self.cfg.delta_every > 0 and not hasattr(codec, "compress_delta"):
            raise ValueError(
                f"delta_every={self.cfg.delta_every} needs a delta-capable "
                f"codec (e.g. codec='deepcabac-delta'), got {codec.name!r}")

        def work():
            flat_p = flatten_tree(snapshot["params"])
            rest = {k: v for k, v in snapshot.items() if k != "params"}
            other = flatten_tree(rest)
            buf = {}
            import io
            bio = io.BytesIO()
            np.savez(bio, **other)
            buf["state.npz"] = bio.getvalue()
            meta_extra = {}
            base_step = self._delta_base() if self.cfg.delta_every > 0 \
                else None
            if base_step is not None:
                coder = codec.coder
                base_entries = self._base_entries(base_step)
                dentries = codec.delta_entries(flat_p, base_entries)
                payloads, manifest = delta_mod.write_delta(
                    dentries, codec_name=codec.name,
                    base=delta_mod.base_ref(self.cfg.directory, base_step),
                    num_gr=coder.num_gr, chunk_size=coder.chunk_size,
                    workers=self.cfg.shard_workers)
                buf.update(payloads)
                buf[sharded.MANIFEST_NAME] = json.dumps(
                    manifest, indent=1).encode()
                compressed = sum(len(b) for b in payloads.values())
                self._base_cache = (step,
                                    codec.reconstruct_entries(dentries))
                meta_extra = {"kind": "delta", "base_step": base_step,
                              "chain_depth":
                                  self._chain_depth(base_step) + 1}
            elif self.cfg.sharded:
                kw = {}
                coder = getattr(codec, "coder", None)
                for attr in ("num_gr", "chunk_size"):
                    if coder is not None and hasattr(coder, attr):
                        kw[attr] = getattr(coder, attr)
                entries = codec.quantize_entries(flat_p)
                payloads, manifest = sharded.write_sharded(
                    entries, mesh, codec_name=codec.name,
                    workers=self.cfg.shard_workers, **kw)
                buf.update(payloads)
                buf[sharded.MANIFEST_NAME] = json.dumps(
                    manifest, indent=1).encode()
                compressed = sum(len(b) for b in payloads.values())
                meta_extra = {"sharded": True,
                              "shard_files": len(payloads),
                              "save_mesh": manifest["mesh"]}
                if self.cfg.delta_every > 0:
                    self._base_cache = (step, entries)
                    meta_extra = {**meta_extra, "kind": "keyframe",
                                  "chain_depth": 0}
            else:
                artifact = codec.compress(flat_p)
                buf["params.dcbc"] = artifact.blob
                compressed = len(buf["params.dcbc"])
                if self.cfg.delta_every > 0:
                    self._base_cache = (step, artifact.quantized)
                    meta_extra = {"kind": "keyframe", "chain_depth": 0}
            raw_bytes = sum(v.nbytes for v in flat_p.values())
            # record only what was actually used: a config knob the chosen
            # codec ignores (delta_rel, or params_mode once codec= is set)
            # must not be recorded as if it shaped the payload
            meta = {"step": step, "codec": codec.name,
                    "codec_hyperparams": codec.hyperparams,
                    "params_raw_bytes": raw_bytes,
                    "params_compressed_bytes": compressed,
                    **meta_extra, **(extra_meta or {})}
            if self.cfg.codec is None:
                meta["params_mode"] = self.cfg.params_mode
            if "delta_rel" in codec.hyperparams:
                meta["delta_rel"] = codec.hyperparams["delta_rel"]
            self._write(buf, meta, step)

        if blocking:
            work()
        else:
            self.wait()
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    # -- restore --------------------------------------------------------------
    def restore(self, template_state, step: int | None = None,
                shardings=None, mesh=None):
        """Rebuild ``template_state``'s pytree from disk.  ``shardings`` (a
        matching pytree of NamedSharding) enables elastic re-placement on a
        different mesh than the one that saved.

        Cold-start decode is batched: every CABAC chunk in the params
        container joins one lane-parallel decode batch
        (``repro.core.cabac_vec``) instead of the serial per-chunk loop —
        restore is a whole-model load, so model-bound decoded memory is
        already implied.

        Sharded checkpoints restore manifest-driven: with ``mesh`` (a jax
        Mesh — any shape, not necessarily the save mesh) each parameter
        comes back as a mesh-sharded ``jax.Array`` assembled from only the
        shard files / chunk ranges its local slices need; without ``mesh``
        tensors are assembled whole on the host.  ``shardings`` then only
        re-places the non-param state."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints found")
        d = os.path.join(self.cfg.directory, f"step_{step:08d}")
        manifest_path = os.path.join(d, sharded.MANIFEST_NAME)
        is_sharded = os.path.exists(manifest_path)
        if mesh is not None and not is_sharded:
            raise ValueError(
                f"restore(mesh=...) needs a sharded checkpoint, but "
                f"step {step} has no {sharded.MANIFEST_NAME} (monolithic "
                f"save) — pass shardings= to re-place a monolithic "
                f"restore instead")
        if is_sharded:
            is_delta = sharded.load_manifest(d).get("base") is not None
            if is_delta:
                # chained (P-frame) step: resolve base chain + apply
                # residuals, then place elastically if a mesh was given
                if mesh is not None:
                    flat = delta_mod.restore_on_mesh_delta(
                        self.cfg.directory, step, mesh,
                        workers=self.cfg.shard_workers)
                else:
                    flat = delta_mod.restore_flat_delta(
                        self.cfg.directory, step,
                        workers=self.cfg.shard_workers)
            elif mesh is not None:
                flat = sharded.restore_on_mesh(
                    d, mesh, workers=self.cfg.shard_workers)
            else:
                flat = sharded.restore_flat(
                    d, workers=self.cfg.shard_workers)
            params = unflatten_like(flat, template_state["params"])
        else:
            with open(os.path.join(d, "params.dcbc"), "rb") as f:
                params = decompress(f.read(), like=template_state["params"],
                                    batched=True)
        with open(os.path.join(d, "state.npz"), "rb") as f:
            other = dict(np.load(f, allow_pickle=False))
        rest_t = {k: v for k, v in template_state.items() if k != "params"}
        rest = unflatten_like(other, rest_t)
        state = {"params": params, **rest}
        if shardings is not None:
            if is_sharded and mesh is not None:
                # params already live on the target mesh; re-place only
                # the rest of the state
                keys = [k for k in state if k != "params"]
                moved = jax.tree.map(
                    jax.device_put, {k: state[k] for k in keys},
                    {k: shardings[k] for k in keys})
                state = {**state, **moved}
            else:
                state = jax.tree.map(jax.device_put, state, shardings)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return state, meta
