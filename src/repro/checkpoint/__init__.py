from .manager import (CheckpointConfig, CheckpointManager,  # noqa: F401
                      flatten_tree, unflatten_like)
