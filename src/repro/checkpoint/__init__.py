from .delta import (DELTA_FILE, DeltaBaseMissingError,  # noqa: F401
                    DeltaChainError, base_ref, base_step_of, resolve_chain,
                    restore_flat_delta, restore_levels, restore_on_mesh_delta,
                    write_delta)
from .manager import (CheckpointConfig, CheckpointManager,  # noqa: F401
                      flatten_tree, unflatten_like)
from .sharded import (MANIFEST_NAME, MeshSpec, RestoreStats,  # noqa: F401
                      assemble_slice, load_manifest, restore_flat,
                      restore_local_slices, restore_on_mesh, verify_files,
                      write_sharded)
