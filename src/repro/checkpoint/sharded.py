"""Sharded DCBC checkpoints: per-shard container files + a JSON manifest.

The monolithic checkpoint path serializes full arrays from one process.
This module is the multi-host-shaped format: parameters are split into
tensor shards along their :mod:`repro.distributed.sharding`
PartitionSpecs, each (owner device, tensor-shard) becomes one record in
that owner's own DCBC container file, and a JSON manifest records
everything a restore needs to be *elastic*:

* the global shape / dtype / codec of every tensor,
* per shard: grid index, global [start, stop) box, owning file, the
  record's (byte offset, length) within that file (so restore preads one
  record instead of mapping the file — ``core.container.read_record_at``),
  and the per-chunk value counts of the v3 CABAC record,
* per file: size + SHA-256 content hash.

Restore is manifest-driven: given a *different* target mesh, the reader
computes which saved shards — and which v3 chunk ranges *within* them,
via the per-chunk value counts — cover each target slice, entropy-decodes
only those chunks through the lane-parallel batched decoder
(``core.codec.decode_level_chunks_batched`` / ``DecodeOptions``) on a
thread pool, and assembles ``jax.make_array_from_single_device_arrays``
outputs.  No host ever materializes the full model.

Quantization happens on the *full* tensor before sharding (the step size
is a global per-tensor quantity), so an N-shard save restored on any mesh
is bit-identical to the monolithic path.

Manifest schema and shard-file layout: docs/compression_api.md
("Sharded checkpoints").
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from ..core import binarization as B
from ..core.codec import (DEFAULT_CHUNK, DecodeOptions, QuantizedTensor,
                          decode_level_chunks_batched, decode_record,
                          encode_level_chunks_batched, resolve_dtype)
from ..core.container import ContainerWriter, read_record_at
from ..distributed.sharding import logical_axes_for_path, spec_for

MANIFEST_NAME = "params.manifest.json"
MANIFEST_FORMAT = "dcbc-manifest"
MANIFEST_VERSION = 1
# Manifest version 2 adds codec chaining: a "base" block naming the frame
# a delta step applies to (repro.checkpoint.delta).  Plain sharded saves
# keep writing version 1; readers here accept both but refuse to restore
# a chained manifest without its chain (see _reject_delta).
MANIFEST_VERSION_DELTA = 2
MANIFEST_MAX_VERSION = MANIFEST_VERSION_DELTA


# ---------------------------------------------------------------------------
# Mesh description (no devices required)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshSpec:
    """A mesh's *shape* — axis names and sizes, no device objects.

    Shard-grid math only needs sizes, so saves (and restore planning) run
    on hosts that cannot see the training fleet's devices; anything with
    a ``.shape`` mapping (``jax.sharding.Mesh``, test FakeMesh) converts
    via :meth:`from_any`.
    """

    axis_names: tuple
    axis_sizes: tuple

    @property
    def shape(self) -> dict:
        return dict(zip(self.axis_names, self.axis_sizes))

    @property
    def size(self) -> int:
        return int(np.prod(self.axis_sizes)) if self.axis_sizes else 1

    @classmethod
    def from_any(cls, mesh) -> "MeshSpec":
        if isinstance(mesh, MeshSpec):
            return mesh
        if mesh is None:
            return cls(("data",), (1,))
        shape = mesh.shape if hasattr(mesh, "shape") else mesh
        return cls(tuple(shape.keys()),
                   tuple(int(v) for v in shape.values()))


def _axes_of(entry) -> tuple:
    """PartitionSpec entry -> tuple of mesh axis names."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def _spec_axes(spec, ndim: int) -> list[tuple]:
    axes = [_axes_of(e) for e in spec]
    return axes + [()] * (ndim - len(axes))


def shard_grid(spec_axes: list[tuple], mesh: MeshSpec) -> tuple[int, ...]:
    """Shard counts per dim: the product of the dim's mesh-axis sizes."""
    shape = mesh.shape
    return tuple(int(np.prod([shape.get(a, 1) for a in axes]))
                 if axes else 1 for axes in spec_axes)


def shard_box(shape, grid, index) -> tuple[tuple, tuple]:
    """Global [start, stop) box of shard ``index`` on the shard grid."""
    starts, stops = [], []
    for dim, n, i in zip(shape, grid, index):
        if dim % n:
            raise ValueError(
                f"dim {dim} not divisible by shard count {n} "
                f"(specs are resolved with divisibility fallback, so this "
                f"indicates a manifest/mesh mismatch)")
        sz = dim // n
        starts.append(i * sz)
        stops.append((i + 1) * sz)
    return tuple(starts), tuple(stops)


def _dim_shard_index(coords: dict, axes: tuple, mesh: MeshSpec) -> int:
    """Compose one dim's shard index from mesh coords (first axis major,
    matching jax PartitionSpec semantics for tuple entries)."""
    idx = 0
    for a in axes:
        idx = idx * mesh.shape.get(a, 1) + coords.get(a, 0)
    return idx


def _owner_device(spec_axes: list[tuple], mesh: MeshSpec, index) -> int:
    """Flat index (C order over mesh axes) of the first device owning the
    shard — the replica at coordinate 0 of every unmentioned axis.  This
    is the device whose file the shard is written to, deduplicating
    replicated shards."""
    coords = {a: 0 for a in mesh.axis_names}
    for axes, idx in zip(spec_axes, index):
        rem = int(idx)
        for pos in range(len(axes) - 1, -1, -1):
            a = axes[pos]
            size = mesh.shape.get(a, 1)
            coords[a] = rem % size
            rem //= size
    flat = 0
    for a in mesh.axis_names:
        flat = flat * mesh.shape[a] + coords[a]
    return flat


def device_coords(flat: int, mesh: MeshSpec) -> dict:
    coords = {}
    for a in reversed(mesh.axis_names):
        coords[a] = flat % mesh.shape[a]
        flat //= mesh.shape[a]
    return coords


def device_box(shape, spec_axes: list[tuple], mesh: MeshSpec,
               flat_device: int) -> tuple[tuple, tuple]:
    """The [start, stop) box of ``shape`` that ``flat_device`` holds under
    the given spec — restore planning for one device of a target mesh."""
    coords = device_coords(flat_device, mesh)
    grid = shard_grid(spec_axes, mesh)
    index = tuple(_dim_shard_index(coords, axes, mesh)
                  for axes in spec_axes)
    return shard_box(shape, grid, index)


def spec_axes_for(name: str, shape, mesh: MeshSpec,
                  rules=None) -> list[tuple]:
    """Resolve a tensor's per-dim mesh axes from the shared rule table —
    the same ``logical_axes_for_path`` + ``spec_for`` path the training
    shardings use, so save and restore can never disagree on geometry."""
    spec = spec_for(shape, logical_axes_for_path(name, len(shape)),
                    mesh, rules)
    return _spec_axes(spec, len(shape))


# ---------------------------------------------------------------------------
# Save: entries -> per-shard container files + manifest
# ---------------------------------------------------------------------------

def write_sharded(entries: dict, mesh, *, codec_name: str, rules=None,
                  num_gr: int = B.DEFAULT_NUM_GR,
                  chunk_size: int = DEFAULT_CHUNK,
                  encode_backend: str = "auto",
                  workers: int = 0) -> tuple[dict[str, bytes], dict]:
    """Build the sharded payload set from quantized entries.

    ``entries`` is the ``Codec.quantize_entries`` output — flat name ->
    ``QuantizedTensor`` | ``Q8Tensor`` | raw ndarray.  Quantized (scalar
    step) tensors are sharded along their resolved PartitionSpecs and each
    shard encoded as one v3 CABAC record in its owner device's container
    file; raw and per-channel-int8 entries are written as a single shard
    in device 0's file (they are small or carry per-channel scales that
    do not slice along the grid).

    Returns ``(payloads, manifest)``: payloads maps file name -> bytes
    (one ``shard_NNNNN.dcbc`` per owning device plus nothing else — the
    caller persists the manifest itself), ready for an atomic
    tmp-dir+rename write.  ``workers`` > 1 runs the per-shard entropy
    encodes on a thread pool (the C lane kernel releases the GIL).
    """
    mesh = MeshSpec.from_any(mesh)
    jobs = []          # (name, entry, index, starts, stops, owner, record)
    tensors: dict = {}
    for name, entry in entries.items():
        if isinstance(entry, QuantizedTensor):
            shape = entry.shape
            axes = spec_axes_for(name, shape, mesh, rules)
            grid = shard_grid(axes, mesh)
            encoding = "cabac_v3"
        else:
            arr = entry if isinstance(entry, np.ndarray) else entry.levels
            shape = tuple(arr.shape)
            axes = [()] * len(shape)
            grid = (1,) * len(shape)
            encoding = "raw" if isinstance(entry, np.ndarray) else "q8"
        tensors[name] = {
            "shape": list(shape),
            "dtype": (str(entry.dtype) if isinstance(entry, np.ndarray)
                      else entry.dtype),
            "encoding": encoding,
            "spec": [list(a) for a in axes],
            "grid": list(grid),
            "shards": [],
        }
        if encoding == "cabac_v3":
            tensors[name]["step"] = float(entry.step)
        for index in np.ndindex(*grid) if grid else [()]:
            starts, stops = shard_box(shape, grid, index)
            owner = _owner_device(axes, mesh, index)
            record = (name if all(g == 1 for g in grid)
                      else f"{name}#{'.'.join(map(str, index))}")
            jobs.append((name, entry, tuple(index), starts, stops,
                         owner, record))

    def encode(job):
        name, entry, index, starts, stops, owner, record = job
        if not isinstance(entry, QuantizedTensor):
            return job, None
        box = tuple(slice(a, b) for a, b in zip(starts, stops))
        chunks, counts = encode_level_chunks_batched(
            entry.levels[box], num_gr, chunk_size, backend=encode_backend)
        return job, (chunks, counts)

    if workers > 1 and len(jobs) > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            encoded = list(ex.map(encode, jobs))
    else:
        encoded = [encode(j) for j in jobs]

    # Group records by owner in deterministic (owner, add) order.
    by_owner: dict[int, list] = {}
    for job, enc in encoded:
        by_owner.setdefault(job[5], []).append((job, enc))

    payloads: dict[str, bytes] = {}
    for owner in sorted(by_owner):
        fname = f"shard_{owner:05d}.dcbc"
        writer = ContainerWriter()
        placed = []
        for (name, entry, index, starts, stops, _o, record), enc \
                in by_owner[owner]:
            if isinstance(entry, QuantizedTensor):
                chunks, counts = enc
                shard_shape = tuple(b - a for a, b in zip(starts, stops))
                writer.add_cabac_v3(record, entry.dtype, shard_shape,
                                    entry.step, num_gr, chunk_size,
                                    chunks, counts)
                placed.append((name, index, starts, stops, record, counts))
            elif isinstance(entry, np.ndarray):
                writer.add_raw(record, entry)
                placed.append((name, index, starts, stops, record, None))
            else:                                   # Q8Tensor
                writer.add_q8(record, entry.dtype, entry.levels, entry.scale)
                placed.append((name, index, starts, stops, record, None))
        blob = writer.tobytes()
        for (name, index, starts, stops, record, counts), (off, length) \
                in zip(placed, writer.record_spans()):
            shard = {"index": list(index), "start": list(starts),
                     "stop": list(stops), "file": fname, "record": record,
                     "offset": off, "length": length}
            if counts is not None:
                shard["chunk_counts"] = [int(c) for c in counts]
            tensors[name]["shards"].append(shard)
        payloads[fname] = blob

    manifest = {
        "format": MANIFEST_FORMAT,
        "manifest_version": MANIFEST_VERSION,
        "codec": codec_name,
        "mesh": {"axes": list(mesh.axis_names),
                 "shape": [int(s) for s in mesh.axis_sizes]},
        "num_gr": int(num_gr),
        "chunk_size": int(chunk_size),
        "tensors": tensors,
        "files": {fname: {"bytes": len(blob),
                          "sha256": hashlib.sha256(blob).hexdigest()}
                  for fname, blob in payloads.items()},
    }
    return payloads, manifest


# ---------------------------------------------------------------------------
# Restore: manifest -> slices / full tensors / mesh-sharded jax Arrays
# ---------------------------------------------------------------------------

class RestoreStats:
    """What a manifest-driven restore actually touched — the honesty
    counter behind 'a sub-mesh restore decodes strictly fewer bytes'."""

    def __init__(self):
        self._lock = threading.Lock()
        self.decoded_values = 0     # entropy-decoded quantized values
        self.read_bytes = 0         # shard-file bytes pread
        self.records_read = 0

    def add(self, values: int = 0, read: int = 0, records: int = 0):
        with self._lock:
            self.decoded_values += int(values)
            self.read_bytes += int(read)
            self.records_read += int(records)

    def as_dict(self) -> dict:
        return {"decoded_values": self.decoded_values,
                "read_bytes": self.read_bytes,
                "records_read": self.records_read}


def load_manifest(directory: str) -> dict:
    path = (directory if str(directory).endswith(".json")
            else os.path.join(directory, MANIFEST_NAME))
    with open(path) as f:
        manifest = json.load(f)
    if manifest.get("format") != MANIFEST_FORMAT:
        raise ValueError(f"{path}: not a {MANIFEST_FORMAT} manifest")
    if manifest.get("manifest_version", 0) > MANIFEST_MAX_VERSION:
        raise ValueError(
            f"{path}: manifest version {manifest['manifest_version']} "
            f"(this reader handles <= {MANIFEST_MAX_VERSION})")
    return manifest


def _reject_delta(manifest: dict, directory: str, caller: str) -> None:
    """Chained (delta) manifests cannot be restored standalone — their
    records are residuals against the base frame the manifest names."""
    if manifest.get("base") is not None:
        raise ValueError(
            f"{directory}: this manifest is a delta (P-frame) step chained "
            f"to base step {manifest['base'].get('step')!r}; {caller} "
            f"cannot restore it standalone — use "
            f"repro.checkpoint.delta.restore_flat_delta / "
            f"restore_on_mesh_delta, which resolve the chain")


def manifest_dir(directory: str) -> str:
    return (os.path.dirname(str(directory))
            if str(directory).endswith(".json") else str(directory))


def verify_files(directory: str, manifest: dict) -> None:
    """Full-file SHA-256 check against the manifest (reads every byte —
    integrity tooling, not the restore hot path)."""
    for fname, info in manifest["files"].items():
        path = os.path.join(directory, fname)
        h = hashlib.sha256()
        with open(path, "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                h.update(block)
        if h.hexdigest() != info["sha256"]:
            raise ValueError(
                f"shard file {fname} content hash mismatch "
                f"(expected {info['sha256'][:12]}..., "
                f"got {h.hexdigest()[:12]}...) — corrupt or partial write")


def _read_span(directory: str, shard: dict, stats: RestoreStats | None):
    """pread one shard record via its manifest byte-range (no whole-file
    read) and parse it with ``read_record_at``."""
    path = os.path.join(directory, shard["file"])
    with open(path, "rb") as f:
        f.seek(shard["offset"])
        buf = f.read(shard["length"])
    if len(buf) < shard["length"]:
        raise ValueError(
            f"truncated shard file {shard['file']}: record "
            f"{shard['record']!r} at offset {shard['offset']} wants "
            f"{shard['length']} bytes, file provides {len(buf)}")
    if stats is not None:
        stats.add(read=len(buf), records=1)
    return read_record_at(buf)


def _intersect(a_start, a_stop, b_start, b_stop):
    starts = tuple(max(a, b) for a, b in zip(a_start, b_start))
    stops = tuple(min(a, b) for a, b in zip(a_stop, b_stop))
    if any(b <= a for a, b in zip(starts, stops)):
        return None
    return starts, stops


def _decode_shard_box(directory, tinfo, shard, starts, stops,
                      opts, num_gr, stats) -> np.ndarray:
    """Decode the [starts, stops) sub-box of one saved shard, entropy-
    decoding only the v3 chunk range that covers it."""
    hdr, payload = _read_span(directory, shard, stats)
    shard_shape = tuple(b - a for a, b in zip(shard["start"], shard["stop"]))
    rel_start = tuple(a - b for a, b in zip(starts, shard["start"]))
    rel_stop = tuple(a - b for a, b in zip(stops, shard["start"]))
    counts = np.asarray(shard.get("chunk_counts") or hdr.chunk_counts,
                        dtype=np.int64)
    ends = np.cumsum(counts)
    chunk_starts = ends - counts
    if shard_shape:
        lo = int(np.ravel_multi_index(rel_start, shard_shape))
        hi = int(np.ravel_multi_index(
            tuple(s - 1 for s in rel_stop), shard_shape)) + 1
    else:
        lo, hi = 0, 1
    c0 = int(np.searchsorted(ends, lo, side="right"))
    c1 = int(np.searchsorted(chunk_starts, hi, side="left"))
    # materialize only the selected chunk range's bytes (not the record)
    lens = np.asarray(hdr.chunk_lens, dtype=np.int64)
    byte_ends = np.cumsum(lens)
    byte_starts = byte_ends - lens
    chunks = [bytes(payload[byte_starts[k]:byte_ends[k]])
              for k in range(c0, c1)]
    span = decode_level_chunks_batched(
        chunks, counts[c0:c1].tolist(), num_gr or hdr.num_gr, opts)
    if stats is not None:
        stats.add(values=int(counts[c0:c1].sum()))
    if not shard_shape:
        return span.reshape(())
    base = int(chunk_starts[c0]) if c1 > c0 else 0
    idx = np.ravel_multi_index(
        np.ix_(*[np.arange(a, b) for a, b in zip(rel_start, rel_stop)]),
        shard_shape)
    return span[idx - base]


def assemble_slice(directory: str, name: str, tinfo: dict,
                   start=None, stop=None, *, opts: DecodeOptions | None = None,
                   num_gr: int | None = None, dequantize: bool = True,
                   stats: RestoreStats | None = None):
    """Assemble one tensor's global [start, stop) box from its covering
    shards, decoding only the chunk ranges the box needs."""
    shape = tuple(tinfo["shape"])
    start = tuple(start) if start is not None else (0,) * len(shape)
    stop = tuple(stop) if stop is not None else shape
    box_shape = tuple(b - a for a, b in zip(start, stop))
    encoding = tinfo["encoding"]

    if encoding != "cabac_v3":
        # raw / q8 entries are single-shard by construction: decode the
        # record, then slice (q8 per-channel scales don't slice on the
        # level grid, so partial boxes require dequantization)
        shard = tinfo["shards"][0]
        hdr, payload = _read_span(directory, shard, stats)
        full = start == (0,) * len(shape) and stop == shape
        if full:
            return decode_record(hdr, payload, dequantize=dequantize,
                                 opts=opts)
        if encoding == "q8" and not dequantize:
            raise ValueError(
                f"{name}: partial restore of 'q8' records requires "
                f"dequantize=True (per-channel scales don't slice)")
        rec = decode_record(hdr, payload, dequantize=True, opts=opts)
        return rec[tuple(slice(a, b) for a, b in zip(start, stop))]

    out = np.empty(box_shape, dtype=np.int64)
    filled = 0
    for shard in tinfo["shards"]:
        inter = _intersect(start, stop, shard["start"], shard["stop"])
        if inter is None:
            continue
        istart, istop = inter
        levels = _decode_shard_box(directory, tinfo, shard, istart, istop,
                                   opts, num_gr, stats)
        dest = tuple(slice(a - s, b - s)
                     for a, b, s in zip(istart, istop, start))
        out[dest] = levels
        filled += levels.size
    if filled != out.size:
        raise ValueError(
            f"{name}: shards cover {filled} of {out.size} elements of "
            f"box {start}..{stop} — manifest does not tile the tensor")
    qt = QuantizedTensor(out, float(tinfo["step"]), tinfo["dtype"])
    return qt.dequantize() if dequantize else qt


def _pool_map(fn, jobs, workers: int):
    if workers > 1 and len(jobs) > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            return list(ex.map(fn, jobs))
    return [fn(j) for j in jobs]


def restore_flat(directory: str, *, opts: DecodeOptions | None = None,
                 dequantize: bool = True, workers: int = 0,
                 stats: RestoreStats | None = None, verify: bool = False
                 ) -> dict:
    """Full host-side restore: every tensor assembled whole (single-host
    deployments / template-driven checkpoint loads)."""
    directory = manifest_dir(directory)
    manifest = load_manifest(directory)
    _reject_delta(manifest, directory, "restore_flat")
    if verify:
        verify_files(directory, manifest)
    items = sorted(manifest["tensors"].items())

    def job(item):
        name, tinfo = item
        return name, assemble_slice(
            directory, name, tinfo, opts=opts,
            num_gr=manifest.get("num_gr"), dequantize=dequantize,
            stats=stats)
    return dict(_pool_map(job, items, workers))


def restore_tensor_on_mesh(directory: str, name: str, tinfo: dict, mesh,
                           *, rules=None, opts: DecodeOptions | None = None,
                           num_gr: int | None = None, dtype=None,
                           workers: int = 0,
                           stats: RestoreStats | None = None):
    """Restore one tensor as a mesh-sharded ``jax.Array``.

    The target PartitionSpec is re-resolved against ``mesh`` (any shape —
    not necessarily the save mesh); each addressable device's slice is
    assembled from only the saved shards (and v3 chunk ranges) that cover
    it, decoded once per unique slice, placed per device and stitched
    with ``jax.make_array_from_single_device_arrays``.  No full-tensor
    host materialization happens for sharded tensors."""
    import jax
    from jax.sharding import NamedSharding

    shape = tuple(tinfo["shape"])
    spec = spec_for(shape, logical_axes_for_path(name, len(shape)),
                    mesh, rules)
    sharding = NamedSharding(mesh, spec)
    idx_map = sharding.addressable_devices_indices_map(shape)
    boxes: dict[tuple, list] = {}        # unique box -> devices
    for dev, idxs in idx_map.items():
        box = tuple((sl.start or 0, sl.stop if sl.stop is not None else dim)
                    for sl, dim in zip(idxs, shape))
        boxes.setdefault(box, []).append(dev)

    def decode(box):
        arr = assemble_slice(
            directory, name, tinfo, [b[0] for b in box], [b[1] for b in box],
            opts=opts, num_gr=num_gr, dequantize=True, stats=stats)
        arr = np.asarray(arr)
        return box, arr.astype(dtype) if dtype is not None else arr

    decoded = dict(_pool_map(decode, list(boxes), workers))
    arrays = [jax.device_put(decoded[box], dev)
              for box, devs in boxes.items() for dev in devs]
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def restore_on_mesh(directory: str, mesh, *, rules=None,
                    opts: DecodeOptions | None = None, workers: int = 0,
                    stats: RestoreStats | None = None,
                    verify: bool = False) -> dict:
    """Elastic restore of every manifest tensor onto a (possibly
    different) target jax mesh — see :func:`restore_tensor_on_mesh`.
    ``workers`` > 1 decodes tensors' slices on a thread pool."""
    directory = manifest_dir(directory)
    manifest = load_manifest(directory)
    _reject_delta(manifest, directory, "restore_on_mesh")
    if verify:
        verify_files(directory, manifest)
    num_gr = manifest.get("num_gr")

    def job(item):
        name, tinfo = item
        return name, restore_tensor_on_mesh(
            directory, name, tinfo, mesh, rules=rules, opts=opts,
            num_gr=num_gr, stats=stats)
    return dict(_pool_map(job, sorted(manifest["tensors"].items()), workers))


def restore_local_slices(directory: str, mesh, local_devices,
                         *, rules=None, opts: DecodeOptions | None = None,
                         workers: int = 0,
                         stats: RestoreStats | None = None) -> dict:
    """Decode only the slices a subset of target-mesh devices owns — what
    one host of a multi-host deployment (or a sub-mesh serving fleet)
    pays at cold start.  ``mesh`` may be a :class:`MeshSpec`; no jax
    devices are touched.  Returns ``{name: {flat_device: ndarray}}``."""
    mesh = MeshSpec.from_any(mesh)
    directory = manifest_dir(directory)
    manifest = load_manifest(directory)
    _reject_delta(manifest, directory, "restore_local_slices")
    num_gr = manifest.get("num_gr")
    jobs = []
    devs_by_box: dict[tuple, list] = {}
    for name, tinfo in sorted(manifest["tensors"].items()):
        shape = tuple(tinfo["shape"])
        axes = spec_axes_for(name, shape, mesh, rules)
        for dev in local_devices:
            starts, stops = device_box(shape, axes, mesh, dev)
            key = (name, starts, stops)
            if key not in devs_by_box:      # replicated slice: decode once
                jobs.append((name, tinfo, starts, stops))
            devs_by_box.setdefault(key, []).append(dev)

    def decode(job):
        name, tinfo, starts, stops = job
        return (name, starts, stops), assemble_slice(
            directory, name, tinfo, starts, stops, opts=opts,
            num_gr=num_gr, dequantize=True, stats=stats)

    out: dict = {}
    for key, arr in _pool_map(decode, jobs, workers):
        for dev in devs_by_box[key]:        # every device gets its slice
            out.setdefault(key[0], {})[dev] = arr
    return out


def manifest_total_values(manifest: dict) -> int:
    """Entropy-coded values across every cabac shard (monolithic-restore
    decode cost, for sub-mesh comparisons)."""
    total = 0
    for tinfo in manifest["tensors"].values():
        for shard in tinfo["shards"]:
            total += int(sum(shard.get("chunk_counts") or []))
    return total


def manifest_files(manifest: dict) -> dict[str, dict]:
    """The manifest's payload-file inventory: file name ->
    ``{"bytes", "sha256"}``.  These hashes are content-address keys — a
    dedup store ingests exactly this set (plus the manifest itself)."""
    return {fname: {"bytes": int(info["bytes"]),
                    "sha256": str(info["sha256"])}
            for fname, info in manifest.get("files", {}).items()}


def manifest_payload_bytes(manifest: dict) -> int:
    """Total on-disk payload bytes the manifest pins (shards or delta
    container; the manifest's own JSON is not counted)."""
    return sum(f["bytes"] for f in manifest_files(manifest).values())
