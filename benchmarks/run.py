"""Benchmark harness — one function per paper table + perf benches.

Prints ``name,us_per_call,derived`` CSV rows.  Perf numbers measured on the
host CPU (the CABAC codec is host-side by design; kernel perf on TPU is
covered by the §Roofline dry-run analysis, not wall-clock here).

Run: PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _row(name: str, us: float, derived: dict):
    print(f"{name},{us:.2f},{json.dumps(derived, default=float)}",
          flush=True)


def bench_table1(fast: bool):
    from .paper_tables import table1
    from .tasks import flat_weights, sparsify_mlp, train_mlp, train_small_lm

    t0 = time.time()
    mlp = train_mlp(steps=200 if fast else 400)
    fixtures = {}

    def mlp_acc(flat):
        return mlp.accuracy({k: np.asarray(v) for k, v in flat.items()})

    fixtures["mlp-dense"] = (flat_weights(mlp.params), None, mlp_acc,
                             mlp.params)
    sp = sparsify_mlp(mlp, steps=250 if fast else 600)
    spw = flat_weights(sp.params)
    nz = np.mean([np.mean(v != 0) for v in spw.values() if v.ndim >= 2])
    fixtures["mlp-sparse"] = (spw, flat_weights(sp.sigma), mlp_acc,
                              sp.params)

    lm = train_small_lm(steps=60 if fast else 150)
    from .tasks import rebuild

    def lm_acc(flat):
        return lm.accuracy(rebuild(lm.params, flat))

    fixtures["small-lm"] = (flat_weights(lm.params), None, lm_acc, lm.params)

    rows = table1(fixtures)
    for r in rows:
        _row(f"table1/{r['model']}", 1e6 * (time.time() - t0), r)
    _row("table1/sparsity", 0.0, {"mlp_sparse_nonzero_frac": float(nz)})
    return fixtures


def bench_table2(fixtures, fast: bool):
    from .paper_tables import table2
    flat, sigma, _, _ = fixtures["mlp-sparse"]
    t0 = time.time()
    rows = table2(flat, sigma)
    for r in rows:
        _row(f"table2/step={r['step']:.4g}", 1e6 * (time.time() - t0), r)


def bench_table3(fixtures, fast: bool):
    from .paper_tables import table3
    for model in ["mlp-dense", "mlp-sparse"]:
        flat = fixtures[model][0]
        t0 = time.time()
        rows = table3(flat)
        for r in rows:
            _row(f"table3/{model}/{r['quantizer']}",
                 1e6 * (time.time() - t0), r)


def bench_fig8(fixtures, fast: bool):
    from .paper_tables import fig8_rate_accuracy
    flat, _, acc_fn, _ = fixtures["mlp-dense"]
    t0 = time.time()
    rows = fig8_rate_accuracy(flat, acc_fn)
    _row("fig8/rate_accuracy", 1e6 * (time.time() - t0), {"points": rows})


def bench_codec_throughput(fast: bool):
    from repro.core import binarization as B
    from repro.core.cabac import RangeDecoder, RangeEncoder
    rng = np.random.default_rng(0)
    n = 100_000 if fast else 400_000
    levels = (rng.standard_t(2, n) * 2).astype(np.int64)
    t0 = time.time()
    enc = RangeEncoder(B.make_contexts())
    B.encode_levels(enc, levels)
    blob = enc.finish()
    t1 = time.time()
    dec = RangeDecoder(blob, B.make_contexts())
    out = B.decode_levels(dec, n)
    t2 = time.time()
    assert np.array_equal(out, levels)
    _row("codec/encode", 1e6 * (t1 - t0),
         {"weights_per_s": n / (t1 - t0),
          "bits_per_param": 8 * len(blob) / n})
    _row("codec/decode", 1e6 * (t2 - t1), {"weights_per_s": n / (t2 - t1)})


def bench_rd_quant_kernel(fast: bool):
    import jax
    from repro import kernels
    from repro.core.quant import nearest_level
    from repro.core.rate_model import estimate_bin_probs
    rd_quant = kernels.get("rd_quant")
    rng = np.random.default_rng(1)
    n = (1 << 18) if fast else (1 << 20)
    w = (rng.standard_normal(n) * 0.05).astype(np.float32)
    probs = estimate_bin_probs(nearest_level(w, 0.01))
    # registry default path (jnp ref on CPU, pallas on TPU)
    out = rd_quant(w, None, probs, step=0.01, lam=1e-4)
    jax.block_until_ready(out)
    t0 = time.time()
    out = rd_quant(w, None, probs, step=0.01, lam=1e-4)
    jax.block_until_ready(out)
    t1 = time.time()
    _row("rd_quant/registry_default", 1e6 * (t1 - t0),
         {"weights_per_s": n / (t1 - t0), "n": n,
          "impl": rd_quant.plan(w, None, probs, step=0.01, lam=1e-4).impl})
    # pallas interpret path — correctness-path timing only (Python-level;
    # the TPU perf story lives in the roofline analysis)
    interp = kernels.KernelPolicy().override("rd_quant", "interpret")
    n2 = 1 << 15
    t0 = time.time()
    out = rd_quant(w[:n2], None, probs, step=0.01, lam=1e-4, policy=interp)
    jax.block_until_ready(out)
    t1 = time.time()
    _row("rd_quant/pallas_interpret", 1e6 * (t1 - t0), {"n": n2})


def bench_dequant_matmul(fast: bool):
    import jax
    import jax.numpy as jnp
    from repro import kernels
    dequant_matmul = kernels.get("dequant_matmul")
    rng = np.random.default_rng(2)
    m, k, n = 256, 2048, 1024
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.random(n) * 0.01, jnp.float32)
    out = dequant_matmul(x, wq, sc)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(10):
        out = dequant_matmul(x, wq, sc)
    jax.block_until_ready(out)
    t1 = time.time()
    us = 1e6 * (t1 - t0) / 10
    _row("dequant_matmul/registry_default", us,
         {"gflops": 2 * m * k * n / 1e9 / (us / 1e6),
          "impl": dequant_matmul.plan(x, wq, sc).impl,
          "weight_bytes_vs_bf16": 0.5})   # int8 weights halve HBM reads


def bench_comm_compression(fast: bool):
    """Wire-rate of the EF-compressed gradient stream (paper §VI)."""
    import jax
    import jax.numpy as jnp
    from repro.compression.q8 import q8_encode
    from repro.distributed.compress import (CompressionConfig,
                                            code_entropy_bits_per_param,
                                            ef_compress_update,
                                            init_error_feedback)
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.standard_normal((256, 1024)) * 1e-3,
                          jnp.float32)}
    ef = init_error_feedback(g)
    cfg = CompressionConfig(enabled=True)
    t0 = time.time()
    gq, ef = ef_compress_update(g, ef, cfg)
    jax.block_until_ready(gq)
    t1 = time.time()
    codes, _ = q8_encode(g["w"])
    ent = code_entropy_bits_per_param(codes)
    _row("comm/ef_int8", 1e6 * (t1 - t0),
         {"wire_bits_per_param_int8": 8.0 + 32.0 / 128,
          "cabac_entropy_bits_per_param": ent,
          "f32_baseline_bits": 32.0})


def bench_compression_registry(fast: bool):
    """Compress+decompress one pytree through every registered codec."""
    from repro import compression
    rng = np.random.default_rng(7)
    n = 64 if fast else 128
    tree = {
        "layers": {"blk": {"w": (rng.standard_normal((2, n, 2 * n)) * 0.05
                                 ).astype(np.float32)}},
        "embed": (rng.standard_normal((4 * n, n)) * 0.05).astype(np.float32),
        "norm": np.ones(n, np.float32),
    }
    for name in compression.available():
        codec = compression.get(name)
        t0 = time.time()
        art = codec.compress(tree)
        t1 = time.time()
        codec.decompress(art.blob, like=tree)
        t2 = time.time()
        _row(f"compression/{name}", 1e6 * (t1 - t0),
             {"bits_per_param": art.report["bits_per_param"],
              "ratio_pct": art.report["ratio_pct"],
              "decode_us": 1e6 * (t2 - t1)})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    fixtures = bench_table1(args.fast)
    bench_table2(fixtures, args.fast)
    bench_table3(fixtures, args.fast)
    bench_fig8(fixtures, args.fast)
    bench_codec_throughput(args.fast)
    bench_rd_quant_kernel(args.fast)
    bench_dequant_matmul(args.fast)
    bench_comm_compression(args.fast)
    bench_compression_registry(args.fast)


if __name__ == "__main__":
    main()
