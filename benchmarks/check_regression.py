"""CI benchmark-regression gate.

Compares freshly produced ``BENCH_*.json`` trajectories against the
committed baselines and fails the job when any smoke metric regresses by
more than ``--max-slowdown`` (default 30%).  Smoke metrics are the
headline throughput/latency numbers of each bench:

* ``BENCH_serve.json``       — per-backend ``total_tok_s``   (higher better;
  hard invariants on the compressed-resident rows: the q8 backend must
  serve at ``hbm_ratio <= 0.35`` of the bf16-resident weight bytes and
  stay greedy token-identical to it — ``tokens_match``)
* ``BENCH_cold_start.json``  — lane-engine ``values_per_s``  (higher better;
  the serial-scalar honesty rows are skipped — they are the baseline being
  beaten, not a product path)
* ``BENCH_shard_restore.json`` — per-path ``restore_s`` (lower better) and
  ``decoded_values_ratio`` (lower better; also re-asserts the sub-mesh
  row decodes strictly fewer values than the monolithic path)
* ``BENCH_delta.json``         — P-frame ``ratio_vs_full`` and
  ``tc_vs_intra`` (both lower better; hard invariants pin the delta at
  <= 0.35x the full re-encode and temporal-context CABAC strictly below
  intra coding of the same residuals) and live-swap ``swap_s``
  (lower better)
* ``BENCH_kv_paging.json``     — paged-KV ``sessions_per_gib_ratio``
  (higher better; hard invariants pin it >= 3x slot mode and require
  ``tokens_match`` — the paged run stays token-identical through forced
  eviction/restore) and ``restore_ms_mean`` (lower better)
* ``BENCH_zoo.json``           — multi-tenant zoo ``dedup_ratio`` (higher
  better; hard invariant >= 2x for 3 delta variants over one keyframe),
  admission ``cold_s``/``warm_s`` (lower better; hard invariant: delta-
  warm admit strictly faster than cold) and routed ``total_tok_s``
  (higher better; hard invariant: routed outputs stay token-identical
  to dedicated single-model sessions, and the budget forced eviction)
* ``BENCH_rd.json``            — per-arch RD-policy ``bytes_ratio`` vs the
  fixed-lambda ``deepcabac-v3`` default (lower better; hard invariant:
  every ``dominance`` row must report ``dominates`` — the swept
  ``deepcabac-rd`` container is <= bytes at <= greedy-token error)

Escape hatch: a commit whose message contains ``[bench-skip]`` passes the
gate with a notice (pass the message via ``--commit-message`` — CI hands
it ``git log -1 --pretty=%B``).  Metrics present only on one side (new
bench, renamed row) are reported and skipped, so adding a bench never
blocks the PR that introduces it.

Run:
    python benchmarks/check_regression.py \
        --baseline-dir /tmp/bench-baseline --fresh-dir . \
        --commit-message "$(git log -1 --pretty=%B)"
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_FILES = ("BENCH_serve.json", "BENCH_cold_start.json",
               "BENCH_shard_restore.json", "BENCH_delta.json",
               "BENCH_kv_paging.json", "BENCH_zoo.json", "BENCH_rd.json")


def _load(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def smoke_metrics(fname: str, report: dict) -> dict[str, tuple[float, bool]]:
    """name -> (value, higher_is_better) for one bench report."""
    out: dict[str, tuple[float, bool]] = {}
    rows = report.get("rows", [])
    if fname == "BENCH_serve.json":
        for r in rows:
            out[f"serve/{r['backend']}/total_tok_s"] = (
                float(r["total_tok_s"]), True)
    elif fname == "BENCH_cold_start.json":
        for r in rows:
            if r["engine"].startswith("scalar"):
                continue
            out[f"cold_start/{r['engine']}@{r['lanes']}/values_per_s"] = (
                float(r["values_per_s"]), True)
    elif fname == "BENCH_shard_restore.json":
        for r in rows:
            out[f"shard_restore/{r['path']}/restore_s"] = (
                float(r["restore_s"]), False)
            out[f"shard_restore/{r['path']}/decoded_values_ratio"] = (
                float(r["decoded_values_ratio"]), False)
    elif fname == "BENCH_delta.json":
        for r in rows:
            if r["path"] == "p_frame":
                out["delta/p_frame/ratio_vs_full"] = (
                    float(r["ratio_vs_full"]), False)
                out["delta/p_frame/tc_vs_intra"] = (
                    float(r["tc_vs_intra"]), False)
            elif r["path"] == "swap":
                out["delta/swap/swap_s"] = (float(r["swap_s"]), False)
    elif fname == "BENCH_kv_paging.json":
        for r in rows:
            if r["path"] == "capacity":
                out["kv_paging/capacity/sessions_per_gib_ratio"] = (
                    float(r["sessions_per_gib_ratio"]), True)
            elif r["path"] == "evict_restore" and r["pages_restored"]:
                out["kv_paging/evict_restore/restore_ms_mean"] = (
                    float(r["restore_ms_mean"]), False)
    elif fname == "BENCH_zoo.json":
        for r in rows:
            if r["path"] == "dedup":
                out["zoo/dedup/dedup_ratio"] = (float(r["dedup_ratio"]),
                                                True)
            elif r["path"] == "admit":
                out["zoo/admit/cold_s"] = (float(r["cold_s"]), False)
                out["zoo/admit/warm_s"] = (float(r["warm_s"]), False)
            elif r["path"] == "route":
                out["zoo/route/total_tok_s"] = (float(r["total_tok_s"]),
                                                True)
    elif fname == "BENCH_rd.json":
        for r in rows:
            if r["path"] == "dominance":
                out[f"rd/{r['arch']}/bytes_ratio"] = (
                    float(r["bytes_ratio"]), False)
    return out


def check_invariants(fname: str, report: dict) -> list[str]:
    """Hard correctness-adjacent invariants of the fresh run (no baseline
    needed)."""
    errors = []
    if fname == "BENCH_serve.json":
        for r in report.get("rows", []):
            if r["backend"] != "q8":
                continue
            if "hbm_ratio" not in r:
                errors.append(
                    "serve: the q8 row carries no hbm_ratio — the "
                    "compressed-resident accounting went unexercised")
                continue
            if r["hbm_ratio"] > 0.35:
                errors.append(
                    f"serve: q8-resident weights are {r['hbm_ratio']:.3f}x "
                    f"the bf16-resident bytes — compressed-resident serving "
                    f"must stay <= 0.35x")
            if not r.get("tokens_match"):
                errors.append(
                    "serve: q8-resident greedy outputs diverged from the "
                    "bf16-resident path — the fused dequant matmuls must "
                    "stay token-identical")
    elif fname == "BENCH_shard_restore.json":
        sub = [r for r in report.get("rows", [])
               if r["path"].startswith("manifest_submesh")]
        for r in sub:
            if r["decoded_values_ratio"] >= 1.0:
                errors.append(
                    f"{r['path']}: sub-mesh restore decoded "
                    f"{r['decoded_values']} values — not strictly fewer "
                    f"than the monolithic path")
    elif fname == "BENCH_delta.json":
        for r in report.get("rows", []):
            if r["path"] != "p_frame":
                continue
            if r["ratio_vs_full"] > 0.35:
                errors.append(
                    f"p_frame: delta is {r['ratio_vs_full']:.3f}x the full "
                    f"re-encode — residual coding must stay <= 0.35x for "
                    f"small perturbations")
            if r["tc_vs_intra"] >= 1.0:
                errors.append(
                    f"p_frame: temporal-context CABAC ({r['tc_bytes']} B) "
                    f"did not beat intra coding of the same residuals "
                    f"({r['intra_bytes']} B)")
    elif fname == "BENCH_kv_paging.json":
        for r in report.get("rows", []):
            if r["path"] != "capacity":
                continue
            if not r["tokens_match"]:
                errors.append(
                    "kv_paging: paged session diverged from slot mode — "
                    "compressed eviction must stay token-identical on "
                    "int8 caches")
            if r["sessions_per_gib_ratio"] < 3.0:
                errors.append(
                    f"kv_paging: {r['sessions_per_gib_ratio']:.2f}x "
                    f"sessions/GiB vs slot mode — the paged cache must "
                    f"sustain >= 3x concurrent long-context sessions per "
                    f"GiB of device KV")
    elif fname == "BENCH_zoo.json":
        for r in report.get("rows", []):
            if r["path"] == "dedup":
                if r["variants"] >= 3 and r["dedup_ratio"] < 2.0:
                    errors.append(
                        f"zoo: dedup_ratio {r['dedup_ratio']:.2f}x for "
                        f"{r['variants']} variants — the content-addressed "
                        f"store must dedup >= 2x with 3 delta variants "
                        f"over one keyframe")
            elif r["path"] == "admit":
                if r["warm_s"] >= r["cold_s"]:
                    errors.append(
                        f"zoo: delta-warm admit ({r['warm_s']}s) not "
                        f"faster than cold ({r['cold_s']}s) — warming from "
                        f"the resident base's levels must beat the full "
                        f"chain decode")
            elif r["path"] == "route":
                if not r["tokens_match"]:
                    errors.append(
                        "zoo: routed outputs diverged from dedicated "
                        "single-model sessions — multi-tenancy must stay "
                        "token-identical")
                if r["evictions"] < 1:
                    errors.append(
                        "zoo: the route bench's budget never forced an "
                        "eviction — the admission loop went unexercised")
    elif fname == "BENCH_rd.json":
        saw_dominance = False
        for r in report.get("rows", []):
            if r["path"] != "dominance":
                continue
            saw_dominance = True
            if not r.get("dominates"):
                errors.append(
                    f"rd/{r['arch']}: swept deepcabac-rd point "
                    f"({r['rd_bytes']} B @ token_err {r['rd_token_err']}) "
                    f"does not dominate the fixed-lambda deepcabac-v3 "
                    f"default ({r['v3_bytes']} B @ {r['v3_token_err']}) — "
                    f"the RD search must find <= bytes at <= distortion")
        if not saw_dominance:
            errors.append(
                "rd: no dominance rows in BENCH_rd.json — the sweep "
                "never compared against the deepcabac-v3 baseline")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly produced ones")
    ap.add_argument("--max-slowdown", type=float, default=0.30,
                    help="fail on > this fractional regression (0.30 = 30%%)")
    ap.add_argument("--commit-message", default="",
                    help="HEAD commit message; '[bench-skip]' skips the gate")
    args = ap.parse_args()

    if "[bench-skip]" in args.commit_message:
        print("benchmark-regression gate SKIPPED ([bench-skip] in commit "
              "message)")
        return 0

    failures: list[str] = []
    notes: list[str] = []
    for fname in BENCH_FILES:
        fresh = _load(os.path.join(args.fresh_dir, fname))
        base = _load(os.path.join(args.baseline_dir, fname))
        if fresh is None:
            notes.append(f"{fname}: no fresh run — skipped")
            continue
        failures += check_invariants(fname, fresh)
        if base is None:
            notes.append(f"{fname}: no committed baseline — skipped "
                         f"(first run of this bench)")
            continue
        fm = smoke_metrics(fname, fresh)
        bm = smoke_metrics(fname, base)
        for name in sorted(bm):
            if name not in fm:
                notes.append(f"{name}: dropped from fresh run — skipped")
                continue
            (fv, higher), (bv, _) = fm[name], bm[name]
            if bv <= 0:
                continue
            change = (fv - bv) / bv if higher else (bv - fv) / bv
            # change < 0 means "worse" in both orientations
            status = "OK " if change >= -args.max_slowdown else "FAIL"
            print(f"{status} {name}: baseline {bv:g} -> fresh {fv:g} "
                  f"({change * 100:+.1f}%)")
            if change < -args.max_slowdown:
                failures.append(
                    f"{name} regressed {-change * 100:.1f}% "
                    f"(baseline {bv:g}, fresh {fv:g}; limit "
                    f"{args.max_slowdown * 100:.0f}%)")
        for name in sorted(set(fm) - set(bm)):
            notes.append(f"{name}: new metric (no baseline) — tracked from "
                         f"next commit")
    for n in notes:
        print(f"note: {n}")
    if failures:
        print("\nbenchmark-regression gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        print("(rerun locally, or add [bench-skip] to the commit message "
              "for a known/intentional slowdown)", file=sys.stderr)
        return 1
    print("benchmark-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
