"""Trained model fixtures for the paper-table benchmarks.

The paper evaluates on ImageNet/CIFAR/MNIST models; offline we train small
models on deterministic synthetic tasks and reproduce the paper's
*mechanisms and orderings* (see DESIGN.md §10): a LeNet-300-100-style MLP
classifier (dense + VD-sparsified) and a small decoder LM.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import flatten_tree, unflatten_like
from repro import configs
from repro.core.fim import variational_fim, vd_sparsify
from repro.data.pipeline import make_batch, make_eval_batches
from repro.models.transformer import train_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

CLASSES, DIM = 10, 64


def synth_classification(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = np.random.default_rng(42).standard_normal((CLASSES, DIM))
    y = rng.integers(0, CLASSES, n)
    x = protos[y] + 0.9 * rng.standard_normal((n, DIM))
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def init_mlp(key, sizes=(DIM, 256, 128, CLASSES)):
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k, (a, b)) * (a ** -0.5)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp_logits(params, x):
    n = len([k for k in params if k.startswith("w")])
    h = x
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_loss(params, batch):
    x, y = batch
    logp = jax.nn.log_softmax(mlp_logits(params, x))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


@dataclass
class ClassifierFixture:
    params: dict
    sigma: dict | None
    accuracy: Callable[[dict], float]
    loss_batches: list


def train_mlp(steps: int = 400, seed: int = 0) -> ClassifierFixture:
    xtr, ytr = synth_classification(8192, seed=1)
    xte, yte = synth_classification(4096, seed=2)
    params = init_mlp(jax.random.PRNGKey(seed))
    cfg = AdamWConfig(lr=2e-3, weight_decay=0.0)
    state = adamw_init(params, cfg)
    step = jax.jit(lambda p, s, b: adamw_update(
        jax.grad(mlp_loss)(p, b), s, p, cfg))
    for i in range(steps):
        sl = slice((i * 256) % 8192, (i * 256) % 8192 + 256)
        params, state = step(params, state, (xtr[sl], ytr[sl]))

    def accuracy(p):
        pred = jnp.argmax(mlp_logits(p, xte), axis=-1)
        return float(jnp.mean(pred == yte))

    batches = [(xtr[i * 512:(i + 1) * 512], ytr[i * 512:(i + 1) * 512])
               for i in range(4)]
    return ClassifierFixture(params, None, accuracy, batches)


def sparsify_mlp(fx: ClassifierFixture, steps: int = 600
                 ) -> ClassifierFixture:
    """Variational-dropout sparsification ([26], paper §V-A) — also yields
    the per-parameter sigmas DC-v1 needs.  beta is auto-tuned: strongest
    sparsifier whose pruned accuracy stays within 2pp of the original
    (mirrors the paper keeping sparse-model accuracy)."""
    orig = fx.accuracy(fx.params)
    best = None
    for beta in (2e-3, 5e-4, 1e-4):
        res = variational_fim(mlp_loss, fx.params, fx.loss_batches,
                              steps=steps, beta=beta, lr=2e-3)
        pruned = vd_sparsify(res)
        acc = fx.accuracy(pruned)
        if acc >= orig - 0.02:
            best = (pruned, res.sigma)
            break
        if best is None:
            best = (pruned, res.sigma)
    pruned, sigma = best
    return ClassifierFixture(
        jax.tree.map(np.asarray, pruned),
        jax.tree.map(np.asarray, sigma),
        fx.accuracy, fx.loss_batches)


@dataclass
class LMFixture:
    cfg: object
    params: dict
    accuracy: Callable[[dict], float]   # next-token accuracy


def train_small_lm(steps: int = 150, seed: int = 0) -> LMFixture:
    cfg = configs.get("llama3-8b", smoke=True)
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.PRNGKey(seed))
    ocfg = AdamWConfig(lr=2e-3)
    state = adamw_init(params, ocfg)
    step = jax.jit(lambda p, s, b: adamw_update(
        jax.grad(train_loss)(p, b, cfg), s, p, ocfg))
    for i in range(steps):
        batch = make_batch(cfg, i, batch=16, seq=64)
        params, state = step(params, state, batch)
    evals = make_eval_batches(cfg, 2, batch=16, seq=64)

    def accuracy(p):
        from repro.models.transformer import forward
        accs = []
        for b in evals:
            logits, _, _ = forward(p, cfg, tokens=b.get("tokens"))
            pred = jnp.argmax(logits, -1)
            accs.append(float(jnp.mean(pred == b["labels"])))
        return float(np.mean(accs))

    return LMFixture(cfg, params, accuracy)


def flat_weights(params) -> dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in flatten_tree(params).items()}


def rebuild(template, flat):
    return unflatten_like({k: np.asarray(v) for k, v in flat.items()},
                          template)
