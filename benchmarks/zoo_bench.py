"""Multi-tenant model-zoo benchmark: dedup, admission, routed serving.

Builds a llama3 smoke keyframe plus delta finetune variants (star
topology — every variant chains straight to the keyframe), then
measures the three economics the zoo trades on:

* ``dedup``  — content-addressed :class:`~repro.serve.zoo.ShardStore`
  on-disk footprint for base + N variants vs naive per-model copies
  (``dedup_ratio = logical / physical``).
* ``admit``  — cold admission (full chain entropy decode from disk) vs
  delta-warm admission (fork the resident base's tracked levels, apply
  only the variant's own delta steps).
* ``route``  — a :class:`~repro.serve.zoo.ZooRouter` serving
  interleaved traffic to base + 2 variants under an HBM budget that
  forces eviction, checked token-identical against dedicated
  single-model sessions.

Writes ``BENCH_zoo.json`` (same trajectory contract as the other
BENCH files; gated by ``check_regression.py`` — dedup_ratio >= 2.0 for
3 variants, warm admit strictly faster than cold, tokens_match).

Run: PYTHONPATH=src python -m benchmarks.zoo_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np


def _build_family(root: str, variants: int):
    """Keyframe at step 1 + ``variants`` partial-finetune delta steps."""
    import jax
    from repro import compression, configs
    from repro.checkpoint import delta
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    from repro.checkpoint.sharded import MANIFEST_NAME
    from repro.compression.tree import flatten_tree
    from repro.models.transformer import init_params

    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    CheckpointManager(CheckpointConfig(
        directory=root, sharded=True,
        codec="deepcabac-delta")).save({"params": params}, step=1)
    codec = compression.get("deepcabac-delta")
    flat = flatten_tree(params)
    base_entries = codec.quantize_entries(flat)
    names = sorted(k for k, v in flat.items() if v.dtype.kind == "f")
    touched = set(names[:max(1, len(names) // 4)])
    for i in range(variants):
        rng = np.random.default_rng(100 + i)
        pert = {k: (v * (1 + 5e-4 * rng.standard_normal(v.shape)))
                .astype(v.dtype) if k in touched else v
                for k, v in flat.items()}
        dentries = codec.delta_entries(pert, base_entries)
        payloads, manifest = delta.write_delta(
            dentries, codec_name=codec.name, base=delta.base_ref(root, 1),
            num_gr=codec.coder.num_gr, chunk_size=codec.coder.chunk_size)
        d = delta.step_dir(root, 2 + i)
        os.makedirs(d)
        for fname, blob in payloads.items():
            with open(os.path.join(d, fname), "wb") as f:
                f.write(blob)
        with open(os.path.join(d, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=1)
    return cfg


def bench_dedup(root: str, store_dir: str, variants: int) -> dict:
    from repro.checkpoint import delta
    from repro.serve.zoo import ShardStore

    store = ShardStore(store_dir)
    t0 = time.time()
    store.add("base", delta.step_dir(root, 1))
    for i in range(variants):
        store.add(f"var-{i}", delta.step_dir(root, 2 + i))
    ingest_s = time.time() - t0
    rep = store.report()
    store.close()
    return {
        "path": "dedup",
        "models": 1 + variants,
        "variants": variants,
        "objects": rep["objects"],
        "logical_mb": round(rep["logical_bytes"] / 2**20, 3),
        "physical_mb": round(rep["physical_bytes"] / 2**20, 3),
        "dedup_ratio": rep["dedup_ratio"],
        "bytes_deduped_mb": round(rep["stats"]["bytes_deduped"] / 2**20, 3),
        "ingest_s": round(ingest_s, 4),
    }


def bench_admit(cfg, root: str, store_dir: str) -> dict:
    """Cold admit of a variant (full chain decode) vs delta-warm admit
    of its sibling from the already-resident base."""
    from repro.checkpoint import delta
    from repro.serve.session import ServeConfig
    from repro.serve.zoo import ModelZoo, ZooConfig, model_resident_bytes

    serve_cfg = ServeConfig(slots=2, max_len=64)
    one = model_resident_bytes(cfg, serve_cfg)
    zoo = ModelZoo(store_dir, ZooConfig(hbm_budget=3 * one,
                                        serve=serve_cfg))
    zoo.register("base", cfg, delta.step_dir(root, 1))
    zoo.register("var-0", cfg, delta.step_dir(root, 2))
    zoo.register("var-1", cfg, delta.step_dir(root, 3))

    t0 = time.time()
    zoo.admit("var-0")                       # base not resident: cold,
    cold_s = time.time() - t0                # full chain decode
    zoo.admit("base")                        # cold too (keyframe only)
    t0 = time.time()
    zoo.admit("var-1")                       # base resident: delta-warm
    warm_s = time.time() - t0
    assert zoo.zoo_report()["models"]["var-1"]["last_admit"] == "warm"
    zoo.close()
    return {
        "path": "admit",
        "cold_s": round(cold_s, 4),
        "warm_s": round(warm_s, 4),
        "warm_vs_cold": round(warm_s / max(cold_s, 1e-9), 4),
    }


def bench_route(cfg, root: str, store_dir: str, requests: int,
                new_tokens: int) -> dict:
    """Interleaved traffic to base + 2 variants under a 2-model budget
    (forces eviction); throughput + token identity vs dedicated
    sessions."""
    from repro.checkpoint import delta
    from repro.serve.backends import get_backend
    from repro.serve.session import ServeConfig, ServeSession
    from repro.serve.zoo import (ModelZoo, ZooConfig, ZooRouter,
                                 model_resident_bytes)

    serve_cfg = ServeConfig(slots=2, max_len=64)
    one = model_resident_bytes(cfg, serve_cfg)
    zoo = ModelZoo(store_dir, ZooConfig(hbm_budget=2 * one + one // 2,
                                        serve=serve_cfg))
    models = {"base": 1, "var-0": 2, "var-1": 3}
    for mid, step in models.items():
        zoo.register(mid, cfg, delta.step_dir(root, step))
    rng = np.random.default_rng(7)
    prompts = {m: rng.integers(1, cfg.vocab_size, 8 + 3 * j)
               for j, m in enumerate(models)}
    order = [m for _ in range(requests) for m in models]

    router = ZooRouter(zoo)
    t0 = time.time()
    handles = [(m, router.submit(m, prompts[m], max_new_tokens=new_tokens))
               for m in order]
    router.run(max_steps=20000)
    total_s = time.time() - t0
    assert all(h.done for _m, h in handles)
    rep = zoo.zoo_report()
    zoo.close()

    tokens_match = True
    for m, step in models.items():
        mine = [list(map(int, h.result())) for mid, h in handles
                if mid == m]
        backend = get_backend("container", track_levels=True)
        params = backend.load_entries(cfg, delta.restore_levels(root, step))
        sess = ServeSession.from_loaded(cfg, params, backend=backend,
                                       serve_cfg=serve_cfg)
        refs = [sess.submit(prompts[m], max_new_tokens=new_tokens)
                for _ in mine]
        sess.run(max_steps=20000)
        ref = [list(map(int, h.result())) for h in refs]
        sess.close()
        tokens_match = tokens_match and mine == ref

    toks = sum(len(h.new_tokens()) for _m, h in handles)
    return {
        "path": "route",
        "models": len(models),
        "requests": len(order),
        "total_tokens": toks,
        "total_s": round(total_s, 4),
        "total_tok_s": round(toks / max(total_s, 1e-9), 2),
        "evictions": rep["stats"]["evictions"],
        "admits_cold": rep["stats"]["admits_cold"],
        "admits_warm": rep["stats"]["admits_warm"],
        "tokens_match": bool(tokens_match),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_zoo.json")
    args = ap.parse_args()

    variants = 3                             # dedup >= 2x needs >= 3
    requests = 2 if args.fast else 4
    new_tokens = 5 if args.fast else 12

    work = tempfile.mkdtemp(prefix="zoo-bench-")
    try:
        root = os.path.join(work, "ckpt")
        os.makedirs(root)
        cfg = _build_family(root, variants)
        rows = [
            bench_dedup(root, os.path.join(work, "store-dedup"), variants),
            bench_admit(cfg, root, os.path.join(work, "store-admit")),
            bench_route(cfg, root, os.path.join(work, "store-route"),
                        requests, new_tokens),
        ]
    finally:
        shutil.rmtree(work, ignore_errors=True)

    report = {
        "bench": "model_zoo",
        "arch": cfg.name,
        "fast": bool(args.fast),
        "variants": variants,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"zoo/{r['path']},{json.dumps(r, default=float)}", flush=True)
    print(f"wrote {args.out}")

    dedup, admit, route = rows
    failures = []
    if dedup["dedup_ratio"] < 2.0:
        failures.append(f"dedup_ratio {dedup['dedup_ratio']} < 2.0 for "
                        f"{variants} variants")
    if admit["warm_s"] >= admit["cold_s"]:
        failures.append(f"warm admit ({admit['warm_s']}s) not faster than "
                        f"cold ({admit['cold_s']}s)")
    if not route["tokens_match"]:
        failures.append("routed outputs diverged from dedicated sessions")
    if route["evictions"] < 1:
        failures.append("budget never forced an eviction")
    if failures:
        raise SystemExit("zoo bench invariants FAILED: "
                         + "; ".join(failures))


if __name__ == "__main__":
    main()
