"""Sharded-checkpoint restore benchmark: manifest-driven vs monolithic.

Saves the smoke-model state both ways with the same ``deepcabac-v3``
codec, then measures

* monolithic restore (whole-container lane-batched decode — the
  pre-sharding cold-start path),
* manifest-driven full restore on a 1-device target (must decode the
  same value count and reproduce the monolithic params bit-for-bit),
* manifest-driven *sub-mesh* restore (one host of an N-way target mesh):
  the decoded-value counter must come in strictly below the monolithic
  path — the random-access payoff of per-shard containers + byte-range
  record reads.

Writes ``BENCH_shard_restore.json`` so CI accumulates a trajectory
(same contract as BENCH_serve / BENCH_cold_start); the benchmark-
regression gate (benchmarks/check_regression.py) compares it against the
committed baseline.

Run: PYTHONPATH=src python -m benchmarks.shard_restore_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np


def _state_dict(copies: int):
    import jax
    from repro import configs
    from repro.models.transformer import init_params

    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if copies == 1:
        return cfg, params
    return cfg, {f"rep{i}": params for i in range(copies)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_shard_restore.json")
    ap.add_argument("--copies", type=int, default=None)
    ap.add_argument("--save-shards", type=int, default=4,
                    help="data-axis size of the save mesh")
    ap.add_argument("--sub-mesh", type=int, default=2,
                    help="data-axis size of the sub-mesh restore target")
    args = ap.parse_args()

    from repro import compression
    from repro.checkpoint import sharded

    copies = args.copies or (1 if args.fast else 4)
    chunk_size = 2048 if args.fast else 4096
    cfg, tree = _state_dict(copies)
    codec = compression.get("deepcabac-v3", delta_rel=1e-3,
                            chunk_size=chunk_size)
    reps = 1 if args.fast else 2

    # -- monolithic baseline -------------------------------------------------
    blob = codec.compress(tree).blob
    mono_best, mono = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        mono = compression.decompress(blob, batched=True)
        mono_best = min(mono_best, time.time() - t0)

    with tempfile.TemporaryDirectory() as td:
        # -- sharded save ----------------------------------------------------
        entries = codec.quantize_entries(tree)
        mesh = sharded.MeshSpec(("data", "model"), (args.save_shards, 1))
        t0 = time.time()
        payloads, manifest = sharded.write_sharded(
            entries, mesh, codec_name=codec.name, chunk_size=chunk_size,
            workers=4)
        save_s = time.time() - t0
        for fname, data in payloads.items():
            with open(os.path.join(td, fname), "wb") as f:
                f.write(data)
        with open(os.path.join(td, sharded.MANIFEST_NAME), "w") as f:
            json.dump(manifest, f)
        total_values = sharded.manifest_total_values(manifest)
        shard_bytes = sum(len(b) for b in payloads.values())

        # -- manifest-driven full restore (1-device target) ------------------
        full_best = float("inf")
        for _ in range(reps):
            stats = sharded.RestoreStats()
            t0 = time.time()
            full = sharded.restore_flat(td, workers=4, stats=stats)
            full_best = min(full_best, time.time() - t0)
        full_stats = stats
        mismatch = [k for k in mono
                    if not np.array_equal(np.asarray(mono[k]),
                                          np.asarray(full[k]))]
        assert not mismatch, f"sharded restore diverged: {mismatch[:3]}"

        # -- sub-mesh restore: one host (device 0) of an N-way target --------
        sub_mesh = sharded.MeshSpec(("data", "model"), (args.sub_mesh, 1))
        sub_best = float("inf")
        for _ in range(reps):
            stats = sharded.RestoreStats()
            t0 = time.time()
            sharded.restore_local_slices(td, sub_mesh, [0], workers=4,
                                         stats=stats)
            sub_best = min(sub_best, time.time() - t0)
        sub_stats = stats
        assert sub_stats.decoded_values < total_values, (
            "sub-mesh restore must decode strictly fewer values than the "
            f"monolithic path ({sub_stats.decoded_values} vs {total_values})")

    rows = [
        {"path": "monolithic", "restore_s": round(mono_best, 4),
         "decoded_values": total_values, "decoded_values_ratio": 1.0,
         "values_per_s": round(total_values / max(mono_best, 1e-9), 1)},
        {"path": "manifest_full_1dev", "restore_s": round(full_best, 4),
         "decoded_values": full_stats.decoded_values,
         "decoded_values_ratio": round(
             full_stats.decoded_values / max(total_values, 1), 4),
         "read_bytes": full_stats.read_bytes,
         "values_per_s": round(
             full_stats.decoded_values / max(full_best, 1e-9), 1)},
        {"path": f"manifest_submesh_1of{args.sub_mesh}",
         "restore_s": round(sub_best, 4),
         "decoded_values": sub_stats.decoded_values,
         "decoded_values_ratio": round(
             sub_stats.decoded_values / max(total_values, 1), 4),
         "read_bytes": sub_stats.read_bytes,
         "values_per_s": round(
             sub_stats.decoded_values / max(sub_best, 1e-9), 1)},
    ]
    report = {
        "bench": "shard_restore",
        "arch": cfg.name,
        "fast": bool(args.fast),
        "copies": copies,
        "chunk_size": chunk_size,
        "save_mesh": manifest["mesh"],
        "tensors": len(manifest["tensors"]),
        "shard_files": len(manifest["files"]),
        "entropy_coded_values": total_values,
        "monolithic_mb": round(len(blob) / 2**20, 2),
        "sharded_mb": round(shard_bytes / 2**20, 2),
        "sharded_save_s": round(save_s, 4),
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"shard_restore/{r['path']},{r['restore_s']},"
              f"{json.dumps(r, default=float)}", flush=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
